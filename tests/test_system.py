"""End-to-end behaviour: train -> checkpoint -> crash -> restart -> serve.

The acceptance story for the fault-tolerance substrate: a training run
interrupted at step k and restarted from its checkpoint must produce the
SAME parameters as the uninterrupted run (deterministic data + exact
restore), and the trained model must serve through the batched engine.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt
from repro.serve import ServeEngine, generate
from repro.train import TrainStepConfig, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3),
        TrainStepConfig(microbatches=1, remat="none", total_steps=100)))
    src = SyntheticLM(vocab=cfg.vocab, seed=9)
    return cfg, model, step_fn, src


def _batch(src, step):
    b = src.batch(step=step, shard=0, n_shards=1, batch=8, seq=32)
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases(setup):
    cfg, model, step_fn, src = setup
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt(params)
    losses = []
    for i in range(40):
        params, opt, m = step_fn(params, opt, _batch(src, i))
        losses.append(float(m["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, f"{first} -> {last}"


def test_crash_restart_exact_resume(setup):
    cfg, model, step_fn, src = setup
    params = model.init(jax.random.PRNGKey(1))
    opt = init_opt(params)

    with tempfile.TemporaryDirectory() as d:
        # continuous run: 10 steps
        p_ref, o_ref = params, opt
        for i in range(10):
            p_ref, o_ref, _ = step_fn(p_ref, o_ref, _batch(src, i))

        # interrupted run: 6 steps, checkpoint, "crash", restore, 4 more
        p, o = params, opt
        for i in range(6):
            p, o, _ = step_fn(p, o, _batch(src, i))
        save(d, 6, {"params": p, "opt": o}, extra={"data_step": 6})
        del p, o

        step = latest_step(d)
        assert step == 6
        state, extra = restore(d, step, {"params": params, "opt": opt})
        p, o = state["params"], state["opt"]
        for i in range(extra["data_step"], 10):
            p, o, _ = step_fn(p, o, _batch(src, i))

        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-5)


def test_microbatch_equivalence(setup):
    """2-way grad accumulation must match the single-batch step closely."""
    cfg, model, _, src = setup
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(src, 0)
    s1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                 TrainStepConfig(microbatches=1, remat="none")))
    s2 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                 TrainStepConfig(microbatches=2, remat="none")))
    p1, _, m1 = s1(params, init_opt(params), batch)
    p2, _, m2 = s2(params, init_opt(params), batch)
    # losses equal (mean over same tokens), params close
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    diffs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 5e-3


def test_remat_does_not_change_loss(setup):
    cfg, model, _, src = setup
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(src, 0)
    outs = []
    for remat in ("none", "full", "dots"):
        s = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                    TrainStepConfig(remat=remat)))
        _, _, m = s(params, init_opt(params), batch)
        outs.append(float(m["loss"]))
    assert max(outs) - min(outs) < 1e-4


def test_serve_after_training(setup):
    cfg, model, step_fn, src = setup
    params = model.init(jax.random.PRNGKey(4))
    opt = init_opt(params)
    for i in range(5):
        params, opt, _ = step_fn(params, opt, _batch(src, i))
    eng = ServeEngine(model, params, slots=4, prompt_len=16, max_new=8)
    prompt = np.asarray(_batch(src, 99)["tokens"][0, :12])
    for rid in range(5):
        eng.submit(rid, prompt)
    out = eng.run()
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert all(len(v) == 8 for v in out.values())
    # greedy generate must equal manual prefill+decode chain
    toks = generate(model, params,
                    {"tokens": jnp.asarray(prompt)[None, :]}, max_new=4)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=20))(
        params, {"tokens": jnp.asarray(prompt)[None, :]})
    t0 = int(jnp.argmax(logits, -1)[0])
    assert int(toks[0, 0]) == t0
