"""The system-level PLM planner: compatibility certificate, shared-bank
planning, the tile knob axis, and the WAMI memory-co-design acceptance
run on the checked-in recording (docs/memory.md)."""

import pytest

from repro.apps.wami.knobs import WAMI_TILE_SIZES
from repro.apps.wami.pipeline import (wami_hls_tool, wami_plm_planner,
                                      wami_session, wami_tmg)
from repro.core import (KnobSpace, MemGen, MemoryCompatGraph, PLMPlanner,
                        PLMRequirement, PLMSpec, exclusive_pairs)
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest
from repro.core.oracle import OracleLedger
from repro.core.plm.planner import shared_area
from repro.core.tmg import Place, TMG, Transition, pipeline_tmg


# ----------------------------------------------------------------------
# compatibility certificate
# ----------------------------------------------------------------------
def test_wami_lk_loop_is_mutually_exclusive():
    """The one-token LK refinement cycle certifies exactly the six loop
    components; streaming neighbours (2-token ping-pong) stay concurrent."""
    g = MemoryCompatGraph(wami_tmg())
    lk = {"warp", "matrix_sub", "sd_update", "matrix_mul", "matrix_add",
          "matrix_resh"}
    for u in lk:
        for v in lk:
            if u != v:
                assert g.may_share(u, v), (u, v)
    assert not g.may_share("debayer", "grayscale")
    assert not g.may_share("gradient", "steep_descent")
    assert not g.may_share("hessian", "matrix_inv")


def test_single_buffer_pipeline_serializes_neighbours():
    """buffers=1 ping-pong: adjacent stages share a 1-token cycle (the
    TMG model itself says they serialize) -> shareable."""
    tmg = pipeline_tmg(["a", "b", "c"], buffers=1)
    g = MemoryCompatGraph(tmg)
    assert g.may_share("a", "b") and g.may_share("b", "c")
    tmg2 = pipeline_tmg(["a", "b", "c"], buffers=2)
    g2 = MemoryCompatGraph(tmg2)
    assert not g2.may_share("a", "b")


def test_self_loops_certify_nothing():
    tmg = TMG([Transition("a"), Transition("b")],
              [Place("self:a", "a", "a", tokens=1),
               Place("self:b", "b", "b", tokens=1),
               Place("f", "a", "b", tokens=2),
               Place("r", "b", "a", tokens=2)])
    assert exclusive_pairs(tmg) == frozenset()


# ----------------------------------------------------------------------
# memgen shared generation
# ----------------------------------------------------------------------
def test_generate_shared_envelope_and_benefit():
    gen = MemGen()
    specs = [PLMSpec(words=32768, word_bits=32, ports=4),
             PLMSpec(words=49152, word_bits=32, ports=2),
             PLMSpec(words=114688, word_bits=32, ports=8)]
    shared = gen.generate_shared(specs)
    assert shared.ports == 8 and shared.clients == 3
    assert shared.banks & (shared.banks - 1) == 0
    private = sum(gen.generate(s).area for s in specs)
    biggest = gen.generate(PLMSpec(words=114688, word_bits=32, ports=8)).area
    assert biggest < shared.area < private


def test_plm_bits_regression():
    """PLM.bits used to be dead code (`... * 0`, always 0)."""
    gen = MemGen()
    plm = gen.generate(PLMSpec(words=8192, word_bits=32, ports=4))
    assert plm.bits == plm.banks * plm.words_per_bank * 32
    assert plm.bits >= 8192 * 32        # capacity is padded up, never down
    assert plm.bits == plm.total_bits(32)


# ----------------------------------------------------------------------
# planner
# ----------------------------------------------------------------------
def _req(name, words=4096, ports=2, area=None, logic=0.01, unit="mm2"):
    gen = MemGen()
    a = area if area is not None else gen.generate(
        PLMSpec(words=words, word_bits=32, ports=ports)).area
    return PLMRequirement(component=name, capacity=words, word_bits=32,
                          ports=ports, area_plm=a, area_logic=logic,
                          unit=unit)


def _chain_planner(names, buffers=1):
    return PLMPlanner(pipeline_tmg(list(names), buffers=buffers))


def test_planner_groups_and_guard():
    planner = _chain_planner(["a", "b", "c"])       # all pairwise exclusive
    plan = planner.plan([_req("a", words=65536), _req("b", words=32768),
                         _req("c", words=65536)])
    merged = [g for g in plan.groups if len(g.members) > 1]
    assert merged, "large exclusive PLMs must merge"
    assert plan.system_cost <= plan.area_private + 1e-12
    assert plan.saved > 0
    for g in plan.groups:
        assert g.area <= g.area_private + 1e-12


def test_guard_holds_when_backend_underprices_memgen():
    """The merge guard compares against the group's PLAN price (private
    area for singletons), so a backend whose area_plm undercuts the
    planner's MemGen model can never merge into a dearer group — the
    dominance invariant holds for ANY area model, not just HLSTool's."""
    planner = _chain_planner(["a", "b", "c"])
    gen = MemGen()
    memgen_price = gen.generate(PLMSpec(words=65536, word_bits=32,
                                        ports=2)).area
    cheap = memgen_price / 3.0             # backend prices below MemGen
    plan = planner.plan([_req("a", words=65536, area=cheap),
                         _req("b", words=65536, area=cheap)])
    assert plan.system_cost <= plan.area_private + 1e-12
    for g in plan.groups:
        assert g.saved >= -1e-12


def test_planner_never_groups_concurrent_components():
    planner = _chain_planner(["a", "b", "c"], buffers=2)   # concurrent
    plan = planner.plan([_req("a", words=65536), _req("b", words=65536)])
    assert all(len(g.members) == 1 for g in plan.groups)
    assert plan.saved == 0.0
    assert plan.system_cost == pytest.approx(plan.area_private)


def test_planner_respects_units_and_unsplittable():
    planner = _chain_planner(["a", "b", "c"])
    reqs = [_req("a", words=65536, unit="mm2"),
            _req("b", area=1e6, words=65536, unit="bytes"),
            PLMRequirement(component="c", capacity=0, word_bits=0, ports=1,
                           area_plm=0.0, area_logic=0.5)]
    plan = planner.plan(reqs)
    assert all(len(g.members) == 1 for g in plan.groups)


def test_planner_deterministic():
    planner = _chain_planner(["a", "b", "c", "d"])
    reqs = [_req(n, words=w) for n, w in
            (("a", 65536), ("b", 32768), ("c", 65536), ("d", 16384))]
    p1 = planner.plan(list(reqs))
    p2 = planner.plan(list(reversed(reqs)))
    assert p1 == p2


def test_shared_area_bytes_unit():
    r1 = _req("a", area=1e5, unit="bytes")
    r2 = _req("b", area=3e5, unit="bytes")
    area, *_ = shared_area([r1, r2], MemGen())
    assert 3e5 < area < 4e5          # max + arbitration, far below the sum


# ----------------------------------------------------------------------
# the tile knob axis
# ----------------------------------------------------------------------
def _tool():
    loop = LoopNest(trip=1024, gamma_r=4, gamma_w=2, arith_ops=16,
                    dep_depth=4, live_values=8)
    spec = ComponentSpec("c", loop, words_in=4096, words_out=4096,
                         outer_repeats=16, base_tile=32)
    return HLSTool({"c": spec}, noise=0.0)


def test_tile_trades_capacity_for_latency():
    """Bigger tile: bigger PLM (more area), fewer outer repeats (lower
    latency) — the capacity-vs-ports trade the planner explores."""
    tool = _tool()
    s32 = tool.synthesize("c", unrolls=4, ports=4, tile=32)
    s64 = tool.synthesize("c", unrolls=4, ports=4, tile=64)
    assert s64.detail["plm_words"] > s32.detail["plm_words"]
    assert s64.area > s32.area
    assert s64.lam < s32.lam
    # native tile == explicit base tile == no tile: identical numbers
    s0 = tool.synthesize("c", unrolls=4, ports=4)
    assert (s32.lam, s32.area) == (s0.lam, s0.area)
    assert s32.tile == 32 and s0.tile == 0


def test_characterize_labels_tile_axis():
    from repro.core.characterize import characterize_component
    ledger = OracleLedger(_tool())
    space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8,
                      tile_sizes=(32, 64))
    res = characterize_component(ledger, "c", space)
    tiles = {dict(p.knobs).get("tile", 0) for p in res.points}
    assert {32, 64} <= tiles
    assert {r.tile for r in res.regions} >= {32, 64}


def test_characterize_tile_order_independent():
    """Region pruning resets per tile ladder: the kept region set must
    not depend on tile_sizes ordering, and a slower tile's cheap
    regions survive even when a bigger tile is faster everywhere."""
    from repro.core.characterize import characterize_component

    def regions_for(order):
        ledger = OracleLedger(_tool())
        space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8,
                          tile_sizes=order)
        res = characterize_component(ledger, "c", space)
        return sorted((r.tile, r.ports, r.lam_max, r.area_min)
                      for r in res.regions)

    asc = regions_for((32, 64))
    desc = regions_for((64, 32))
    assert asc == desc
    assert {t for t, *_ in asc} == {32, 64}


def test_tile_points_cached_separately():
    ledger = OracleLedger(_tool())
    a = ledger.synthesize("c", unrolls=4, ports=2, tile=32)
    b = ledger.synthesize("c", unrolls=4, ports=2, tile=64)
    assert a.area != b.area
    assert ledger.total("c") == 2
    ledger.synthesize("c", unrolls=4, ports=2, tile=64)   # cache hit
    assert ledger.total("c") == 2


# ----------------------------------------------------------------------
# session integration + WAMI acceptance
# ----------------------------------------------------------------------
def test_session_shared_cost_dominates_naive_sum_analytical():
    sess = wami_session(0.3, workers=8, share_plm=True,
                        tile_sizes=WAMI_TILE_SIZES)
    res = sess.run()
    assert res.mapped
    strictly = 0
    for m in res.mapped:
        assert m.cost_unshared is not None
        assert m.cost_actual <= m.cost_unshared + 1e-12
        if m.cost_actual < m.cost_unshared * (1 - 1e-12):
            strictly += 1
        assert m.plm_groups            # LK loop shares on every point
    assert strictly >= 1


def test_wami_plm_acceptance_on_checked_in_recording():
    """ISSUE acceptance: on the tile-128 recording, the shared-PLM
    system front dominates or equals the per-component-sum front at
    every point, at least one point is strictly cheaper, the drive is
    deterministic across runs, and the tile axis shows up in >= 3
    components' characterized Pareto sets."""
    from repro.apps.wami.pallas import wami_plm_session
    res1 = wami_plm_session(0.25, workers=4).run()
    res2 = wami_plm_session(0.25, workers=4).run()

    pts1 = [(m.theta_actual, m.cost_actual, m.cost_unshared, m.plm_groups)
            for m in res1.mapped]
    pts2 = [(m.theta_actual, m.cost_actual, m.cost_unshared, m.plm_groups)
            for m in res2.mapped]
    assert pts1 == pts2
    assert res1.invocations == res2.invocations

    strictly = 0
    for theta, shared, naive, groups in pts1:
        assert shared <= naive + 1e-9
        if shared < naive * (1 - 1e-12):
            strictly += 1
    assert strictly >= 1

    tile_axis = [n for n, ch in res1.characterizations.items()
                 if len({dict(p.knobs).get("tile", 0)
                         for p in ch.points} - {0}) >= 2]
    assert len(tile_axis) >= 3


def test_wami_plm_planner_excludes_software_component():
    planner = wami_plm_planner()
    assert "matrix_inv" in planner.exclude


def test_excluded_component_area_stays_in_the_plan():
    """exclude means nothing-to-share, not free: the component's whole
    area must survive as unsplittable logic in the planned cost."""
    tool = _tool()
    planner = PLMPlanner(pipeline_tmg(["c", "d"]), exclude=("c",))
    synth = tool.synthesize("c", unrolls=4, ports=2)
    plan = planner.plan_point(OracleLedger(tool), {"c": synth})
    assert plan.system_cost == pytest.approx(synth.area)
    (group,) = plan.groups
    assert group.members == ("c",) and group.area == 0.0
