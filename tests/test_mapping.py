"""Synthesis mapping phi (Eqs. 4-5) — including the paper's own example."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CDFGFacts, CountingTool, Region, map_target, phi)
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest


def test_paper_example_2():
    """Fig. 7: lam_max=40s, lam_min=10s, mu_min=1, mu_max=30;
    lam_target=20s must map to 11 unrolls (after ceiling)."""
    mu = phi(20.0, 10.0, 40.0, 1, 30)
    assert math.ceil(mu) == 11


def test_phi_endpoints():
    assert phi(40.0, 10.0, 40.0, 1, 30) == pytest.approx(1.0)
    assert phi(10.0, 10.0, 40.0, 1, 30) == pytest.approx(30.0)


@settings(max_examples=100, deadline=None)
@given(st.floats(1.0, 50.0), st.floats(51.0, 500.0),
       st.integers(1, 8), st.integers(9, 64))
def test_phi_monotone_decreasing(lam_min, lam_max, mu_min, mu_max):
    """More aggressive latency targets need more unrolls."""
    lams = [lam_min + (lam_max - lam_min) * f for f in (0.1, 0.4, 0.7, 1.0)]
    mus = [phi(l, lam_min, lam_max, mu_min, mu_max) for l in lams]
    for a, b in zip(mus, mus[1:]):
        assert a >= b - 1e-9
    assert all(mu_min - 1e-9 <= m <= mu_max + 1e-9 for m in mus)


def _tool():
    spec = ComponentSpec(
        "c", LoopNest(trip=1024, gamma_r=2, gamma_w=1, arith_ops=8,
                      dep_depth=3, live_values=8),
        words_in=2048, words_out=2048)
    return CountingTool(HLSTool({"c": spec}, noise=0.0))


def _regions(tool):
    from repro.core import KnobSpace, characterize_component
    return characterize_component(tool, "c",
                                  KnobSpace(clock_ns=1.0, max_ports=4,
                                            max_unrolls=16)).regions


def test_map_inside_region_meets_target():
    tool = _tool()
    regions = _regions(tool)
    r = regions[0]
    lam_target = (r.lam_min + r.lam_max) / 2
    out = map_target(tool, "c", regions, lam_target)
    assert out.synthesis.feasible
    assert out.synthesis.lam <= lam_target * 1.0 + 1e-12


def test_map_gap_falls_to_next_region():
    tool = _tool()
    regions = _regions(tool)
    assert len(regions) >= 2
    slow = sorted(regions, key=lambda r: r.lam_max, reverse=True)
    gap_lo = slow[1].lam_max          # fastest corner of next region
    gap_hi = slow[0].lam_min          # slowest corner of first region
    if gap_lo < gap_hi:               # a real gap exists
        lam_target = (gap_lo + gap_hi) / 2
        out = map_target(tool, "c", regions, lam_target)
        assert out.fallback == "next-region"
        # conservative: trades area to preserve throughput
        assert out.synthesis.lam <= lam_target


def test_map_extremes():
    tool = _tool()
    regions = _regions(tool)
    out_slow = map_target(tool, "c", regions, 1e9)
    assert out_slow.fallback in ("", "slowest")
    out_fast = map_target(tool, "c", regions, 1e-12)
    assert out_fast.fallback == "fastest"


def test_mapping_reuses_characterized_points():
    """The next-region fallback must be a cache hit (no new invocation)."""
    tool = _tool()
    regions = _regions(tool)
    before = tool.total("c")
    slow = sorted(regions, key=lambda r: r.lam_max, reverse=True)
    if len(slow) >= 2 and slow[1].lam_max < slow[0].lam_min:
        lam_target = (slow[1].lam_max + slow[0].lam_min) / 2
        map_target(tool, "c", regions, lam_target)
        assert tool.total("c") == before  # cache hit
