"""Simulated HLS tool + memory generator behaviour (DESIGN.md Section 2)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import MemGen, PLMSpec
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest


def _spec(**kw):
    d = dict(trip=1024, gamma_r=4, gamma_w=2, arith_ops=16, dep_depth=4,
             live_values=8)
    d.update(kw)
    return ComponentSpec("c", LoopNest(**d), words_in=4096, words_out=4096)


def test_determinism():
    t1 = HLSTool({"c": _spec()})
    t2 = HLSTool({"c": _spec()})
    a = t1.synthesize("c", unrolls=8, ports=4)
    b = t2.synthesize("c", unrolls=8, ports=4)
    assert (a.lam, a.area, a.states_per_iter) == (b.lam, b.area, b.states_per_iter)


def test_ports_reduce_latency_increase_area():
    tool = HLSTool({"c": _spec()}, noise=0.0)
    s1 = tool.synthesize("c", unrolls=8, ports=1)
    s8 = tool.synthesize("c", unrolls=8, ports=8)
    assert s8.lam < s1.lam
    assert s8.area > s1.area


def test_unrolls_diminishing_returns():
    """lam(u) improvements shrink with u (the Amdahl shape behind phi)."""
    tool = HLSTool({"c": _spec()}, noise=0.0)
    lams = [tool.synthesize("c", unrolls=u, ports=4).lam
            for u in (4, 8, 16, 32)]
    gains = [a - b for a, b in zip(lams, lams[1:])]
    assert all(g >= -1e-12 for g in gains)
    assert gains[0] > gains[-1]


def test_max_states_enforced():
    tool = HLSTool({"c": _spec()}, noise=0.0)
    free = tool.synthesize("c", unrolls=16, ports=2)
    capped = tool.synthesize("c", unrolls=16, ports=2,
                             max_states=free.states_per_iter - 1)
    assert not capped.feasible
    ok = tool.synthesize("c", unrolls=16, ports=2,
                         max_states=free.states_per_iter)
    assert ok.feasible


def test_plm_dominates_area():
    """Memory is 40-90% of accelerator area (paper Section 2.1)."""
    tool = HLSTool({"c": _spec()}, noise=0.0)
    s = tool.synthesize("c", unrolls=4, ports=4)
    frac = s.detail["area_plm"] / s.area
    assert 0.4 <= frac <= 0.95


def test_memgen_banks_power_of_two():
    gen = MemGen()
    for ports in (1, 2, 3, 4, 6, 8, 16):
        plm = gen.generate(PLMSpec(words=8192, word_bits=32, ports=ports))
        assert plm.banks & (plm.banks - 1) == 0
        assert plm.banks >= -(-ports // 2)   # ceil(ports/2) dual-ported


@settings(max_examples=40, deadline=None)
@given(st.integers(256, 65536), st.sampled_from([1, 2, 4, 8, 16]))
def test_memgen_area_monotone_in_ports(words, ports):
    gen = MemGen()
    a1 = gen.generate(PLMSpec(words=words, word_bits=32, ports=ports)).area
    a2 = gen.generate(PLMSpec(words=words, word_bits=32, ports=ports * 2)).area
    assert a2 >= a1


def test_cdfg_facts_roundtrip():
    tool = HLSTool({"c": _spec()}, noise=0.0)
    lr = tool.synthesize("c", unrolls=4, ports=4)
    facts = tool.cdfg_facts("c", lr)
    assert facts.gamma_r == 4 and facts.gamma_w == 2
    # Eq. 1 must be an upper bound at the lower-right point itself
    assert facts.h(lr.unrolls, lr.ports) >= lr.states_per_iter
