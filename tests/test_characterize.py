"""Algorithm 1 (component characterization) + the lambda-constraint Eq. 1."""

import pytest

from repro.core import (CDFGFacts, CountingTool, KnobSpace,
                        characterize_component)
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest


def test_eq1_paper_example_1():
    """Fig. 6: gamma_r=1, gamma_w=1, eta=1, ports=2:
    h_2(2) = 3 and h_2(3) = 4."""
    facts = CDFGFacts(gamma_r=1, gamma_w=1, eta=1, trip=100)
    assert facts.h(2, 2) == 3
    assert facts.h(3, 2) == 4


def _tool(noise=0.0):
    spec = ComponentSpec(
        "c", LoopNest(trip=4096, gamma_r=4, gamma_w=2, arith_ops=12,
                      dep_depth=4, live_values=10),
        words_in=8192, words_out=4096)
    return CountingTool(HLSTool({"c": spec}, noise=noise))


def test_regions_structure():
    tool = _tool()
    res = characterize_component(
        tool, "c", KnobSpace(clock_ns=1.0, max_ports=8, max_unrolls=16))
    assert len(res.regions) >= 2
    for r in res.regions:
        # corners: upper-left is faster but larger (or degenerate)
        assert r.lam_min <= r.lam_max
        assert r.area_min <= r.area_max + 1e-12
        assert r.mu_min == max(1, r.ports)      # line 3 of Algorithm 1
        assert r.mu_max >= r.mu_min
    # ports are powers of two, increasing
    ports = [r.ports for r in res.regions]
    assert ports == sorted(ports)
    assert all(p & (p - 1) == 0 for p in ports)


def test_more_ports_faster_regions():
    """Each kept region's fast corner must improve on the previous
    (pruning drops port counts with no latency gain, Section 7.2)."""
    tool = _tool()
    res = characterize_component(
        tool, "c", KnobSpace(clock_ns=1.0, max_ports=16, max_unrolls=32))
    lam_mins = [r.lam_min for r in res.regions]
    assert all(a > b for a, b in zip(lam_mins, lam_mins[1:]))


def test_failed_is_a_per_run_delta_on_a_prewarmed_ledger():
    """Regression: ``CharacterizationResult.failed`` must be the run's
    own delta, like ``invocations`` — re-characterizing on a warm ledger
    (restored cache, repeated exploration) used to report the ledger's
    cumulative failure count against zero new invocations."""
    tool = _tool(noise=2.0)
    space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=24)
    first = characterize_component(tool, "c", space)
    assert first.failed > 0                   # the space has discards
    assert first.failed == tool.failed.get("c", 0)
    second = characterize_component(tool, "c", space)
    # warm ledger: every request is a cache hit — nothing was invoked,
    # so nothing newly failed
    assert second.invocations == 0
    assert second.failed == 0
    assert repr(second.regions) == repr(first.regions)


def test_lambda_constraint_discards_count_as_invocations():
    tool = _tool(noise=2.0)      # aggressive heuristic noise
    res = characterize_component(
        tool, "c", KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=24))
    # failed syntheses are counted (Fig. 11 includes them)
    assert res.invocations >= 2 * len(res.regions)
    assert res.failed == tool.failed.get("c", 0)


def test_invocation_cache():
    """Same knobs are never synthesized twice (Section 7.3)."""
    tool = _tool()
    space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
    characterize_component(tool, "c", space)
    n1 = tool.total("c")
    characterize_component(tool, "c", space)   # all cache hits
    assert tool.total("c") == n1


def test_spans_grow_with_memory_codesign():
    """Ports in the DSE (COSMOS) vs dual-port only (No Memory): Table 1's
    headline — the co-design spans dominate."""
    tool1, tool2 = _tool(), _tool()
    full = characterize_component(
        tool1, "c", KnobSpace(clock_ns=1.0, max_ports=16, max_unrolls=32))
    dual = characterize_component(
        tool2, "c", KnobSpace(clock_ns=1.0, min_ports=2, max_ports=2,
                              max_unrolls=32))
    assert full.lam_span > dual.lam_span
    assert full.area_span > dual.area_span
