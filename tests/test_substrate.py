"""Substrate: optimizer, data, checkpoint, compression, fault tolerance."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step, list_steps,
                              restore, save)
from repro.data import DataPipeline, SyntheticLM
from repro.dist import dequantize_blockwise, ef_compress, quantize_blockwise
from repro.ft import StragglerDetector, Watchdog, largest_pow2_leq, replan
from repro.optim import (AdamWConfig, apply_updates, clip_by_global_norm,
                         global_norm, init_opt, warmup_cosine)


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "scale": jnp.array([2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0)
    state = init_opt(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2)
                     + jnp.sum((p["scale"] - 1.0) ** 2))(params)
        params, state, m = apply_updates(cfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(jnp.abs(params["scale"] - 1.0).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == pytest.approx(0.0)
    assert float(warmup_cosine(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, warmup=10, total=100)) == pytest.approx(0.1)


# ----------------------------------------------------------------------
# data
# ----------------------------------------------------------------------
def test_synthetic_deterministic_and_seekable():
    src = SyntheticLM(vocab=1000, seed=3)
    b1 = src.batch(step=7, shard=0, n_shards=2, batch=4, seq=16)
    b2 = src.batch(step=7, shard=0, n_shards=2, batch=4, seq=16)
    b3 = src.batch(step=8, shard=0, n_shards=2, batch=4, seq=16)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards differ
    b4 = src.batch(step=7, shard=1, n_shards=2, batch=4, seq=16)
    assert not np.array_equal(b1["tokens"], b4["tokens"])
    # targets are next tokens
    assert np.array_equal(b1["targets"][:, :-1], b1["tokens"][:, 1:])


def test_synthetic_has_structure():
    """Markov structure => repeated bigrams far above uniform chance."""
    src = SyntheticLM(vocab=50000, seed=0)
    b = src.batch(step=0, shard=0, n_shards=1, batch=8, seq=512)
    toks = b["tokens"]
    bigrams = set()
    repeats = 0
    for row in toks:
        for a, c in zip(row[:-1], row[1:]):
            if (a, c) in bigrams:
                repeats += 1
            bigrams.add((a, c))
    assert repeats > 10      # uniform 50k^2 space would give ~0


def test_pipeline_prefetch_and_restart():
    src = SyntheticLM(vocab=100, seed=1)
    pipe = DataPipeline(src, global_batch=4, seq=8, prefetch=2)
    first = [next(pipe)["tokens"] for _ in range(3)]
    pipe.close()
    pipe2 = DataPipeline(src, global_batch=4, seq=8, start_step=0)
    again = [next(pipe2)["tokens"] for _ in range(3)]
    pipe2.close()
    for a, b in zip(first, again):
        assert np.array_equal(a, b)


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def _tree():
    return {"layer": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                      "b": np.zeros(3, np.float32)},
            "step_count": np.int32(5)}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save(d, 10, _tree(), extra={"data_step": 10})
        out, extra = restore(d, 10, _tree())
        assert extra == {"data_step": 10}
        np.testing.assert_array_equal(out["layer"]["w"], _tree()["layer"]["w"])


def test_checkpoint_atomicity_ignores_torn_tmp():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, _tree())
        # simulate a crash mid-write of step 2
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert latest_step(d) == 1
        assert list_steps(d) == [1]


def test_checkpoint_latest_pointer_fallback():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, _tree())
        save(d, 2, _tree())
        os.remove(os.path.join(d, "LATEST"))
        assert latest_step(d) == 2


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, _tree())
        bad = _tree()
        bad["layer"]["w"] = np.zeros((2, 2), np.float32)
        with pytest.raises(ValueError):
            restore(d, 1, bad)


def test_async_checkpointer_gc():
    with tempfile.TemporaryDirectory() as d:
        with AsyncCheckpointer(d, keep_last=2) as ck:
            for s in (1, 2, 3, 4):
                ck.save_async(s, _tree())
        assert list_steps(d) == [3, 4]


# ----------------------------------------------------------------------
# compression
# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2000), st.integers(0, 5))
def test_quantize_roundtrip_bounded(n, seed):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    q, s = quantize_blockwise(jnp.asarray(x))
    y = np.asarray(dequantize_blockwise(q, s, (n,)))
    blk_max = np.abs(x).max() if n else 0.0
    assert np.abs(x - y).max() <= blk_max / 127 * 1.01 + 1e-9


def test_error_feedback_identity():
    g = jax.random.normal(jax.random.PRNGKey(0), (513,))
    gh, err = ef_compress(g)
    assert float(jnp.abs((gh + err) - g).max()) < 1e-6


def test_error_feedback_converges():
    """EF compression preserves the long-run gradient sum."""
    gs = [jax.random.normal(jax.random.PRNGKey(i), (256,)) * 0.1
          for i in range(50)]
    err = jnp.zeros(256)
    total_hat = jnp.zeros(256)
    for g in gs:
        gh, err = ef_compress(g, err)
        total_hat += gh
    total = sum(gs)
    assert float(jnp.abs(total_hat + err - total).max()) < 1e-4


# ----------------------------------------------------------------------
# fault tolerance
# ----------------------------------------------------------------------
def test_straggler_detector():
    det = StragglerDetector(4, patience=2)
    for _ in range(4):
        rep = det.update([1.0, 1.0, 1.0, 3.0])
    assert rep.flagged == [3]
    det2 = StragglerDetector(4, patience=2)
    rep = det2.update([1.0, 1.0, 1.0, 3.0])   # one strike only
    assert rep.flagged == []


def test_watchdog_fires_and_recovers():
    events = []
    wd = Watchdog(timeout_s=0.15, poll_s=0.02,
                  on_stall=lambda step, gap: events.append(step))
    wd.beat(1)
    time.sleep(0.4)
    assert wd.stalled and events == [1]
    wd.beat(2)
    assert not wd.stalled
    wd.close()


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 512))
def test_elastic_plan_properties(surviving):
    plan = replan((2, 16, 16), ("pod", "data", "model"), surviving)
    used = 1
    for s in plan.new_shape:
        used *= s
    assert used <= surviving
    assert used == largest_pow2_leq(surviving)
    assert all(s >= 1 for s in plan.new_shape)


def test_elastic_keeps_tp_when_possible():
    plan = replan((16, 16), ("data", "model"), 255)
    assert plan.new_shape == (8, 16)
    assert not plan.needs_resharding
    plan2 = replan((16, 16), ("data", "model"), 8)
    assert plan2.needs_resharding
