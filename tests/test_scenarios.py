"""The registry-driven scenario sweep (benchmarks/scenarios.py + run.py):

  (a) every registered app x backend pair appears in the enumerated
      matrix exactly once per supporting bench (and at least once
      overall — the kernels bench spans the full wildcard product);
  (b) cells that cannot run carry a non-empty skip reason, and the
      registry's capability introspection explains *why*;
  (c) ``--list`` is deterministic and byte-stable across two runs, and
      unknown ``--only``/``--cell`` names exit non-zero listing what IS
      registered;
  (d) the fig10 cells (analytical, and the share-plm variant that
      replaced the old ``--share-plm`` global flag) stay byte-identical
      to their PR-4 flat artifacts under ``artifacts/bench/``.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks import scenarios as S               # noqa: E402
from benchmarks.scenarios import Cell               # noqa: E402


def _cli(*argv, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *argv],
                          cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=timeout)


# ----------------------------------------------------------------------
# (a) the matrix covers every registered pair, exactly once per bench
# ----------------------------------------------------------------------
def test_every_registered_pair_once_per_supporting_bench():
    from repro.core.registry import list_apps, list_backends
    cells = S.enumerate_matrix()
    mods = S.bench_modules()
    app_names = [a.name for a in list_apps()]
    backend_names = [b.name for b in list_backends()]
    for bench, mod in mods.items():
        spec = mod.SCENARIOS
        if "pairs" in spec:
            continue
        apps = app_names if spec["apps"] == "*" else list(spec["apps"])
        bks = (backend_names if spec["backends"] == "*"
               else list(spec["backends"]))
        for a in apps:
            for b in bks:
                hits = [sc for sc in cells
                        if sc.cell == Cell(bench, a, b, "")]
                assert len(hits) == 1, (bench, a, b, hits)
    # the kernels bench is the full wildcard product, so every
    # registered pair is enumerated at least once overall
    for a in app_names:
        for b in backend_names:
            assert any(sc.cell.app == a and sc.cell.backend == b
                       for sc in cells), (a, b)


def test_matrix_enumeration_is_deterministic_in_process():
    first = S.enumerate_matrix()
    second = S.enumerate_matrix()
    assert first == second
    ids = [sc.cell.id for sc in first]
    assert len(ids) == len(set(ids)), "duplicate cell ids"


# ----------------------------------------------------------------------
# (b) unsupported cells carry a reason; the registry explains why
# ----------------------------------------------------------------------
def _toy_app():
    from repro.core.hlsim import HLSTool
    from repro.core.knobs import KnobSpace
    from repro.core.registry import App
    from repro.core.tmg import pipeline_tmg
    return App(
        name="toy-scenarios-test",
        description="two-stage toy without a measured surface",
        tmg=lambda: pipeline_tmg(["a", "b"]),
        knob_spaces=lambda **_: {n: KnobSpace(clock_ns=1.0, max_ports=2,
                                              max_unrolls=4)
                                 for n in ("a", "b")},
        analytical=lambda: HLSTool({}),
    )


def test_unsupported_cells_carry_skip_reason():
    from repro.core.registry import _APPS, get_backend, register_app
    toy = _toy_app()
    try:
        register_app(toy)
        cells = S.enumerate_matrix()
        toy_cells = [sc for sc in cells
                     if sc.cell.app == "toy-scenarios-test"]
        # the wildcard kernels bench must enumerate the new app...
        assert {sc.cell.bench for sc in toy_cells} >= {"kernels"}
        # ...and every cell it cannot run is skipped WITH a reason
        for sc in toy_cells:
            assert not sc.runnable, sc
            assert sc.skip_reason and sc.skip_reason.strip(), sc
        # registry-level introspection: pallas explains itself
        reason = get_backend("pallas").skip_reason(toy)
        assert reason and "kernel specs" in reason
        assert get_backend("analytical").skip_reason(toy) is None
    finally:
        _APPS.pop("toy-scenarios-test", None)


def test_pallas_explains_missing_recording():
    from repro.core.registry import get_backend
    import dataclasses
    toy = dataclasses.replace(
        _toy_app(), kernel_specs=lambda tile: {},
        measurement_path=lambda t: os.path.join(REPO, "artifacts",
                                                "measurements",
                                                f"nonexistent_{t}.json"),
        recorded_tiles=(32,), record_hint="re-record with `toy --record`")
    reason = get_backend("pallas").skip_reason(toy)
    assert reason and "no recording on disk" in reason
    assert "toy --record" in reason          # the re-record command


def test_every_skip_in_the_real_matrix_is_explained():
    for sc in S.enumerate_matrix():
        if not sc.runnable:
            assert sc.skip_reason and sc.skip_reason.strip(), sc


def test_backend_describe_carries_capability_block():
    from repro.core.registry import get_backend, list_apps
    doc = get_backend("pallas").describe(list_apps())
    assert doc["measured"] is True
    assert doc["apps"]["wami"]["supported"] is True
    assert 128 in doc["apps"]["wami"]["tiles"]
    wami = [a for a in list_apps() if a.name == "wami"][0].describe()
    assert wami["measured"] and wami["plm_planner"]
    keys = {(r["tile"], r["device_kind"]) for r in wami["recordings"]}
    assert (128, "interpret") in keys


# ----------------------------------------------------------------------
# (c) --list is byte-stable; unknown names error out loudly
# ----------------------------------------------------------------------
def test_list_is_deterministic_and_byte_stable():
    r1 = _cli("--list")
    r2 = _cli("--list")
    assert r1.returncode == 0, r1.stderr
    assert r1.stdout == r2.stdout
    lines = r1.stdout.splitlines()
    assert lines[0] == "cell,status,reason"
    assert any(line.startswith("fig10/wami-pallas-share_plm,")
               for line in lines)
    assert lines[-1].endswith("0 unexplained")


def test_unknown_names_exit_nonzero_and_list_valid():
    r = _cli("--only", "nonesuch")
    assert r.returncode != 0
    assert "nonesuch" in r.stderr and "fig10" in r.stderr
    r = _cli("--cell", "bogus/none-such")
    assert r.returncode != 0
    assert "fig4/wami-analytical" in r.stderr
    r = _cli("--backend", "verilog")
    assert r.returncode != 0
    assert "analytical" in r.stderr and "pallas" in r.stderr


def test_runner_writes_cell_artifact_and_matrix_json(tmp_path):
    from benchmarks import run as harness
    rc = harness.main(["--cell", "autoshard/zoo-analytical",
                       "--out-dir", str(tmp_path)])
    assert rc == 0
    assert (tmp_path / "autoshard" / "zoo-analytical.csv").exists()
    doc = json.loads((tmp_path / "matrix.json").read_text())
    by_id = {c["id"]: c for c in doc["cells"]}
    ran = by_id["autoshard/zoo-analytical"]
    assert ran["status"] == "run"
    assert ran["artifact"] == os.path.join("autoshard",
                                           "zoo-analytical.csv")
    assert ran["summary"]                       # the stdout csv rows
    others = [c for c in doc["cells"] if c["id"] != ran["id"]]
    assert others and all(c["status"] == "filtered" for c in others)


def test_list_honours_filters(capsys):
    from benchmarks import run as harness
    rc = harness.main(["--list", "--only", "fig10"])
    assert rc == 0
    lines = capsys.readouterr().out.splitlines()
    body = [ln for ln in lines[1:] if not ln.startswith("#")]
    assert body and all(ln.startswith("fig10/") for ln in body)


def test_explicitly_requested_unrunnable_cell_fails(tmp_path):
    from benchmarks import run as harness
    from repro.core.registry import _APPS, register_app
    toy = _toy_app()
    try:
        register_app(toy)
        # the wildcard kernels bench enumerates the toy app; naming its
        # (skipped) cell explicitly must exit non-zero, not silently 0
        rc = harness.main(["--cell",
                           "kernels/toy-scenarios-test-analytical",
                           "--out-dir", str(tmp_path)])
        assert rc != 0
    finally:
        _APPS.pop("toy-scenarios-test", None)


def test_matrix_md_is_fresh():
    """docs/matrix.md must match a regeneration from the live registry
    (the CI scenario-matrix job enforces the same on every PR)."""
    want = S.render_matrix_md()
    with open(os.path.join(REPO, "docs", "matrix.md")) as f:
        got = f.read()
    assert got == want, ("docs/matrix.md is stale — regenerate with "
                         "`python -m benchmarks.run --emit-docs`")


# ----------------------------------------------------------------------
# (d) fig10 cells == the PR-4 flag-path outputs, byte for byte
# ----------------------------------------------------------------------
def test_fig10_share_plm_cell_matches_pr4_flag_path(bench_cell_lines,
                                                    committed_artifact):
    # fig10_pareto_pallas_share_plm.csv is the committed output of the
    # old `--share-plm` global-flag path (PR 3/4 era) — the variant
    # cell that replaced the flag must reproduce it byte for byte
    from benchmarks import fig10_pareto
    got = bench_cell_lines(fig10_pareto,
                           Cell("fig10", "wami", "pallas", "share_plm"))
    assert got == committed_artifact("fig10_pareto_pallas_share_plm.csv")


def test_fig10_analytical_cell_matches_committed_reference(
        bench_cell_lines, committed_artifact):
    from benchmarks import fig10_pareto
    got = bench_cell_lines(fig10_pareto, Cell("fig10", "wami", "analytical"))
    assert got == committed_artifact("fig10", "wami-analytical.csv")


@pytest.mark.slow
def test_fig10_analytical_share_plm_cell_matches_pr4_flag_path(
        bench_cell_lines, committed_artifact):
    from benchmarks import fig10_pareto
    got = bench_cell_lines(fig10_pareto,
                           Cell("fig10", "wami", "analytical", "share_plm"))
    assert got == committed_artifact("fig10_pareto_share_plm.csv")
