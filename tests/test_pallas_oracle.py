"""PallasOracle semantics: feasibility, accounting, record/replay
determinism, fallback routing, and calibration."""

import math

import pytest

from repro.apps.wami.pallas import (default_measurement_path,
                                    wami_measurement_set,
                                    wami_pallas_components,
                                    wami_pallas_oracle, wami_pallas_session,
                                    wami_plm_session)
from repro.core import (CalibratedTool, InvocationRequest, KnobSpace,
                        MeasurementSet, MeasurementStore,
                        MissingMeasurementError, OracleLedger, PallasOracle,
                        Synthesis, cosmos_dse, fit_latency_scales)
from repro.core.tmg import pipeline_tmg


def _fake_timer(name, ports, unrolls, runner):
    """Deterministic stand-in for the wall clock: Amdahl-ish in the
    unrolls, sub-linear benefit in ports, component-dependent offset."""
    return (1e-3 * (32 / unrolls) + 2e-4 * ports ** 0.5
            + 1e-5 * len(name))


def _small():
    comps = wami_pallas_components(tile=32)
    sub = {n: comps[n] for n in ("grayscale", "gradient")}
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
              for n in sub}
    return sub, spaces


# ----------------------------------------------------------------------
# feasibility + accounting
# ----------------------------------------------------------------------
def test_non_divisible_knobs_are_infeasible_and_counted():
    sub, _ = _small()
    ledger = OracleLedger(PallasOracle(sub, timer=_fake_timer))
    s = ledger.synthesize("gradient", unrolls=5, ports=2)   # 32 % 5 != 0
    assert not s.feasible and math.isinf(s.lam)
    assert ledger.invocations["gradient"] == 1              # Fig. 11 counts it
    assert ledger.failed["gradient"] == 1
    ok = ledger.synthesize("gradient", unrolls=4, ports=2)
    assert ok.feasible and ok.lam > 0 and ok.area > 0


def test_vmem_budget_is_the_lambda_constraint():
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer, vmem_budget=1024)
    s = oracle.synthesize("gradient", unrolls=8, ports=1)
    assert not s.feasible


def test_max_states_cap_discards():
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer)
    s = oracle.synthesize("gradient", unrolls=8, ports=1, max_states=1)
    assert not s.feasible and s.states_per_iter > 1


def test_unknown_component_requires_fallback():
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer)
    with pytest.raises(KeyError):
        oracle.synthesize("matrix_mul", unrolls=2, ports=1)


def test_ports_parallelism_and_area_economics():
    """More banks: lower per-bank latency, higher VMEM area (DESIGN.md §2)."""
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer)
    s1 = oracle.synthesize("gradient", unrolls=4, ports=1)
    s4 = oracle.synthesize("gradient", unrolls=4, ports=4)
    assert s4.lam < s1.lam
    assert s4.area > s1.area


# ----------------------------------------------------------------------
# record / replay
# ----------------------------------------------------------------------
def _front(res):
    return [(p.perf, p.cost) for p in res.pareto()]


def test_replay_is_byte_identical_to_fresh_record(tmp_path):
    sub, spaces = _small()
    tmg = pipeline_tmg(list(sub))
    path = str(tmp_path / "m.json")

    fresh = PallasOracle(sub, mode="record",
                         store=MeasurementStore(path), timer=_fake_timer)
    r1 = cosmos_dse(tmg, fresh, spaces, delta=0.3)
    assert fresh.flush() == path

    replay = PallasOracle(sub, mode="replay",
                          store=MeasurementStore.load(path))
    r2 = cosmos_dse(tmg, replay, spaces, delta=0.3, workers=8)

    assert _front(r1) == _front(r2)
    assert r1.invocations == r2.invocations
    assert [(m.theta_actual, m.cost_actual) for m in r1.mapped] \
        == [(m.theta_actual, m.cost_actual) for m in r2.mapped]


def test_record_resumes_without_retiming_paid_points(tmp_path):
    """A killed recording campaign (autoflushed, never flush()ed) must
    resume from the flushed file and never re-time a paid point."""
    sub, _ = _small()
    path = str(tmp_path / "m.json")
    calls = []

    def counting_timer(name, ports, unrolls, runner):
        calls.append((name, ports, unrolls))
        return _fake_timer(name, ports, unrolls, runner)

    first = PallasOracle(sub, mode="record",
                         store=MeasurementStore(path, flush_every=1),
                         timer=counting_timer)
    first.synthesize("gradient", unrolls=4, ports=2)
    first.synthesize("gradient", unrolls=8, ports=2)
    first.synthesize("grayscale", unrolls=4, ports=1)
    assert len(calls) == 3
    # simulated kill: no flush() — the autoflush already persisted all 3
    resumed_store = MeasurementStore.load(path, flush_every=1)
    assert len(resumed_store) == 3

    second = PallasOracle(sub, mode="record", store=resumed_store,
                          timer=counting_timer)
    s = second.synthesize("gradient", unrolls=4, ports=2)    # paid already
    assert s.feasible and len(calls) == 3                    # not re-timed
    second.synthesize("gradient", unrolls=16, ports=2)       # new point
    assert len(calls) == 4
    assert len(MeasurementStore.load(path)) == 4             # autoflushed


def test_autoflush_batches_by_flush_every(tmp_path):
    import os
    path = str(tmp_path / "m.json")
    store = MeasurementStore(path, flush_every=3)
    store.put(("a", 1, 1), 1.0)
    store.put(("a", 1, 2), 1.0)
    assert not os.path.exists(path)          # below the batch threshold
    store.put(("a", 1, 3), 1.0)
    assert os.path.exists(path)              # third put flushed atomically
    assert len(MeasurementStore.load(path)) == 3


def test_store_roundtrip_and_missing_measurement(tmp_path):
    path = str(tmp_path / "m.json")
    store = MeasurementStore(path, meta={"tile": 32})
    store.put(("gradient", 2, 4), 1.5e-3)
    store.save()
    loaded = MeasurementStore.load(path)
    assert loaded.get(("gradient", 2, 4)) == pytest.approx(1.5e-3)
    assert loaded.meta == {"tile": 32}

    sub, _ = _small()
    replay = PallasOracle(sub, mode="replay", store=loaded)
    s = replay.synthesize("gradient", unrolls=4, ports=2)
    assert s.feasible and s.detail["wall_s"] == pytest.approx(1.5e-3)
    with pytest.raises(MissingMeasurementError):
        replay.synthesize("gradient", unrolls=8, ports=1)


def test_checked_in_recording_drives_wami_end_to_end():
    """Acceptance: cosmos_dse over the full WAMI TMG from the committed
    recording — deterministic, no TPU, fallback prices the 6x6 stages."""
    import os
    assert os.path.exists(default_measurement_path())
    res1 = wami_pallas_session(0.25, workers=4).run()
    res2 = wami_pallas_session(0.25, workers=4).run()
    assert len(res1.characterizations) == 12
    assert len(res1.mapped) >= 5
    assert res1.theta_max > res1.theta_min > 0
    assert _front(res1) == _front(res2)
    assert res1.invocations == res2.invocations


# ----------------------------------------------------------------------
# tile routing + replay-miss policy
# ----------------------------------------------------------------------
def test_non_native_tile_routes_to_fallback():
    from repro.apps.wami.pipeline import wami_hls_tool
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer, native_tile=32,
                          fallback=wami_hls_tool(tile=32))
    native = oracle.synthesize("gradient", unrolls=4, ports=2, tile=32)
    assert native.detail.get("wall_s") is not None       # measured path
    other = oracle.synthesize("gradient", unrolls=4, ports=2, tile=64)
    assert other.feasible and "wall_s" not in other.detail
    assert other.tile == 64


def test_tile_request_without_native_tile_is_an_error():
    """An oracle with no declared native_tile cannot price a tile axis
    — doing so would relabel one tile's measurements as another's."""
    sub, _ = _small()
    oracle = PallasOracle(sub, timer=_fake_timer)
    with pytest.raises(ValueError, match="native_tile"):
        oracle.synthesize("gradient", unrolls=4, ports=2, tile=64)


def test_fallback_priced_native_point_reports_fallback_requirement(tmp_path):
    """missing='fallback' points carry no wall_s; their PLM requirement
    must come from the fallback's logic/PLM split, not be misread as an
    all-memory measured footprint."""
    from repro.apps.wami.pipeline import wami_hls_tool
    sub, _ = _small()
    path = str(tmp_path / "m.json")
    store = MeasurementStore(path)
    store.put(("gradient", 2, 4), 1.5e-3)
    store.save()
    lax = PallasOracle(sub, mode="replay",
                       store=MeasurementStore.load(path),
                       fallback=wami_hls_tool(tile=32), missing="fallback")
    measured = lax.synthesize("gradient", unrolls=4, ports=2)
    req_m = lax.plm_requirement("gradient", measured)
    assert req_m.unit == "bytes" and req_m.area_logic == 0.0
    modelled = lax.synthesize("gradient", unrolls=8, ports=2)
    req_f = lax.plm_requirement("gradient", modelled)
    assert req_f.unit == "mm2" and req_f.area_logic > 0.0
    assert req_f.area_plm == pytest.approx(modelled.detail["area_plm"])


def test_replay_missing_fallback_policy(tmp_path):
    from repro.apps.wami.pipeline import wami_hls_tool
    sub, _ = _small()
    path = str(tmp_path / "m.json")
    store = MeasurementStore(path)
    store.put(("gradient", 2, 4), 1.5e-3)
    store.save()
    strict = PallasOracle(sub, mode="replay",
                          store=MeasurementStore.load(path))
    with pytest.raises(MissingMeasurementError):
        strict.synthesize("gradient", unrolls=8, ports=2)
    lax = PallasOracle(sub, mode="replay",
                       store=MeasurementStore.load(path),
                       fallback=wami_hls_tool(tile=32), missing="fallback")
    hit = lax.synthesize("gradient", unrolls=4, ports=2)
    assert hit.detail["wall_s"] == pytest.approx(1.5e-3)  # recorded point
    miss = lax.synthesize("gradient", unrolls=8, ports=2)
    assert miss.feasible and "wall_s" not in miss.detail  # fallback-priced
    with pytest.raises(ValueError):
        PallasOracle(sub, mode="replay", store=store, missing="fallback")


# ----------------------------------------------------------------------
# MeasurementSet: multi-recording routing
# ----------------------------------------------------------------------
def _store_with(tmp_path, name, tile, entries):
    store = MeasurementStore(str(tmp_path / name),
                             meta={"tile": tile, "interpret": True})
    for key, wall in entries.items():
        store.put(key, wall)
    store.save()
    return store


def test_measurement_set_native_hit_and_multi_tile_routing(tmp_path):
    """Recorded tiles replay measured walls; unrecorded tiles fall
    through to the fallback tool."""
    from repro.apps.wami.pipeline import wami_hls_tool
    s32 = _store_with(tmp_path, "t32.json", 32,
                      {("gradient", 2, 4): 1.0e-3})
    s64 = _store_with(tmp_path, "t64.json", 64,
                      {("gradient", 2, 4): 3.0e-3})
    ms = MeasurementSet()
    ms.add(s32)
    ms.add(s64)
    assert ms.keys() == [(32, "interpret"), (64, "interpret")]
    oracle = PallasOracle(wami_pallas_components(32), mode="replay",
                          measurements=ms,
                          components_factory=wami_pallas_components,
                          fallback=wami_hls_tool(tile=32),
                          native_tile=32, missing="fallback")
    native = oracle.synthesize("gradient", unrolls=4, ports=2)
    assert native.detail["wall_s"] == pytest.approx(1.0e-3)
    t64 = oracle.synthesize("gradient", unrolls=4, ports=2, tile=64)
    assert t64.detail["wall_s"] == pytest.approx(3.0e-3)
    assert t64.tile == 64
    # measured tiles see tile geometry: same knobs, 2x edge => 4x blocks
    assert t64.area > native.area
    t128 = oracle.synthesize("gradient", unrolls=4, ports=2, tile=128)
    assert t128.feasible and "wall_s" not in t128.detail    # fallback
    # facts for a measured non-native tile come from that tile's specs
    assert oracle.cdfg_facts("gradient", t64).trip == 64


def test_measurement_set_missing_error_names_key_and_lists_available(
        tmp_path):
    s32 = _store_with(tmp_path, "t32.json", 32,
                      {("gradient", 2, 4): 1.0e-3})
    oracle = PallasOracle(wami_pallas_components(32), mode="replay",
                          measurements=MeasurementSet().add(s32),
                          native_tile=32, missing="error")
    with pytest.raises(MissingMeasurementError) as exc:
        oracle.synthesize("gradient", unrolls=8, ports=2)
    msg = str(exc.value)
    assert "(tile=32, device='interpret')" in msg      # the missing key
    assert "recorded keys" in msg                      # ...and what exists


def test_recorded_tile_resolves_without_native_tile_declared(tmp_path):
    """The old single-store design raised ValueError for an explicit
    tile even when that tile WAS the recording's — the MeasurementSet
    shim must resolve it instead."""
    store = _store_with(tmp_path, "t32.json", 32,
                        {("gradient", 2, 4): 1.0e-3})
    with pytest.warns(DeprecationWarning):
        oracle = PallasOracle(wami_pallas_components(32), mode="replay",
                              store=MeasurementStore.load(store.path))
    hit = oracle.synthesize("gradient", unrolls=4, ports=2, tile=32)
    assert hit.feasible and hit.detail["wall_s"] == pytest.approx(1.0e-3)
    native = oracle.synthesize("gradient", unrolls=4, ports=2)
    assert native.detail["wall_s"] == pytest.approx(1.0e-3)
    # a genuinely unrecorded tile still errors, naming the missing key
    with pytest.raises((ValueError, MissingMeasurementError),
                       match="tile=64"):
        oracle.synthesize("gradient", unrolls=4, ports=2, tile=64)


def test_legacy_store_shim_warns_and_preserves_cache_keys(tmp_path):
    """PallasOracle(store=...) deprecates but stays byte-compatible:
    same results, same OracleLedger cache keys as measurements=."""
    store = _store_with(tmp_path, "t32.json", 32,
                        {("gradient", 2, 4): 1.0e-3,
                         ("grayscale", 1, 4): 2.0e-3})
    with pytest.warns(DeprecationWarning, match="legacy single-recording"):
        legacy = PallasOracle(wami_pallas_components(32), mode="replay",
                              store=MeasurementStore.load(store.path),
                              native_tile=32)
    modern = PallasOracle(wami_pallas_components(32), mode="replay",
                          measurements=MeasurementSet.from_store(
                              MeasurementStore.load(store.path), tile=32),
                          native_tile=32)
    requests = [InvocationRequest("gradient", unrolls=4, ports=2),
                InvocationRequest("grayscale", unrolls=4, ports=1),
                InvocationRequest("gradient", unrolls=4, ports=2, tile=32)]
    led_a, led_b = OracleLedger(legacy), OracleLedger(modern)
    out_a = led_a.evaluate_batch(requests)
    out_b = led_b.evaluate_batch(requests)
    assert [(s.lam, s.area, s.tile) for s in out_a] \
        == [(s.lam, s.area, s.tile) for s in out_b]
    keys_a = sorted((r.component, r.unrolls, r.ports, r.tile)
                    for r in led_a.records)
    assert keys_a == sorted((r.component, r.unrolls, r.ports, r.tile)
                            for r in led_b.records)
    assert led_a.invocations == led_b.invocations


def test_checked_in_multi_tile_recordings_route_measured_vs_fallback():
    """The REAL recorded artifacts (tile 64 + 128): a multi-tile session
    oracle replays measured walls at both tiles and falls back only on
    genuinely unrecorded tiles — the ROADMAP multi-tile item, exercised
    against the committed recordings rather than mocks."""
    import os
    for tile in (64, 128):
        assert os.path.exists(default_measurement_path(tile))
    from repro.apps.wami.pallas import wami_unit_system
    from repro.apps.wami.pipeline import wami_hls_tool
    ms = wami_measurement_set((64, 128))
    assert ms.tiles("interpret") == (64, 128)
    oracle = PallasOracle(
        wami_pallas_components(128), mode="replay", measurements=ms,
        components_factory=wami_pallas_components,
        fallback=wami_unit_system().calibrated(wami_hls_tool()),
        native_tile=128, missing="fallback")
    s128 = oracle.synthesize("gradient", unrolls=1, ports=1, tile=128)
    s64 = oracle.synthesize("gradient", unrolls=1, ports=1, tile=64)
    s256 = oracle.synthesize("gradient", unrolls=1, ports=1, tile=256)
    assert "wall_s" in s128.detail and "wall_s" in s64.detail
    assert s256.feasible and "wall_s" not in s256.detail
    # distinct recordings, distinct walls
    assert s64.detail["wall_s"] != s128.detail["wall_s"]


def test_plm_session_with_measured_tiles_replays_tile64(tmp_path):
    """wami_plm_session(measured_tiles=(64, 128)) drives the tile axis
    measured-vs-fallback end to end and stays deterministic."""
    res = wami_plm_session(0.25, measured_tiles=(64, 128), workers=4).run()
    measured_t64 = [
        o for m in res.mapped for o in m.outcomes
        if o.synthesis.tile == 64 and "wall_s" in (o.synthesis.detail or {})]
    assert measured_t64, "no mapped tile-64 point replayed a measured wall"
    # the default (single-recording) drive prices ALL tile-64 points
    # through the fallback — the recordings genuinely change the drive
    base = wami_plm_session(0.25, workers=4).run()
    assert not [o for m in base.mapped for o in m.outcomes
                if o.synthesis.tile == 64
                and "wall_s" in (o.synthesis.detail or {})]


# ----------------------------------------------------------------------
# calibration
# ----------------------------------------------------------------------
class _StubModel:
    def synthesize(self, component, *, unrolls, ports, max_states=None):
        return Synthesis(lam=1e-3 * unrolls, area=1.0, ports=ports,
                         unrolls=unrolls)

    def cdfg_facts(self, component, synth):
        raise NotImplementedError


def test_calibration_recovers_scale():
    measured = [("k", p, u, 2.0 * 1e-3 * u)
                for p in (1, 2) for u in (2, 4, 8)]
    fit = fit_latency_scales(_StubModel(), measured)
    assert fit.scale("k") == pytest.approx(2.0)
    assert fit.lam_spread["k"] == pytest.approx(1.0)
    assert fit.scale("unseen") == 1.0

    cal = CalibratedTool(_StubModel(), fit)
    s = cal.synthesize("k", unrolls=4, ports=1)
    assert s.lam == pytest.approx(8e-3)
    assert s.area == 1.0                      # areas stay backend-local


def test_calibration_skips_bad_points():
    fit = fit_latency_scales(_StubModel(), [("k", 1, 4, float("inf")),
                                            ("k", 1, 4, -1.0),
                                            ("k", 1, 4, 4e-3)])
    assert fit.scale("k") == pytest.approx(1.0)   # only the 1x point fits
    assert fit.points["k"] == 1
