"""Eq. 2 LP synthesis planning: envelope, feasibility, optimality."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ComponentModel, PiecewiseLinearCost, plan,
                        pipeline_tmg, sweep, theta_bounds)
from repro.core.planning import _simplex


def test_convex_envelope():
    pts = [(1.0, 10.0), (2.0, 4.0), (3.0, 3.5), (4.0, 1.0), (2.5, 9.0)]
    f = PiecewiseLinearCost.from_points(pts)
    # envelope is below all points and convex
    for x, y in pts:
        assert f(x) <= y + 1e-9
    xs = np.linspace(1.0, 4.0, 50)
    ys = [f(x) for x in xs]
    # convexity: second differences non-negative
    d2 = np.diff(ys, 2)
    assert np.all(d2 >= -1e-6)


def _models():
    mk = PiecewiseLinearCost.from_points
    return {
        "a": ComponentModel("a", 1.0, 4.0, mk([(1.0, 8.0), (4.0, 2.0)])),
        "b": ComponentModel("b", 2.0, 6.0, mk([(2.0, 9.0), (6.0, 3.0)])),
        "c": ComponentModel("c", 1.0, 3.0, mk([(1.0, 5.0), (3.0, 1.0)])),
    }


def test_plan_at_theta_min_picks_cheapest():
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    models = _models()
    th_lo, th_hi = theta_bounds(tmg, models)
    pt = plan(tmg, models, th_lo)
    assert pt is not None
    # at the loosest throughput, every component sits at lam_max (cheapest)
    for n, m in models.items():
        assert pt.lam_targets[n] == pytest.approx(m.lam_max, rel=1e-6)


def test_plan_at_theta_max_feasible_and_fast():
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    models = _models()
    _, th_hi = theta_bounds(tmg, models)
    pt = plan(tmg, models, th_hi)
    assert pt is not None
    # the critical component must be at its fastest point
    assert min(pt.lam_targets.values()) >= 0


def test_planned_assignment_achieves_theta():
    """LP feasibility must imply the TMG sustains the target theta."""
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    models = _models()
    th_lo, th_hi = theta_bounds(tmg, models)
    for theta in np.linspace(th_lo, th_hi, 6):
        pt = plan(tmg, models, float(theta))
        assert pt is not None
        achieved = tmg.throughput(pt.lam_targets)
        assert achieved >= theta * (1 - 1e-6)


def test_cost_monotone_in_theta():
    """Tighter throughput targets can only cost more (LP optimality)."""
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    models = _models()
    points = sweep(tmg, models, delta=0.3)
    costs = [p.cost for p in points]
    assert all(b >= a - 1e-6 for a, b in zip(costs, costs[1:]))


def test_sweep_ratio():
    tmg = pipeline_tmg(["a", "b"], buffers=2)
    models = {k: _models()[k] for k in ("a", "b")}
    pts = sweep(tmg, models, delta=0.5)
    for p, q in zip(pts, pts[1:-1]):
        assert q.theta / p.theta == pytest.approx(1.5, rel=1e-6)


def test_simplex_fallback_matches_scipy():
    """The dependency-free simplex solves a small LP to the same optimum."""
    # min x + y st x + 2y >= 4, 3x + y >= 6, 0 <= x,y <= 10
    c = np.array([1.0, 1.0])
    A_ub = np.array([[-1.0, -2.0], [-3.0, -1.0]])
    b_ub = np.array([-4.0, -6.0])
    bounds = [(0.0, 10.0), (0.0, 10.0)]
    x = _simplex(c, A_ub, b_ub, bounds)
    assert x is not None
    from scipy.optimize import linprog
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, bounds=bounds, method="highs")
    assert c @ x == pytest.approx(ref.fun, rel=1e-6)
