"""Pallas kernels vs their jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import mha, mha_ref
from repro.kernels.ssd_scan import ssd, ssd_oracle
from repro.kernels.wami_gradient import gradient, gradient_oracle

KEY = jax.random.PRNGKey(7)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Skv,H,K,d", [
    (1, 128, 128, 4, 4, 64),       # MHA
    (2, 128, 128, 8, 2, 64),       # GQA 4:1
    (1, 256, 256, 4, 2, 32),       # small head dim
    (1, 128, 256, 4, 2, 64),       # Sq < Skv (chunked prefill)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, Sq, Skv, H, K, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, d), dtype)
    k = jax.random.normal(ks[1], (B, Skv, K, d), dtype)
    v = jax.random.normal(ks[2], (B, Skv, K, d), dtype)
    off = Skv - Sq
    o1 = mha(q, k, v, q_offset=off, use_pallas=True, interpret=True,
             block_q=64, block_kv=64)
    o2 = mha_ref(q, k, v, q_offset=off)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("window,softcap", [(64, 0.0), (0, 30.0), (32, 20.0)])
def test_flash_window_softcap(window, softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    o1 = mha(q, k, v, window=window, softcap=softcap, use_pallas=True,
             interpret=True, block_q=64, block_kv=64)
    o2 = mha_ref(q, k, v, window=window, softcap=softcap)
    assert float(jnp.abs(o1 - o2).max()) < 2e-5


def test_flash_block_size_invariance():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [mha(q, k, v, use_pallas=True, interpret=True,
                block_q=bq, block_kv=bk)
            for bq, bk in ((64, 64), (128, 128), (64, 256), (256, 64))]
    for o in outs[1:]:
        assert float(jnp.abs(o - outs[0]).max()) < 1e-5


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("Bz,S,H,P,N,chunk", [
    (2, 128, 4, 32, 64, 32),
    (1, 256, 2, 64, 128, 128),
    (2, 64, 8, 16, 32, 64),       # chunk == S (single chunk)
])
def test_ssd_matches_sequential(Bz, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bz, S, N)) * 0.3
    C = jax.random.normal(ks[4], (Bz, S, N)) * 0.3
    y1, h1 = ssd(x, dt, A, B, C, chunk=chunk, use_pallas=True, interpret=True)
    y2, h2 = ssd_oracle(x, dt, A, B, C)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
    assert float(jnp.abs(h1 - h2).max()) < 1e-4


def test_ssd_chunk_invariance():
    ks = jax.random.split(KEY, 5)
    Bz, S, H, P, N = 1, 128, 2, 16, 32
    x = jax.random.normal(ks[0], (Bz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bz, S, N)) * 0.3
    C = jax.random.normal(ks[4], (Bz, S, N)) * 0.3
    outs = [ssd(x, dt, A, B, C, chunk=c, use_pallas=True, interpret=True)[0]
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        assert float(jnp.abs(o - outs[0]).max()) < 1e-4


# ----------------------------------------------------------------------
# WAMI gradient (the COSMOS-knob kernel)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("ports", [1, 2, 4])
@pytest.mark.parametrize("unrolls", [4, 8, 16])
def test_wami_gradient_knob_sweep(ports, unrolls):
    img = jax.random.normal(KEY, (64, 128)) * 10
    gx1, gy1 = gradient(img, ports=ports, unrolls=unrolls, interpret=True)
    gx2, gy2 = gradient_oracle(img)
    assert float(jnp.abs(gx1 - gx2).max()) < 1e-6
    assert float(jnp.abs(gy1 - gy2).max()) < 1e-6


def test_wami_gradient_vmem_model():
    from repro.kernels.wami_gradient import grid_steps, vmem_bytes
    # more ports => smaller blocks, more (parallel) grid steps
    assert vmem_bytes(128, 128, ports=4, unrolls=8) \
        == vmem_bytes(128, 128, ports=1, unrolls=8) // 4
    assert grid_steps(128, 128, ports=4, unrolls=8) \
        == 4 * grid_steps(128, 128, ports=1, unrolls=8)
