"""The App/Backend registry: round-trips, unknown-name errors, and the
apps x backends support matrix (every supported pair smoke-constructs)."""

import pytest

from repro.core import (App, Backend, ExplorationSession, KnobSpace,
                        PallasOracle, build_session, build_tool, get_app,
                        get_backend, list_apps, list_backends, register_app,
                        register_backend)
from repro.core.hlsim import HLSTool
from repro.core.registry import _APPS
from repro.core.tmg import pipeline_tmg


# ----------------------------------------------------------------------
# round-trips
# ----------------------------------------------------------------------
def test_builtin_apps_resolve_by_name():
    assert get_app("wami").name == "wami"
    assert get_app("fleet").name == "fleet"
    names = [a.name for a in list_apps()]
    assert "wami" in names and "fleet" in names


def test_builtin_backends_resolve_by_name():
    analytical = get_backend("analytical")
    pallas = get_backend("pallas")
    assert not analytical.measured and pallas.measured
    assert {b.name for b in list_backends()} >= {"analytical", "pallas"}


def test_unknown_names_list_whats_registered():
    with pytest.raises(KeyError, match="wami"):
        get_app("nonesuch")
    with pytest.raises(KeyError, match="analytical"):
        get_backend("nonesuch")


def test_register_app_round_trip():
    app = App(
        name="toy-registry-test",
        description="two-stage toy",
        tmg=lambda: pipeline_tmg(["a", "b"]),
        knob_spaces=lambda **_: {n: KnobSpace(clock_ns=1.0, max_ports=2,
                                              max_unrolls=4)
                                 for n in ("a", "b")},
        analytical=lambda: HLSTool({}),
    )
    try:
        register_app(app)
        assert get_app("toy-registry-test") is app
        assert get_backend("analytical").supports(app)
        assert not get_backend("pallas").supports(app)   # no kernel specs
    finally:
        _APPS.pop("toy-registry-test", None)


# ----------------------------------------------------------------------
# capability metadata
# ----------------------------------------------------------------------
def test_wami_capability_metadata():
    wami = get_app("wami")
    pallas = get_backend("pallas")
    assert pallas.supports(wami)
    tiles = pallas.supported_tiles(wami)
    assert 128 in tiles                 # the checked-in native recording
    assert set(tiles) <= set(wami.recorded_tiles)
    cal = pallas.calibrate(wami)
    assert cal is not None and hasattr(cal, "synthesize")


def test_fleet_capability_metadata():
    fleet = get_app("fleet")
    assert get_backend("pallas").supports(fleet)
    assert get_backend("pallas").supported_tiles(fleet) == (0,)
    assert get_backend("analytical").supports(fleet)


# ----------------------------------------------------------------------
# the support matrix: every supported pair smoke-constructs
# ----------------------------------------------------------------------
def test_every_supported_pair_smoke_constructs():
    for app in list_apps():
        for backend in list_backends():
            if not backend.supports(app):
                continue
            session = build_session(app.name, backend.name)
            assert isinstance(session, ExplorationSession)
            assert set(session.spaces) == {
                t.name for t in session.tmg.transitions} - set(app.fixed)


def test_build_tool_returns_the_backend_oracle():
    assert isinstance(build_tool("wami", "pallas"), PallasOracle)
    tool = build_tool("wami", "analytical")
    assert hasattr(tool, "synthesize") and not isinstance(tool, PallasOracle)


def test_build_session_injected_tool_skips_factory():
    marker = build_tool("wami", "analytical")
    session = build_session("wami", "analytical", tool=marker)
    assert session.ledger.tool is marker


# ----------------------------------------------------------------------
# registry-resolved drives stay byte-identical to the classic wrappers
# ----------------------------------------------------------------------
def test_registry_session_matches_classic_wami_session():
    from repro.apps.wami import wami_session
    a = wami_session(delta=0.3, workers=4).run()
    b = build_session("wami", "analytical", delta=0.3, workers=4).run()
    assert [(m.theta_actual, m.cost_actual) for m in a.mapped] \
        == [(m.theta_actual, m.cost_actual) for m in b.mapped]
    assert a.invocations == b.invocations
