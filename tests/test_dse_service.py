"""Concurrency battery for the multi-tenant DSE service.

The contract under test (docs/service.md): N tenants running
concurrently through one :class:`~repro.serve.DSEService` get fronts
byte-identical to N isolated sequential runs, with per-tenant ledger
attribution identical to isolation — while the shared oracle underneath
dedups the real tool traffic (cache hits, in-flight joins, batching)
and one tenant's failure never leaks into another tenant's front or the
shared cache.
"""

import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DSEQuery, OracleLedger, SharedOracle
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest
from repro.core.knobs import KnobSpace
from repro.core.oracle import InvocationRequest, PersistentOracleCache
from repro.core.registry import (App, _APPS, build_query_session,
                                 register_app)
from repro.core.tmg import pipeline_tmg
from repro.serve import Busy, DSEService


# ----------------------------------------------------------------------
# runnable toy apps (registered per-test, deregistered by fixture —
# leaking them would change the scenario matrix other tests assert on)
# ----------------------------------------------------------------------
def _toy_specs(scale=1):
    return {
        "a": ComponentSpec("a", LoopNest(256 * scale, 2, 1, 8, 3, 6),
                           1024, 1024),
        "b": ComponentSpec("b", LoopNest(128 * scale, 1, 1, 4, 2, 4),
                           512, 512),
    }


class _BrokenTool(HLSTool):
    """Seeded failure: every price for component 'b' raises."""

    def synthesize(self, component, **kw):
        if component == "b":
            raise RuntimeError("seeded oracle failure for 'b'")
        return super().synthesize(component, **kw)


class _GatedTool(HLSTool):
    """Every price blocks until the test opens the gate — lets a test
    hold a worker busy deterministically (backpressure tests)."""

    gate = threading.Event()

    def synthesize(self, component, **kw):
        if not _GatedTool.gate.wait(timeout=30):
            raise TimeoutError("test gate never opened")
        return super().synthesize(component, **kw)


def _toy_app(name, tool_factory=None, scale=1):
    return App(
        name=name,
        description="runnable toy for the DSE-service battery",
        tmg=lambda: pipeline_tmg(["a", "b"], buffers=2),
        knob_spaces=lambda **_: {n: KnobSpace(clock_ns=1.0, max_ports=4,
                                              max_unrolls=8)
                                 for n in ("a", "b")},
        analytical=tool_factory or (lambda: HLSTool(_toy_specs(scale))),
    )


TOYS = {
    "svc-toy-a": _toy_app("svc-toy-a"),
    "svc-toy-b": _toy_app("svc-toy-b", scale=2),
    "svc-toy-broken": _toy_app("svc-toy-broken",
                               lambda: _BrokenTool(_toy_specs())),
    "svc-toy-gated": _toy_app("svc-toy-gated",
                              lambda: _GatedTool(_toy_specs())),
}


@pytest.fixture(autouse=True)
def _toy_registry():
    for app in TOYS.values():
        register_app(app)
    _GatedTool.gate.clear()
    try:
        yield
    finally:
        _GatedTool.gate.set()        # never leave a worker blocked
        for name in TOYS:
            _APPS.pop(name, None)


def _isolated(query):
    """Reference run: the query alone, its own session + ledger."""
    s = build_query_session(query)
    return s.run(), dict(s.ledger.invocations)


def _front(result):
    """The byte-comparable surface of one tenant's answer."""
    return repr(result.planned), repr(result.mapped)


# ----------------------------------------------------------------------
# (1) N concurrent tenants == N sequential isolated runs, byte-identical
# ----------------------------------------------------------------------
def test_concurrent_tenants_match_isolated_runs():
    queries = [
        DSEQuery(app="svc-toy-a", tenant="t0"),
        DSEQuery(app="svc-toy-a", delta=0.5, tenant="t1"),
        DSEQuery(app="svc-toy-b", tenant="t2"),
        DSEQuery(app="svc-toy-b", delta=0.4, tenant="t3"),
        DSEQuery(app="svc-toy-a", tenant="t4"),      # exact duplicate of t0
    ]
    iso = {q.tenant: _isolated(q) for q in queries}
    with DSEService(max_pending=8, workers=4) as svc:
        handles = svc.submit_all(queries)
        results = {h.query.tenant: h.result(timeout=60) for h in handles}
        stats = svc.stats()
    for h in handles:
        ref, ref_inv = iso[h.query.tenant]
        assert _front(results[h.query.tenant]) == _front(ref), h.query
        # per-tenant attribution identical to isolation (Fig. 11)
        assert h.invocations() == ref_inv, h.query
        assert h.status == "done" and h.done()
    # the shared ledger saw strictly fewer real calls than the tenants
    # paid in attribution: t0/t1/t4 overlap on svc-toy-a, t2/t3 on -b
    tenant_sum = sum(sum(inv.values()) for _, inv in iso.values())
    assert stats["shared_invocations"] < tenant_sum
    assert stats["tenant_invocations"] == tenant_sum
    # and the dedup surfaced as cache hits and/or in-flight joins
    pool_a = stats["pools"]["svc-toy-a-analytical"]
    assert pool_a["tenants"] == 3
    assert pool_a["hits"] + pool_a["joins"] > 0


def test_stats_reports_per_pool_front_sizes():
    """``stats()['pools'][slug]['front_sizes']`` maps each completed
    delta label to that query's Pareto-front cardinality — the sizing
    signal the SoC composition layer reads off a running service
    (docs/soc.md) without re-running any exploration."""
    queries = [
        DSEQuery(app="svc-toy-a", delta=0.5, tenant="s0"),
        DSEQuery(app="svc-toy-a", delta=0.4, tenant="s1"),
        DSEQuery(app="svc-toy-b", delta=0.5, tenant="s2"),
    ]
    with DSEService(max_pending=4, workers=2) as svc:
        handles = svc.submit_all(queries)
        fronts = {h.query.tenant: len(h.result(timeout=60).pareto())
                  for h in handles}
        stats = svc.stats()
    assert stats["pools"]["svc-toy-a-analytical"]["front_sizes"] == {
        "delta=0.5": fronts["s0"], "delta=0.4": fronts["s1"]}
    assert stats["pools"]["svc-toy-b-analytical"]["front_sizes"] == {
        "delta=0.5": fronts["s2"]}
    assert all(n >= 1 for n in fronts.values())


# ----------------------------------------------------------------------
# (2) randomized tenant mixes / interleavings (property test)
# ----------------------------------------------------------------------
_REF_CACHE = {}


def _reference(query):
    if query.pool_key + (query.delta,) not in _REF_CACHE:
        _REF_CACHE[query.pool_key + (query.delta,)] = _isolated(query)
    return _REF_CACHE[query.pool_key + (query.delta,)]


@settings(max_examples=8, deadline=None)
@given(mix=st.lists(
    st.tuples(st.sampled_from(["svc-toy-a", "svc-toy-b"]),
              st.sampled_from([None, 0.4, 0.5])),
    min_size=1, max_size=6),
    workers=st.integers(min_value=1, max_value=4))
def test_randomized_tenant_mixes_stay_deterministic(mix, workers):
    """Any tenant mix, any submission interleaving, any worker count:
    every tenant's front equals its isolated reference."""
    for app in TOYS.values():          # hypothesis reruns outlive fixtures
        register_app(app)
    queries = [DSEQuery(app=a, delta=d, tenant=f"t{i}")
               for i, (a, d) in enumerate(mix)]
    with DSEService(max_pending=len(queries), workers=workers) as svc:
        handles = svc.submit_all(queries)
        for h in handles:
            ref, ref_inv = _reference(h.query)
            assert _front(h.result(timeout=60)) == _front(ref)
            assert h.invocations() == ref_inv


# ----------------------------------------------------------------------
# (3) seeded failure: surfaces to that tenant only
# ----------------------------------------------------------------------
def test_failure_is_isolated_to_its_tenant():
    queries = [
        DSEQuery(app="svc-toy-a", tenant="healthy-0"),
        DSEQuery(app="svc-toy-broken", tenant="doomed"),
        DSEQuery(app="svc-toy-b", tenant="healthy-1"),
    ]
    iso = {q.tenant: _isolated(q)
           for q in queries if q.tenant != "doomed"}
    with DSEService(max_pending=4, workers=3) as svc:
        handles = svc.submit_all(queries)
        doomed = next(h for h in handles if h.query.tenant == "doomed")
        with pytest.raises(RuntimeError, match="seeded oracle failure"):
            doomed.result(timeout=60)
        assert doomed.status == "failed"
        assert isinstance(doomed.exception(), RuntimeError)
        for h in handles:
            if h.query.tenant == "doomed":
                continue
            ref, ref_inv = iso[h.query.tenant]
            assert _front(h.result(timeout=60)) == _front(ref)
            assert h.invocations() == ref_inv
        stats = svc.stats()
    assert stats["queries"]["failed"] == 1
    assert stats["queries"]["done"] == 2
    # the error was never cached in the broken tenant's pool
    broken = stats["pools"]["svc-toy-broken-analytical"]
    assert broken["cache"]["entries"] <= broken["invocations"]


def test_error_is_never_cached_and_retry_reinvokes():
    """SharedOracle error semantics, same rule as OracleLedger: a raise
    is never stored, and a retry of the key dispatches (and counts)
    again."""
    calls = []

    class Flaky(HLSTool):
        def synthesize(self, component, **kw):
            calls.append(component)
            if len(calls) == 1:
                raise RuntimeError("transient")
            return super().synthesize(component, **kw)

    cache = PersistentOracleCache(max_entries=None)
    shared = SharedOracle(Flaky(_toy_specs()), cache=cache, name="flaky")
    req = InvocationRequest(component="a", unrolls=1, ports=1)
    with pytest.raises(RuntimeError, match="shared oracle invocation"):
        shared.evaluate(req)
    assert cache.get(req.key) is None          # error not cached
    out = shared.evaluate(req)                 # retry reaches the tool
    assert out.feasible and len(calls) == 2
    assert shared.total("a") == 2              # counted both times
    assert cache.get(req.key) is not None      # success IS cached
    shared.close()


# ----------------------------------------------------------------------
# (4) LRU eviction: evicted points re-invoke exactly once
# ----------------------------------------------------------------------
def test_lru_eviction_reinvokes_exactly_once():
    calls = []

    class Counting(HLSTool):
        def synthesize(self, component, **kw):
            calls.append((component, kw["unrolls"]))
            return super().synthesize(component, **kw)

    cache = PersistentOracleCache(max_entries=2)
    shared = SharedOracle(Counting(_toy_specs()), cache=cache, name="lru")
    reqs = [InvocationRequest(component="a", unrolls=u, ports=1)
            for u in (1, 2, 4)]
    for r in reqs:
        shared.evaluate(r)
    assert len(calls) == 3
    assert cache.stats()["evictions"] == 1     # u=1 fell out (oldest)
    # recent entries answer from cache: no new tool calls
    shared.evaluate(reqs[1])
    shared.evaluate(reqs[2])
    assert len(calls) == 3 and shared.hits == 2
    # the evicted key re-invokes the tool exactly once...
    shared.evaluate(reqs[0])
    assert len(calls) == 4
    # ...and is cached again (now u=2 is the evictee)
    shared.evaluate(reqs[0])
    assert len(calls) == 4
    stats = cache.stats()
    assert stats["entries"] == 2 and stats["evictions"] == 2
    assert stats["hits"] == 3 and stats["misses"] >= 4
    shared.close()


def test_lru_eviction_keeps_tenant_ledgers_consistent():
    """Fig. 11 counting survives eviction: a tenant's ledger counts a
    point once no matter how often the shared cache forgot it, because
    the ledger's own (unbounded, per-run) cache answers repeats — only
    a *different* tenant re-asking pays a real re-invocation."""
    shared = SharedOracle(HLSTool(_toy_specs()),
                          cache=PersistentOracleCache(max_entries=1),
                          name="tiny")
    t1, t2 = OracleLedger(shared), OracleLedger(shared)
    r1 = InvocationRequest(component="a", unrolls=1, ports=1)
    r2 = InvocationRequest(component="a", unrolls=2, ports=1)
    t1.evaluate(r1)
    t1.evaluate(r2)                  # evicts r1 from the shared cache
    t1.evaluate(r1)                  # tenant repeat: own cache, no count
    assert t1.total("a") == 2        # exactly the distinct points asked
    assert shared.total("a") == 2    # no re-invocation for the repeat
    t2.evaluate(r1)                  # new tenant, evicted key: re-pays
    assert t2.total("a") == 1
    assert shared.total("a") == 3    # exactly one re-invocation
    shared.close()


def test_persistent_lru_bound_survives_reload(tmp_path):
    root = str(tmp_path / "cache")
    cache = PersistentOracleCache(root, max_entries=2, flush_every=1)
    shared = SharedOracle(HLSTool(_toy_specs()), cache=cache)
    reqs = [InvocationRequest(component="a", unrolls=u, ports=1)
            for u in (1, 2, 4)]
    for r in reqs:
        shared.evaluate(r)
    shared.close()
    fresh = PersistentOracleCache(root, max_entries=2)
    stats = fresh.stats()
    assert stats["entries"] == 2
    # the survivors are the two most recent points
    assert fresh.get(reqs[0].key) is None
    assert fresh.get(reqs[1].key) is not None
    assert fresh.get(reqs[2].key) is not None


# ----------------------------------------------------------------------
# (5) backpressure: bounded queue, callers block or get Busy
# ----------------------------------------------------------------------
def test_backpressure_busy_and_unblock():
    svc = DSEService(max_pending=1, workers=1)
    try:
        running = svc.submit(DSEQuery(app="svc-toy-gated", tenant="slow"))
        # wait until the worker picked it up (the queue slot frees)
        deadline = time.monotonic() + 10
        while running.poll() == "queued":
            assert time.monotonic() < deadline
            time.sleep(0.01)
        queued = svc.submit(DSEQuery(app="svc-toy-a", tenant="q"))
        assert not isinstance(queued, Busy)
        # the one queue slot is taken: non-blocking submit bounces...
        busy = svc.submit(DSEQuery(app="svc-toy-a", tenant="rejected"),
                          block=False)
        assert isinstance(busy, Busy) and "queue full" in busy.reason
        # ...and a blocking submit with a timeout bounces too
        busy2 = svc.submit(DSEQuery(app="svc-toy-a", tenant="timed-out"),
                           timeout=0.05)
        assert isinstance(busy2, Busy) and "timed out" in busy2.reason
        _GatedTool.gate.set()
        assert running.result(timeout=60) is not None
        assert queued.result(timeout=60) is not None
        assert svc.stats()["queries"]["rejected_busy"] == 2
    finally:
        _GatedTool.gate.set()
        svc.close()


def test_blocking_submit_waits_out_the_backpressure():
    svc = DSEService(max_pending=1, workers=1)
    try:
        running = svc.submit(DSEQuery(app="svc-toy-gated", tenant="slow"))
        while running.poll() == "queued":
            time.sleep(0.01)
        queued = svc.submit(DSEQuery(app="svc-toy-a", tenant="q1"))
        got = []

        def blocked_submit():
            got.append(svc.submit(DSEQuery(app="svc-toy-a", tenant="q2")))

        t = threading.Thread(target=blocked_submit)
        t.start()
        t.join(timeout=0.2)
        assert t.is_alive()          # genuinely blocked on the full queue
        _GatedTool.gate.set()        # drain -> slot frees -> submit lands
        t.join(timeout=60)
        assert not t.is_alive()
        handle = got[0]
        assert not isinstance(handle, Busy)
        assert handle.result(timeout=60) is not None
        assert queued.result(timeout=60) is not None
    finally:
        _GatedTool.gate.set()
        svc.close()


# ----------------------------------------------------------------------
# (6) submission-time validation + lifecycle
# ----------------------------------------------------------------------
def test_unknown_names_raise_at_submit_not_in_the_worker():
    with DSEService(max_pending=2, workers=1) as svc:
        with pytest.raises(KeyError, match="unknown app"):
            svc.submit(DSEQuery(app="no-such-app"))
        with pytest.raises(KeyError, match="unknown backend"):
            svc.submit(DSEQuery(app="svc-toy-a", backend="verilog"))
        assert svc.stats()["queries"]["submitted"] == 0


def test_close_without_drain_fails_queued_handles():
    svc = DSEService(max_pending=4, workers=1)
    running = svc.submit(DSEQuery(app="svc-toy-gated", tenant="slow"))
    while running.poll() == "queued":
        time.sleep(0.01)
    abandoned = svc.submit(DSEQuery(app="svc-toy-a", tenant="late"))
    _GatedTool.gate.set()
    svc.close(drain=False)
    with pytest.raises(RuntimeError, match="closed before"):
        abandoned.result(timeout=5)
    assert abandoned.status == "failed"
    assert running.result(timeout=5) is not None   # running ones finish
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(DSEQuery(app="svc-toy-a"))


def test_result_timeout_raises_timeouterror():
    svc = DSEService(max_pending=2, workers=1)
    try:
        h = svc.submit(DSEQuery(app="svc-toy-gated", tenant="slow"))
        with pytest.raises(TimeoutError):
            h.result(timeout=0.05)
    finally:
        _GatedTool.gate.set()
        svc.close()


# ----------------------------------------------------------------------
# (7) the real thing: 4 tenants over 2 apps x 2 backends (acceptance)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_acceptance_four_tenants_two_apps_two_backends():
    """The ISSUE acceptance run in test form (the soak bench repeats it
    under load): fronts byte-identical to isolation, shared ledger
    strictly below the per-tenant sum."""
    queries = [
        DSEQuery(app="wami", backend="analytical", tenant="t0"),
        DSEQuery(app="wami", backend="analytical", delta=0.5, tenant="t1"),
        DSEQuery(app="wami", backend="pallas", share_plm=True,
                 tenant="t2"),
        DSEQuery(app="fleet", backend="analytical", tenant="t3"),
    ]
    iso = {q.tenant: _isolated(q) for q in queries}
    with DSEService(max_pending=8, workers=3) as svc:
        handles = svc.submit_all(queries)
        for h in handles:
            ref, ref_inv = iso[h.query.tenant]
            assert _front(h.result(timeout=300)) == _front(ref), h.query
            assert h.invocations() == ref_inv
        stats = svc.stats()
    tenant_sum = sum(sum(inv.values()) for _, inv in iso.values())
    assert stats["shared_invocations"] < tenant_sum
    assert len(stats["pools"]) == 3     # t0/t1 coalesced onto one pool
