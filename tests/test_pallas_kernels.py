"""Interpret-mode parity: every WAMI stage kernel == its jnp oracle
across the (ports x unrolls) knob grid (the PallasOracle's functional
check — DESIGN.md §2).

Marked ``slow``: interpret-mode compiles dominate the suite's wall
clock, so CI runs this module in its own lane (`-m slow`) next to the
kernel smoke gate; the tier-1 fast lane skips it with `-m "not slow"`.
A plain `pytest` run still executes everything.
"""

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.kernels.wami_change_det import (change_detection,
                                           change_detection_oracle)
from repro.kernels.wami_debayer import debayer, debayer_oracle
from repro.kernels.wami_grayscale import grayscale, grayscale_oracle
from repro.kernels.wami_steep import (hessian, hessian_oracle,
                                      steepest_descent,
                                      steepest_descent_oracle)
from repro.kernels.wami_warp import warp_affine, warp_affine_oracle

KEY = jax.random.PRNGKey(11)
H, W = 32, 64
KNOBS = [(1, 4), (2, 8), (4, 2)]          # (ports, unrolls)

# shear small enough that every warp source fraction stays well inside
# (0, 1): the floor() cell choice is then identical across compilations
# and parity is exact (boundary flips would gather a different pixel)
P_AFFINE = jnp.array([1 / 1024, -1 / 2048, 0.5, 1 / 2048, -1 / 1024, 0.5],
                     jnp.float32)


def _close(a, b, tol=1e-5):
    fa, fb = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    scale = max(1.0, float(jnp.abs(fb).max()))
    assert float(jnp.abs(fa - fb).max()) / scale < tol


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_debayer_parity(ports, unrolls):
    bayer = jax.random.uniform(KEY, (H, W)) * 1023.0
    got = debayer(bayer, ports=ports, unrolls=unrolls, interpret=True)
    _close(got, debayer_oracle(bayer))


def test_debayer_odd_block_parity():
    """Odd unroll counts misalign blocks with the 2x2 Bayer quad; the
    in-kernel global-parity recovery must still be exact."""
    bayer = jax.random.uniform(KEY, (30, 64)) * 1023.0
    got = debayer(bayer, ports=2, unrolls=5, interpret=True)
    _close(got, debayer_oracle(bayer))


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_grayscale_parity(ports, unrolls):
    rgb = jax.random.uniform(KEY, (H, W, 3)) * 255.0
    got = grayscale(rgb, ports=ports, unrolls=unrolls, interpret=True)
    _close(got, grayscale_oracle(rgb))


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_steepest_descent_parity(ports, unrolls):
    ks = jax.random.split(KEY, 2)
    gx = jax.random.normal(ks[0], (H, W))
    gy = jax.random.normal(ks[1], (H, W))
    got = steepest_descent(gx, gy, ports=ports, unrolls=unrolls,
                           interpret=True)
    _close(got, steepest_descent_oracle(gx, gy))


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_hessian_parity(ports, unrolls):
    sd = jax.random.normal(KEY, (H, W, 6))
    got = hessian(sd, ports=ports, unrolls=unrolls, interpret=True)
    _close(got, hessian_oracle(sd), tol=1e-4)   # accumulation order


def test_hessian_block_size_invariance():
    """The reduction must not depend on the BlockSpec tiling."""
    sd = jax.random.normal(KEY, (H, W, 6))
    outs = [hessian(sd, ports=p, unrolls=u, interpret=True)
            for p, u in ((1, 32), (2, 4), (4, 16))]
    for o in outs[1:]:
        _close(o, outs[0], tol=1e-4)


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_warp_parity(ports, unrolls):
    img = jax.random.uniform(KEY, (H, W)) * 255.0
    got = warp_affine(img, P_AFFINE, ports=ports, unrolls=unrolls,
                      interpret=True)
    _close(got, warp_affine_oracle(img, P_AFFINE))


@pytest.mark.parametrize("ports,unrolls", KNOBS)
def test_change_detection_parity(ports, unrolls):
    ks = jax.random.split(KEY, 2)
    gray = jax.random.uniform(ks[0], (H, W)) * 100.0
    mu = gray[..., None] + jax.random.normal(ks[1], (H, W, 3)) * 8.0
    var = jnp.full((H, W, 3), 36.0)
    w = jnp.full((H, W, 3), 1.0 / 3.0)
    m1, mu1, v1, w1 = change_detection(gray, mu, var, w, ports=ports,
                                       unrolls=unrolls, interpret=True)
    m2, mu2, v2, w2 = change_detection_oracle(gray, mu, var, w)
    assert int((m1 != m2).sum()) == 0       # mask is exact (same argmin)
    _close(mu1, mu2)
    _close(v1, v2)
    _close(w1, w2)


def test_vmem_models_scale_with_knobs():
    """More ports => proportionally smaller blocks, more grid steps;
    more unrolls => proportionally bigger blocks, fewer steps."""
    from repro.kernels import (wami_change_det, wami_debayer,
                               wami_grayscale, wami_steep, wami_warp)
    for mod in (wami_debayer, wami_grayscale, wami_steep, wami_warp,
                wami_change_det):
        v1 = mod.vmem_bytes(128, 128, ports=1, unrolls=8)
        assert mod.vmem_bytes(128, 128, ports=4, unrolls=8) == v1 // 4
        assert mod.vmem_bytes(128, 128, ports=1, unrolls=16) == v1 * 2
        g1 = mod.grid_steps(128, 128, ports=1, unrolls=8)
        assert mod.grid_steps(128, 128, ports=4, unrolls=8) == 4 * g1
