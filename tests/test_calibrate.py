"""core.calibrate coverage: latency-fit round-trips, the area exchange
rate, and the dominance-preservation property of calibration."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CalibratedTool, DesignPoint, Synthesis,
                        dominates_min_min, fit_area_scale,
                        fit_latency_scales)
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest


def _hls(noise=0.0):
    loop = LoopNest(trip=1024, gamma_r=4, gamma_w=2, arith_ops=16,
                    dep_depth=4, live_values=8)
    return HLSTool({"c": ComponentSpec("c", loop, words_in=4096,
                                       words_out=4096)}, noise=noise)


# ----------------------------------------------------------------------
# latency fit
# ----------------------------------------------------------------------
def test_latency_fit_round_trip_exact():
    """Measured = k * model at every point -> the fit recovers k exactly
    and the calibrated tool reproduces the measurements."""
    tool = _hls()
    k = 3.7
    pts = [(p, u) for p in (1, 2, 4) for u in (4, 8, 16)]
    measured = [("c", p, u, k * tool.synthesize("c", unrolls=u,
                                                ports=p).lam)
                for p, u in pts]
    fit = fit_latency_scales(tool, measured)
    assert fit.scale("c") == pytest.approx(k, rel=1e-12)
    assert fit.lam_spread["c"] == pytest.approx(1.0)
    cal = CalibratedTool(tool, fit)
    for (p, u), (_, _, _, lam) in zip(pts, measured):
        assert cal.synthesize("c", unrolls=u, ports=p).lam == \
            pytest.approx(lam, rel=1e-12)


def test_latency_fit_uses_the_measured_points_tile():
    """5-tuple measured points carry a tile: the fit must query the
    model at that tile, not fold the tile ratio into the scale."""
    from repro.core.hlsim import ComponentSpec, LoopNest
    loop = LoopNest(trip=1024, gamma_r=4, gamma_w=2, arith_ops=16,
                    dep_depth=4, live_values=8)
    tool = HLSTool({"c": ComponentSpec("c", loop, words_in=4096,
                                       words_out=4096, outer_repeats=16,
                                       base_tile=32)}, noise=0.0)
    k = 2.0
    measured = [("c", p, u, k * tool.synthesize("c", unrolls=u, ports=p,
                                                tile=t).lam, t)
                for p in (1, 2) for u in (4, 8) for t in (32, 64)]
    fit = fit_latency_scales(tool, measured)
    assert fit.scale("c") == pytest.approx(k, rel=1e-12)
    assert fit.lam_spread["c"] == pytest.approx(1.0)   # no tile leakage


def test_latency_fit_order_independent():
    tool = _hls()
    measured = [("c", p, u, 1e-3 * u * (1 + 0.1 * p))
                for p in (1, 2, 4) for u in (4, 8, 16)]
    f1 = fit_latency_scales(tool, measured)
    f2 = fit_latency_scales(tool, list(reversed(measured)))
    assert f1.scales == f2.scales          # bitwise: sorted log sum


# ----------------------------------------------------------------------
# area fit
# ----------------------------------------------------------------------
def test_area_scale_round_trip():
    tool = _hls()
    k = 7.5e4                              # "bytes per mm2"
    measured = [("c", p, u, k * tool.synthesize("c", unrolls=u,
                                                ports=p).area)
                for p in (1, 2, 4) for u in (4, 8)]
    scale, n, spread = fit_area_scale(tool, measured)
    assert scale == pytest.approx(k, rel=1e-12)
    assert n == 6 and spread == pytest.approx(1.0)


def test_area_scale_skips_bad_points():
    tool = _hls()
    good = 2.0 * tool.synthesize("c", unrolls=4, ports=2).area
    scale, n, _ = fit_area_scale(tool, [("c", 2, 4, float("inf")),
                                        ("c", 2, 4, -5.0),
                                        ("c", 2, 4, good)])
    assert n == 1 and scale == pytest.approx(2.0)
    assert fit_area_scale(tool, []) == (1.0, 0, 1.0)


def test_calibrated_tool_scales_area_and_detail():
    tool = _hls()
    fit = fit_latency_scales(tool, [])
    cal = CalibratedTool(tool, fit, area_scale=1e4, unit="bytes")
    raw = tool.synthesize("c", unrolls=4, ports=2)
    s = cal.synthesize("c", unrolls=4, ports=2)
    assert s.area == pytest.approx(raw.area * 1e4)
    assert s.detail["area_plm"] == pytest.approx(
        raw.detail["area_plm"] * 1e4)
    assert s.detail["area_logic"] == pytest.approx(
        raw.detail["area_logic"] * 1e4)
    req = cal.plm_requirement("c", s)
    assert req.unit == "bytes"
    assert req.area_plm == pytest.approx(s.detail["area_plm"])
    assert req.area_plm + req.area_logic == pytest.approx(s.area)


# ----------------------------------------------------------------------
# property: calibration never reorders dominance within one backend
# ----------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.floats(min_value=1e-6, max_value=1e6),
       st.floats(min_value=1e-6, max_value=1e6),
       st.lists(st.tuples(st.floats(min_value=1e-9, max_value=1e3),
                          st.floats(min_value=1e-9, max_value=1e3)),
                min_size=2, max_size=12))
def test_calibration_preserves_dominance_order(k_lam, k_area, raw_points):
    """Scaling every latency by one positive constant and every area by
    another is a monotone map on both axes, so min-min dominance between
    any two points of a single backend is invariant — the guarantee that
    lets mixed fronts use fitted exchange rates without corrupting
    per-backend Pareto structure."""
    pts = [DesignPoint(perf=lam, cost=area) for lam, area in raw_points]
    scaled = [DesignPoint(perf=lam * k_lam, cost=area * k_area)
              for lam, area in raw_points]
    for i, a in enumerate(pts):
        for j, b in enumerate(pts):
            if i == j:
                continue
            assert dominates_min_min(a, b) == \
                dominates_min_min(scaled[i], scaled[j])


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=0.1, max_value=10.0),
       st.floats(min_value=0.1, max_value=10.0))
def test_calibrated_hlstool_preserves_dominance(k_lam, k_area):
    """Same property through the real CalibratedTool on real syntheses."""
    tool = _hls()
    fit = fit_latency_scales(
        tool, [("c", p, u, k_lam * tool.synthesize("c", unrolls=u,
                                                   ports=p).lam)
               for p in (1, 2) for u in (2, 4)])
    cal = CalibratedTool(tool, fit, area_scale=k_area)
    knobs = [(p, u) for p in (1, 2, 4) for u in (4, 8)]
    raw = [tool.synthesize("c", unrolls=u, ports=p) for p, u in knobs]
    cald = [cal.synthesize("c", unrolls=u, ports=p) for p, u in knobs]

    def dp(s):
        return DesignPoint(perf=s.lam, cost=s.area)

    for i in range(len(knobs)):
        for j in range(len(knobs)):
            if i == j:
                continue
            assert dominates_min_min(dp(raw[i]), dp(raw[j])) == \
                dominates_min_min(dp(cald[i]), dp(cald[j]))
