"""WAMI components: functional goldens + the end-to-end LK pipeline."""

import jax
import jax.numpy as jnp
import jax.scipy.signal as jsig
import numpy as np
import pytest

from repro.apps.wami import (FRAME, build_components, change_detection,
                             debayer, gradient, grayscale, hessian,
                             lucas_kanade, matrix_invert, sd_update,
                             steepest_descent, wami_app, wami_tmg,
                             warp_affine)


@pytest.fixture(scope="module")
def img():
    key = jax.random.PRNGKey(0)
    raw = jax.random.uniform(key, (64, 64), jnp.float32)
    k = jnp.ones((5, 5)) / 25.0
    return jsig.convolve2d(raw, k, mode="same") * 100.0


def test_debayer_constant_image():
    out = debayer(jnp.full((16, 16), 77.0))
    assert out.shape == (16, 16, 3)
    assert float(jnp.abs(out - 77.0).max()) < 1e-4


def test_grayscale_weights():
    rgb = jnp.stack([jnp.full((4, 4), 1.0), jnp.zeros((4, 4)),
                     jnp.zeros((4, 4))], -1)
    assert float(grayscale(rgb)[0, 0]) == pytest.approx(0.299)


def test_gradient_of_ramp():
    yy, xx = jnp.meshgrid(jnp.arange(32.0), jnp.arange(32.0), indexing="ij")
    gx, gy = gradient(3 * xx + 7 * yy)
    assert float(gx[5:-5, 5:-5].mean()) == pytest.approx(3.0, rel=1e-5)
    assert float(gy[5:-5, 5:-5].mean()) == pytest.approx(7.0, rel=1e-5)


def test_warp_identity_and_shift(img):
    assert float(jnp.abs(warp_affine(img, jnp.zeros(6)) - img).max()) < 1e-4
    shifted = warp_affine(img, jnp.array([0, 0, 1.0, 0, 0, 0]))  # x' = x+1
    assert float(jnp.abs(shifted[:, :-1] - img[:, 1:]).max()) < 1e-3


def test_hessian_psd(img):
    gx, gy = gradient(img)
    H = hessian(steepest_descent(gx, gy))
    assert H.shape == (6, 6)
    assert float(jnp.abs(H - H.T).max()) < 1e-2 * float(jnp.abs(H).max())
    eig = jnp.linalg.eigvalsh(H)
    assert float(eig.min()) >= -1e-3 * float(eig.max())


def test_matrix_invert(img):
    A = jax.random.normal(jax.random.PRNGKey(1), (6, 6)) + 6 * jnp.eye(6)
    assert float(jnp.abs(matrix_invert(A) @ A - jnp.eye(6)).max()) < 1e-3


def test_lucas_kanade_recovers_affine(img):
    p_true = jnp.array([0.01, -0.005, 0.8, 0.004, 0.008, -0.5], jnp.float32)
    moved = warp_affine(img, p_true)
    p_est = lucas_kanade(moved, img, n_iters=30)
    assert float(jnp.abs(p_est - p_true).max()) < 1e-3


def test_change_detection_flags_changes(img):
    mu = jnp.repeat(img[..., None], 3, -1)
    var = jnp.full(img.shape + (3,), 36.0)
    w = jnp.full(img.shape + (3,), 1 / 3)
    # unchanged frame -> almost no foreground
    mask0, *_ = change_detection(img, mu, var, w)
    assert float(mask0.mean()) < 0.05
    # a bright square appears
    changed = img.at[20:30, 20:30].add(200.0)
    mask1, *_ = change_detection(changed, mu, var, w)
    assert float(mask1[20:30, 20:30].mean()) > 0.9


def test_wami_app_end_to_end(img):
    frames = jnp.stack([img, img, img.at[10:20, 10:20].add(150.0)])
    masks, ps = wami_app(frames, n_iters=4)
    assert masks.shape == (2, 64, 64)
    assert float(masks[0].mean()) < 0.1          # static frame: clean
    assert float(masks[1][10:20, 10:20].mean()) > 0.5


def test_wami_tmg_structure():
    tmg = wami_tmg()
    assert tmg.strongly_connected()
    assert tmg.n == 13
    delays = {t.name: 1.0 for t in tmg.transitions}
    assert 0 < tmg.throughput(delays) < float("inf")


def test_component_cdfg_extraction():
    comps = build_components(tile=64, frame=128)
    assert len(comps) == 12
    ln = comps["gradient"].loop_nest()
    assert ln.gamma_r == 5 and ln.gamma_w == 2      # 5-point stencil, 2 outs
    ln = comps["grayscale"].loop_nest()
    assert ln.gamma_r == 3 and ln.gamma_w == 1      # RGB in, luma out
    assert comps["change_det"].loop_nest().gamma_r == 1  # register-cached
