"""Full COSMOS vs exhaustive: front quality + invocation reduction."""

import pytest

from repro.core import (CountingTool, HLSTool, KnobSpace, compose_exhaustive,
                        cosmos_dse, exhaustive_dse, pareto_front_max_min,
                        pipeline_tmg)
from repro.core.hlsim import ComponentSpec, LoopNest


def _system():
    specs = {
        "a": ComponentSpec("a", LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
        "b": ComponentSpec("b", LoopNest(512, 4, 2, 16, 5, 10), 2048, 1024),
        "c": ComponentSpec("c", LoopNest(128, 1, 1, 4, 2, 4), 512, 512),
    }
    tool = HLSTool(specs)
    tmg = pipeline_tmg(list(specs), buffers=2)
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=8, max_unrolls=16)
              for n in specs}
    return specs, tool, tmg, spaces


def test_cosmos_beats_exhaustive_on_invocations():
    specs, tool, tmg, spaces = _system()
    res = cosmos_dse(tmg, tool, spaces, delta=0.3)
    ex = exhaustive_dse(list(specs), HLSTool(dict(
        (n, specs[n]) for n in specs)), spaces)
    assert ex.total_invocations > 2.5 * res.total_invocations


def test_extreme_points_match_exhaustive():
    """At theta_min / theta_max the mapped points must coincide with the
    exhaustive front's extreme points."""
    specs, tool, tmg, spaces = _system()
    res = cosmos_dse(tmg, tool, spaces, delta=0.3)
    ex = exhaustive_dse(list(specs), HLSTool(dict(specs)), spaces)
    front = compose_exhaustive(tmg, ex.fronts)
    lo_ex, hi_ex = front[0], front[-1]
    mapped = sorted(res.mapped, key=lambda m: m.theta_actual)
    assert mapped[0].theta_actual == pytest.approx(lo_ex.perf, rel=1e-6)
    assert mapped[-1].theta_actual == pytest.approx(hi_ex.perf, rel=1e-6)


def test_mapped_points_near_exhaustive_front():
    """Every COSMOS point must be within a bounded factor of the true
    front's cost at >= its throughput (quality guarantee in practice)."""
    specs, tool, tmg, spaces = _system()
    res = cosmos_dse(tmg, tool, spaces, delta=0.3)
    ex = exhaustive_dse(list(specs), HLSTool(dict(specs)), spaces)
    front = compose_exhaustive(tmg, ex.fronts)
    for m in res.pareto():
        # cheapest exhaustive point at >= this throughput
        cands = [p.cost for p in front if p.perf >= m.perf * (1 - 1e-9)]
        if not cands:
            continue
        assert m.cost <= min(cands) * 1.6


def test_mapped_theta_meets_plan():
    """Mapping is conservative: actual throughput >= planned (the paper
    trades area to preserve throughput)."""
    specs, tool, tmg, spaces = _system()
    res = cosmos_dse(tmg, tool, spaces, delta=0.3)
    for m in res.mapped:
        assert m.theta_actual >= m.theta_planned * (1 - 0.02)


def test_fixed_software_component():
    """Matrix-Inv-style fixed transitions join the TMG but are never
    synthesized."""
    specs, tool, tmg0, spaces = _system()
    from repro.core import TMG, Place, Transition
    names = list(specs) + ["sw"]
    tmg = pipeline_tmg(names, buffers=2)
    res = cosmos_dse(tmg, tool, spaces, delta=0.5, fixed={"sw": 1e-4})
    assert "sw" not in res.invocations
    assert "sw" not in res.characterizations
