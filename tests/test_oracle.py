"""The unified oracle layer: ledger parity, batching, persistence."""

import os
import threading

import pytest

from repro.core import (CountingTool, HLSTool, InvocationRequest, KnobSpace,
                        OracleLedger, PersistentOracleCache, cosmos_dse,
                        exhaustive_dse, pipeline_tmg)
from repro.core.hlsim import ComponentSpec, LoopNest


class SpyTool(HLSTool):
    """HLSTool that counts *real* synthesis calls reaching the backend."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.calls = 0
        self._call_lock = threading.Lock()

    def synthesize(self, *args, **kwargs):
        with self._call_lock:
            self.calls += 1
        return super().synthesize(*args, **kwargs)


def _specs():
    return {
        "a": ComponentSpec("a", LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
        "b": ComponentSpec("b", LoopNest(512, 4, 2, 16, 5, 10), 2048, 1024),
        "c": ComponentSpec("c", LoopNest(128, 1, 1, 4, 2, 4), 512, 512),
    }


def _spaces(specs, max_ports=8, max_unrolls=16):
    return {n: KnobSpace(clock_ns=1.0, max_ports=max_ports,
                         max_unrolls=max_unrolls) for n in specs}


# ----------------------------------------------------------------------
# CountingTool-parity semantics
# ----------------------------------------------------------------------
def test_repeats_are_cached_and_uncounted():
    tool = SpyTool(_specs())
    led = OracleLedger(tool)
    s1 = led.synthesize("a", unrolls=4, ports=2)
    s2 = led.synthesize("a", unrolls=4, ports=2)
    assert s1 is s2                       # served from cache
    assert led.total("a") == 1
    assert tool.calls == 1
    # different max_states is a different knob point
    led.synthesize("a", unrolls=4, ports=2, max_states=99)
    assert led.total("a") == 2


def test_failures_are_counted():
    led = OracleLedger(HLSTool(_specs(), noise=0.0))
    out = led.synthesize("a", unrolls=16, ports=1, max_states=1)
    assert not out.feasible
    assert led.total("a") == 1
    assert led.failed["a"] == 1
    # the infeasible point is cached too (repeat uncounted)
    led.synthesize("a", unrolls=16, ports=1, max_states=1)
    assert led.total("a") == 1


def test_countingtool_is_the_ledger():
    """The legacy name keeps the seed's construction + surface."""
    ct = CountingTool(HLSTool(_specs()))
    assert isinstance(ct, OracleLedger)
    ct.synthesize("a", unrolls=2, ports=2)
    assert ct.invocations == {"a": 1}
    assert ct.total() == 1


def test_inflight_dedup_under_concurrency():
    """N threads racing on one knob point trigger ONE backend call."""
    tool = SpyTool(_specs())
    led = OracleLedger(tool)
    req = InvocationRequest(component="a", unrolls=4, ports=2)
    barrier = threading.Barrier(8)
    outs = []

    def hammer():
        barrier.wait()
        outs.append(led.evaluate(req))

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tool.calls == 1
    assert led.total("a") == 1
    assert all(o is outs[0] for o in outs)


def test_records_are_per_real_invocation():
    led = OracleLedger(HLSTool(_specs()))
    led.phase = "characterize"
    led.synthesize("a", unrolls=2, ports=2)
    led.synthesize("a", unrolls=2, ports=2)      # cache hit: no record
    led.phase = "map"
    led.synthesize("b", unrolls=4, ports=4)
    assert len(led.records) == 2
    assert led.records_by_phase() == {"characterize": 1, "map": 1}
    r = led.records[0]
    assert (r.component, r.unrolls, r.ports, r.feasible) == ("a", 2, 2, True)


# ----------------------------------------------------------------------
# Batched vs serial determinism
# ----------------------------------------------------------------------
def test_exhaustive_batched_matches_serial():
    specs = _specs()
    spaces = _spaces(specs)
    e1 = exhaustive_dse(list(specs), HLSTool(dict(specs)), spaces, workers=1)
    e8 = exhaustive_dse(list(specs), HLSTool(dict(specs)), spaces, workers=8)
    assert e1.invocations == e8.invocations
    assert repr(e1.points) == repr(e8.points)
    assert repr(e1.fronts) == repr(e8.fronts)


def test_cosmos_batched_matches_serial():
    specs = _specs()
    spaces = _spaces(specs)
    tmg = pipeline_tmg(list(specs), buffers=2)
    r1 = cosmos_dse(tmg, HLSTool(dict(specs)), spaces, delta=0.3, workers=1)
    r8 = cosmos_dse(tmg, HLSTool(dict(specs)), spaces, delta=0.3, workers=8)
    assert r1.invocations == r8.invocations
    assert repr(r1.planned) == repr(r8.planned)
    assert repr(r1.mapped) == repr(r8.mapped)
    assert repr(r1.pareto()) == repr(r8.pareto())


def test_evaluate_batch_preserves_order_and_dedupes():
    tool = SpyTool(_specs())
    led = OracleLedger(tool, workers=4)
    reqs = [InvocationRequest("a", unrolls=u, ports=2) for u in (2, 3, 2, 4)]
    outs = led.evaluate_batch(reqs)
    assert [o.unrolls for o in outs] == [2, 3, 2, 4]
    assert tool.calls == 3               # the duplicate collapsed
    assert led.total("a") == 3


# ----------------------------------------------------------------------
# Persistent cache: kill/restart resumes with zero re-invocations
# ----------------------------------------------------------------------
def test_persistent_cache_resume(tmp_path):
    specs = _specs()
    spaces = _spaces(specs, max_ports=4, max_unrolls=8)
    tmg = pipeline_tmg(list(specs), buffers=2)
    root = os.path.join(tmp_path, "oracle-cache")

    t1 = SpyTool(dict(specs))
    r1 = cosmos_dse(tmg, t1, spaces, delta=0.3,
                    cache=PersistentOracleCache(root), workers=4)
    assert t1.calls > 0

    # "restart": fresh tool, fresh ledger, same cache root
    t2 = SpyTool(dict(specs))
    r2 = cosmos_dse(tmg, t2, spaces, delta=0.3,
                    cache=PersistentOracleCache(root), workers=4)
    assert t2.calls == 0                  # zero re-invocations
    assert repr(r1.mapped) == repr(r2.mapped)
    assert r1.invocations == r2.invocations   # counts reconstructed


def test_persistent_cache_partial_resume(tmp_path):
    """A run killed mid-way re-invokes only the missing points and the
    final counts match an uninterrupted run."""
    specs = _specs()
    spaces = _spaces(specs, max_ports=4, max_unrolls=8)
    root = os.path.join(tmp_path, "cache")

    # pay for a few points (flushed every put), then "die"
    led = OracleLedger(SpyTool(dict(specs)),
                       cache=PersistentOracleCache(root, flush_every=1))
    led.synthesize("a", unrolls=1, ports=1)
    led.synthesize("a", unrolls=2, ports=2)

    tmg = pipeline_tmg(list(specs), buffers=2)
    t_ref = SpyTool(dict(specs))
    ref = cosmos_dse(tmg, t_ref, spaces, delta=0.3)
    t_res = SpyTool(dict(specs))
    res = cosmos_dse(tmg, t_res, spaces, delta=0.3,
                     cache=PersistentOracleCache(root))
    assert t_res.calls < t_ref.calls      # resumed run paid less
    assert repr(ref.mapped) == repr(res.mapped)
    assert ref.invocations == res.invocations


def test_persistent_cache_tile_keys_and_legacy_records(tmp_path):
    """Tile-differentiated points persist under 5-element keys, and a
    pre-tile cache (4-element keys) reloads as native-tile points."""
    import json

    import numpy as np

    from repro.checkpoint import store as ckpt

    specs = _specs()
    loop = LoopNest(256, 2, 1, 8, 3, 6)
    specs["t"] = ComponentSpec("t", loop, 1024, 1024, outer_repeats=4,
                               base_tile=32)
    root = os.path.join(tmp_path, "cache")
    led = OracleLedger(SpyTool(dict(specs)),
                       cache=PersistentOracleCache(root, flush_every=1))
    s32 = led.synthesize("t", unrolls=4, ports=2, tile=32)
    s64 = led.synthesize("t", unrolls=4, ports=2, tile=64)
    assert s32.area != s64.area

    led2 = OracleLedger(SpyTool(dict(specs)),
                        cache=PersistentOracleCache(root))
    assert led2.synthesize("t", unrolls=4, ports=2, tile=64).area == s64.area
    assert led2.total("t") == 2            # both tile points reconstructed

    # hand-build a legacy (4-key) cache record and reload it
    legacy_root = os.path.join(tmp_path, "legacy")
    entry = {"key": ["t", 4, 2, None],
             "synth": {"lam": 1.0, "area": 2.0, "ports": 2, "unrolls": 4,
                       "states": 3, "feasible": True, "detail": {}}}
    ckpt.save(legacy_root, 1, {"n_entries": np.asarray(1)},
              extra={"entries": [entry]})
    cache = PersistentOracleCache(legacy_root)
    (key, synth), = cache.entries().items()
    assert key == ("t", 4, 2, None, 0)     # tile=0: native
    assert synth.area == 2.0 and synth.tile == 0
