"""Shared pytest config.

NOTE: XLA_FLAGS / device-count forcing deliberately NOT set here — smoke
tests and benches run on the single real CPU device; only
launch/dryrun.py (its own process) forces 512 host devices.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
