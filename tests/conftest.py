"""Shared pytest config.

NOTE: XLA_FLAGS / device-count forcing deliberately NOT set here — smoke
tests and benches run on the single real CPU device; only
launch/dryrun.py (its own process) forces 512 host devices.

When ``hypothesis`` is not installed (it is a test extra, not a runtime
dependency), a stub is installed into ``sys.modules`` BEFORE collection
so the property-test modules still import: every ``@given`` test body is
replaced with a clean ``pytest.skip`` and the rest of each module runs
normally.  ``pip install -e .[test]`` restores the real property tests.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------------
# golden-artifact byte gates (fig10 / pricing / soc cells): one shared
# capture + load pair instead of per-module copies
# ----------------------------------------------------------------------
class CaptureReport:
    """Minimal stand-in for benchmarks.run's Report: keeps the lines
    one cell writes so a test can byte-compare them."""

    def __init__(self):
        self.lines = None

    def write(self, name, lines):
        self.lines = list(lines)

    def csv(self, *args, **kwargs):
        pass


@pytest.fixture
def bench_cell_lines():
    """Run one bench module's cell through a capture report and return
    its output exactly as `benchmarks.run` would write it to disk."""

    def _lines(mod, cell) -> str:
        report = CaptureReport()
        mod.run(report, cell)
        assert report.lines is not None
        return "\n".join(report.lines) + "\n"

    return _lines


@pytest.fixture
def committed_artifact():
    """Read a committed golden file under artifacts/bench/."""

    def _read(*parts) -> str:
        with open(os.path.join(REPO, "artifacts", "bench", *parts)) as f:
            return f.read()

    return _read

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import types

    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not see the strategy
            # parameters, or it would try to resolve them as fixtures
            def _skipped_property_test():
                pytest.skip("hypothesis not installed "
                            "(pip install -e .[test])")
            _skipped_property_test.__name__ = fn.__name__
            _skipped_property_test.__doc__ = fn.__doc__
            _skipped_property_test.__module__ = fn.__module__
            return _skipped_property_test
        return deco

    def _passthrough(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder for strategy objects built at import time."""

        def __init__(self, name="st"):
            self._name = name

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, item):
            return _Strategy(f"{self._name}.{item}")

        def __repr__(self):
            return f"<{self._name} stub>"

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy(f"st.{name}")

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _passthrough
    _hyp.example = _passthrough
    _hyp.assume = lambda *a, **k: True
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
