"""Pareto utilities + hypothesis invariants."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DesignPoint, check_delta_curve, pareto_front_max_min,
                        pareto_front_min_min, span)

pts = st.lists(
    st.tuples(st.floats(0.1, 100), st.floats(0.1, 100)).map(
        lambda t: DesignPoint(perf=t[0], cost=t[1])),
    min_size=1, max_size=40)


def test_front_basic():
    p = [DesignPoint(1, 10), DesignPoint(2, 5), DesignPoint(3, 1),
         DesignPoint(3, 2), DesignPoint(0.5, 20)]
    front = pareto_front_min_min(p)
    assert DesignPoint(3, 2) not in front
    assert DesignPoint(3, 1) in front
    assert DesignPoint(1, 10) in front


def test_span():
    assert span([1.0, 2.0, 4.0]) == pytest.approx(4.0)
    assert span([]) == 1.0


@settings(max_examples=100, deadline=None)
@given(pts)
def test_front_members_not_dominated(points):
    front = pareto_front_min_min(points)
    for f in front:
        dominated = any(
            (q.perf <= f.perf and q.cost <= f.cost)
            and (q.perf < f.perf or q.cost < f.cost) for q in points)
        assert not dominated


@settings(max_examples=100, deadline=None)
@given(pts)
def test_every_point_dominated_by_front_or_in_it(points):
    front = pareto_front_min_min(points)
    fkeys = {(f.perf, f.cost) for f in front}
    for p in points:
        ok = (p.perf, p.cost) in fkeys or any(
            f.perf <= p.perf and f.cost <= p.cost for f in front)
        assert ok


@settings(max_examples=100, deadline=None)
@given(pts)
def test_front_idempotent(points):
    f1 = pareto_front_min_min(points)
    assert pareto_front_min_min(f1) == f1


@settings(max_examples=50, deadline=None)
@given(pts)
def test_max_min_front_sorted_tradeoff(points):
    """Along a (theta up, cost down) front, cost must rise with perf."""
    front = pareto_front_max_min(points)
    for a, b in zip(front, front[1:]):
        assert b.perf > a.perf
        assert b.cost > a.cost


def test_delta_curve():
    close = [DesignPoint(1.0, 1.0), DesignPoint(1.1, 1.05),
             DesignPoint(1.2, 1.12)]
    assert check_delta_curve(close, delta=0.25)
    gappy = [DesignPoint(1.0, 1.0), DesignPoint(5.0, 1.01)]
    assert not check_delta_curve(gappy, delta=0.25)
