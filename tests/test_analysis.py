"""Schedule-aware static analysis: busy-interval certificates, the
independent PLM-plan race detector, the exhaustive-optimal packing gate,
and the repo lint driver (docs/analysis.md)."""

import dataclasses
import json
import math
import os
import random

import pytest

from repro.core import (App, KnobSpace, MemGen, PLMPlanner, PLMRequirement,
                        PLMSpec, Schedule, build_session, exclusive_pairs,
                        get_app)
from repro.core.analysis.intervals import (BusyInterval, busy_intervals,
                                           compat_source_for,
                                           intervals_overlap,
                                           schedule_exclusive_pairs)
from repro.core.analysis.lint import LintFinding, lint_all, lint_app
from repro.core.analysis.packing import optimal_plan, partitions
from repro.core.analysis.verify import (PlanVerificationError,
                                        assert_plan_sound, verify_plan)
from repro.core.planning import (ComponentModel, PiecewiseLinearCost, plan,
                                 theta_bounds)
from repro.core.plm.compat import CompatSource, MemoryCompatGraph
from repro.core.plm.planner import shared_area
from repro.core.plm.spec import (MemoryGroup, MemoryPlan,
                                 memory_plan_from_json, memory_plan_to_json)
from repro.core.tmg import (TMG, Place, Transition, feedback_pipeline_tmg,
                            pipeline_tmg)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------
def _toy_models(tmg, lam_min=0.5, lam_max=2.0):
    cost = PiecewiseLinearCost.from_points([(lam_min, 4.0), (lam_max, 1.0)])
    return {t.name: ComponentModel(name=t.name, lam_min=lam_min,
                                   lam_max=lam_max, cost=cost)
            for t in tmg.transitions}


def _mm2_requirement(name, words, ports=2, logic=0.05):
    gen = MemGen()
    area = gen.generate(PLMSpec(words=words, word_bits=32, ports=ports)).area
    return PLMRequirement(component=name, capacity=words, word_bits=32,
                          ports=ports, area_plm=area, area_logic=logic)


# ----------------------------------------------------------------------
# Schedule as a first-class planning output (PlanPoint.schedule)
# ----------------------------------------------------------------------
def test_plan_returns_schedule():
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    models = _toy_models(tmg)
    lo, hi = theta_bounds(tmg, models)
    pt = plan(tmg, models, theta=(lo + hi) / 2)
    assert pt is not None and pt.schedule is not None
    sched = pt.schedule
    assert sched.theta == pt.theta
    assert set(sched.sigma) == {"a", "b", "c"} == set(sched.tau)
    # tau IS the planned latency-target vector, just re-keyed
    assert sched.tau == pt.lam_targets
    # one-token self places bound every firing inside one period
    for nme, tau in sched.tau.items():
        assert 0.0 < tau <= sched.period + 1e-12, nme
    # admissibility spot check: the schedule satisfies every place row
    # sigma_dst - sigma_src + tau_src_if_selected >= -M0/theta is the
    # LP's feasibility; re-check via the TMG matrices
    import numpy as np
    names = [t.name for t in tmg.transitions]
    sig = np.array([sched.sigma[n] for n in names])
    tau = np.array([sched.tau[n] for n in names])
    A, B = tmg.incidence_matrix(), tmg.input_delay_selector()
    lhs = A @ sig - B @ tau + tmg.initial_marking() / sched.theta
    assert (lhs >= -1e-6).all()


def test_schedule_json_roundtrip():
    s = Schedule(theta=2.5, sigma={"a": 0.0, "b": 0.1}, tau={"a": 0.2,
                                                             "b": 0.3})
    back = Schedule.from_json(json.loads(json.dumps(s.to_json())))
    assert back == s
    assert back.tag() == s.tag() == "theta=2.5"


def test_plan_point_json_backwards_compatible():
    """Pre-schedule session snapshots (no 'schedule' key) still load."""
    from repro.core.session import _plan_from_json, _plan_to_json
    tmg = pipeline_tmg(["a", "b"], buffers=2)
    pt = plan(tmg, _toy_models(tmg), theta=1.0)
    d = _plan_to_json(pt)
    assert _plan_from_json(d).schedule == pt.schedule
    d.pop("schedule")
    old = _plan_from_json(d)
    assert old.schedule is None and old.lam_targets == pt.lam_targets


# ----------------------------------------------------------------------
# memoization: simple_cycles / compat graphs computed once per TMG
# ----------------------------------------------------------------------
def test_simple_cycles_memoized_with_call_counter(monkeypatch):
    tmg = pipeline_tmg(["a", "b", "c"], buffers=1)
    calls = {"n": 0}
    orig = TMG.simple_cycles

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(TMG, "simple_cycles", counting)
    first = tmg.simple_cycles()
    second = tmg.simple_cycles()
    # the wrapper is hit twice, but the enumeration ran once: the second
    # call returned the cached list object
    assert calls["n"] == 2 and first is second

    # exclusive_pairs is itself cached per TMG: after the first call the
    # cycle enumerator is not consulted again
    calls["n"] = 0
    p1 = exclusive_pairs(tmg)
    p2 = exclusive_pairs(tmg)
    assert p1 is p2 and calls["n"] <= 1


def test_compat_graph_cached_per_tmg():
    tmg = pipeline_tmg(["a", "b"], buffers=1)
    assert MemoryCompatGraph.for_tmg(tmg) is MemoryCompatGraph.for_tmg(tmg)
    other = pipeline_tmg(["a", "b"], buffers=1)
    assert MemoryCompatGraph.for_tmg(other) is not \
        MemoryCompatGraph.for_tmg(tmg)


# ----------------------------------------------------------------------
# busy intervals: the circular-overlap primitive
# ----------------------------------------------------------------------
def test_intervals_overlap_linear_and_wrapped():
    P = 1.0
    a = BusyInterval("a", 0.0, 0.3)
    b = BusyInterval("b", 0.4, 0.3)
    assert not intervals_overlap(a, b, P)
    assert intervals_overlap(a, BusyInterval("c", 0.2, 0.3), P)
    # wrap-around: [0.8, 1.1) crosses zero into [0, 0.1)
    w = BusyInterval("w", 0.8, 0.3)
    assert intervals_overlap(w, BusyInterval("x", 0.05, 0.1), P)
    assert not intervals_overlap(w, BusyInterval("y", 0.45, 0.3), P)


def test_intervals_touching_counts_as_overlap():
    """Conservative: zero-slack adjacency is NOT certified disjoint."""
    P = 1.0
    a = BusyInterval("a", 0.0, 0.5)
    assert intervals_overlap(a, BusyInterval("b", 0.5, 0.4), P)
    assert intervals_overlap(a, BusyInterval("b", 0.5 + 1e-12, 0.4), P)
    assert not intervals_overlap(a, BusyInterval("b", 0.5 + 1e-6, 0.4), P)


def test_full_period_interval_overlaps_everything():
    P = 2.0
    full = BusyInterval("f", 0.3, 2.0)
    assert intervals_overlap(full, BusyInterval("b", 0.0, 0.01), P)


def test_schedule_certificate_toy():
    s = Schedule(theta=1.0,
                 sigma={"a": 0.0, "b": 0.45, "c": 0.1},
                 tau={"a": 0.4, "b": 0.4, "c": 0.2})
    cert = schedule_exclusive_pairs(s)
    assert cert.certifies("a", "b")            # [0,.4) vs [.45,.85)
    assert not cert.certifies("a", "c")        # [0,.4) vs [.1,.3)
    assert cert.certifies("b", "c")            # [.45,.85) vs [.1,.3)
    assert cert.tag == s.tag() and cert.theta == 1.0


def test_schedule_certificate_toy_wrapped():
    # b wraps: [0.9, 1.2) == [0.9,1)+[0,0.2); a=[0.25,0.55) is clear
    s = Schedule(theta=1.0, sigma={"a": 0.25, "b": 0.9},
                 tau={"a": 0.3, "b": 0.3})
    assert schedule_exclusive_pairs(s).certifies("a", "b")
    s2 = Schedule(theta=1.0, sigma={"a": 0.1, "b": 0.9},
                  tau={"a": 0.3, "b": 0.3})
    assert not schedule_exclusive_pairs(s2).certifies("a", "b")


def test_certified_pairs_never_cobusy_randomized():
    """Property (satellite): against an independent timed simulation, a
    certificate is never wrong.  100 random periodic schedules; busyness
    is evaluated from the *absolute* definition (t - sigma) mod P < tau,
    not the certifier's 3-shift interval algebra."""
    rng = random.Random(7)
    grid = [i / 499 for i in range(499)]
    for trial in range(100):
        period = rng.choice([0.5, 1.0, 3.0])
        names = ["t%d" % i for i in range(rng.randint(2, 6))]
        sigma = {n: rng.uniform(-2.0, 2.0) for n in names}
        tau = {n: rng.uniform(0.01, period) for n in names}
        s = Schedule(theta=1.0 / period, sigma=sigma, tau=tau)
        cert = schedule_exclusive_pairs(s)

        def busy(n, t):
            return ((t - sigma[n]) % period) < tau[n]

        for pair in cert.pairs:
            u, v = sorted(pair)
            for g in grid:
                t = g * period
                assert not (busy(u, t) and busy(v, t)), \
                    (trial, u, v, t, sigma, tau)


# ----------------------------------------------------------------------
# firing-rule simulator: structural certificates against brute force
# ----------------------------------------------------------------------
def _explore_inflight(tmg, cap=50000):
    """Exhaustive reachability under start/end (non-atomic) firing
    semantics.  Returns every reachable set of simultaneously in-flight
    transitions.  Independent of the cycle-based certificate: it only
    knows the firing rule."""
    places = tmg.places
    inputs = {t.name: [i for i, p in enumerate(places) if p.dst == t.name]
              for t in tmg.transitions}
    outputs = {t.name: [i for i, p in enumerate(places) if p.src == t.name]
               for t in tmg.transitions}
    start = (tuple(p.tokens for p in places), frozenset())
    seen = {start}
    frontier = [start]
    concurrent = set()
    while frontier:
        marking, inflight = frontier.pop()
        concurrent.add(inflight)
        nxt = []
        for t in tmg.transitions:
            n = t.name
            if n not in inflight and all(marking[i] >= 1
                                         for i in inputs[n]):
                m = list(marking)
                for i in inputs[n]:
                    m[i] -= 1
                nxt.append((tuple(m), inflight | {n}))
            if n in inflight:
                m = list(marking)
                for i in outputs[n]:
                    m[i] += 1
                nxt.append((tuple(m), inflight - {n}))
        for state in nxt:
            if state not in seen:
                seen.add(state)
                frontier.append(state)
        assert len(seen) < cap, "state space exceeded the test cap"
    return concurrent


@pytest.mark.parametrize("tmg", [
    pipeline_tmg(["a", "b", "c"], buffers=1),
    pipeline_tmg(["a", "b", "c", "d"], buffers=2),
    feedback_pipeline_tmg(["a", "b", "c", "d"], "c", "b", 1),
])
def test_structural_pairs_never_cofire_exhaustive(tmg):
    certified = exclusive_pairs(tmg)
    reachable = _explore_inflight(tmg)
    for inflight in reachable:
        for pair in certified:
            assert not (pair <= inflight), (sorted(pair), sorted(inflight))


def test_simulator_not_vacuous():
    """The brute-force explorer does find real concurrency — 2-token
    ping-pong neighbours co-fire somewhere — so the previous test's
    silence is meaningful."""
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    reachable = _explore_inflight(tmg)
    assert frozenset(("a", "b")) not in exclusive_pairs(tmg)
    assert any({"a", "b"} <= s for s in reachable)
    # and the structural certificate for the 1-token variant is honest:
    one = pipeline_tmg(["a", "b", "c"], buffers=1)
    assert frozenset(("a", "b")) in exclusive_pairs(one)


# ----------------------------------------------------------------------
# WAMI acceptance: strictly more pairs, pointwise-dominant fronts
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def wami_shared_session():
    sess = build_session("wami", "analytical", share_plm=True, workers=8,
                         verify_plans=True)
    sess.run()
    return sess


def test_wami_schedule_certifies_strictly_more_pairs(wami_shared_session):
    """The acceptance bar: on WAMI, every LP schedule's busy-interval
    certificate covers strictly more shareable pairs than the
    structural six-component LK clique (15 pairs)."""
    sess = wami_shared_session
    structural = exclusive_pairs(sess.tmg)
    assert len(structural) == 15          # C(6,2) of the LK loop
    assert sess.mapped
    for m in sess.mapped:
        assert m.schedule is not None
        src = compat_source_for(sess.tmg, m.schedule)
        assert src.structural == structural
        assert len(src.conditional) > 0, m.theta_planned
        assert len(src.pairs) > len(structural)
        # tiers are disjoint and honestly labelled
        assert not (src.conditional & src.structural)
        u, v = sorted(next(iter(src.conditional)))
        assert src.tier(u, v) == "schedule"


def test_wami_certified_pairs_never_cobusy(wami_shared_session):
    """Timed check on the real LP schedules: certified conditional pairs
    have disjoint busy windows under the absolute firing times."""
    sess = wami_shared_session
    m = sess.mapped[len(sess.mapped) // 2]
    sched = m.schedule
    period = sched.period
    src = compat_source_for(sess.tmg, sched)
    for pair in src.conditional:
        u, v = sorted(pair)
        for i in range(499):
            t = (i / 499) * period
            bu = ((t - sched.sigma[u]) % period) < sched.tau[u]
            bv = ((t - sched.sigma[v]) % period) < sched.tau[v]
            assert not (bu and bv), (u, v, t)


def test_wami_shared_front_pointwise_dominates(wami_shared_session):
    """The two-tier plan is selected only when cheaper, so every mapped
    point's system cost is <= the structural-only replan."""
    sess = wami_shared_session
    planner = sess.memory_planner
    saw_schedule_win = False
    for m in sess.mapped:
        assert m.memory_plan is not None
        synths = {o.component: o.synthesis for o in m.outcomes}
        reqs = planner.requirements(sess.ledger, synths)
        structural_only = planner.plan(reqs)
        assert m.cost_actual == m.memory_plan.system_cost
        assert m.cost_actual <= structural_only.system_cost + 1e-12
        if m.memory_plan.compat_tag is not None:
            saw_schedule_win = True
            assert m.memory_plan.compat_tag == m.schedule.tag()
            assert m.cost_actual < structural_only.system_cost
    # the schedule tier must actually win somewhere, else the whole
    # subsystem is dead weight
    assert saw_schedule_win


def test_wami_emitted_plans_verify(wami_shared_session):
    """The independent race detector re-proves every emitted plan (the
    session already ran with verify_plans=True; this re-checks the
    stored plans through the public API)."""
    sess = wami_shared_session
    for m in sess.mapped:
        assert verify_plan(m.memory_plan, sess.tmg, m.schedule) == []


# ----------------------------------------------------------------------
# the race detector catches tampered plans
# ----------------------------------------------------------------------
def _sound_two_member_plan():
    """A genuinely sound plan on a 1-token pipeline: a+b share."""
    tmg = pipeline_tmg(["a", "b", "c"], buffers=1)
    planner = PLMPlanner(tmg)
    reqs = [_mm2_requirement("a", 32768), _mm2_requirement("b", 16384),
            _mm2_requirement("c", 8192, ports=4)]
    plan_ = planner.plan(reqs)
    assert any(len(g.members) > 1 for g in plan_.groups)
    return plan_, tmg


def test_verifier_passes_sound_plan():
    plan_, tmg = _sound_two_member_plan()
    assert verify_plan(plan_, tmg) == []
    assert_plan_sound(plan_, tmg)          # must not raise


def _tamper(plan_, idx, **changes):
    groups = list(plan_.groups)
    groups[idx] = dataclasses.replace(groups[idx], **changes)
    return dataclasses.replace(plan_, groups=tuple(groups))


def test_verifier_flags_race():
    """Merging a structurally-concurrent pair (2-token neighbours) is a
    race, whatever the claimed areas say."""
    tmg = pipeline_tmg(["a", "b"], buffers=2)
    reqs = [_mm2_requirement("a", 32768), _mm2_requirement("b", 16384)]
    area, cap, bits, ports, banks = shared_area(reqs, MemGen())
    bad = MemoryPlan(groups=(MemoryGroup(
        members=("a", "b"), capacity=cap, word_bits=bits, ports=ports,
        area=area, area_private=sum(r.area_plm for r in reqs),
        banks=banks, requirements=tuple(reqs)),),
        area_memory=area, area_logic=0.1)
    rules = {v.rule for v in verify_plan(bad, tmg)}
    assert rules == {"V-RACE"}
    with pytest.raises(PlanVerificationError):
        assert_plan_sound(bad, tmg)


def test_verifier_flags_unknown_member():
    tmg = pipeline_tmg(["a", "b"], buffers=1)
    plan_, _ = _sound_two_member_plan()
    rules = {v.rule for v in verify_plan(plan_, tmg)}
    assert "V-RACE" in rules               # member c unknown to this TMG


def test_verifier_flags_tag_mismatch():
    plan_, tmg = _sound_two_member_plan()
    tagged = dataclasses.replace(plan_, compat_tag="theta=42")
    assert {v.rule for v in verify_plan(tagged, tmg)} == {"V-TAG"}
    wrong = Schedule(theta=7.0, sigma={}, tau={})
    assert {v.rule for v in verify_plan(tagged, tmg, wrong)} == {"V-TAG"}


def test_verifier_flags_area_and_guard_and_capacity():
    plan_, tmg = _sound_two_member_plan()
    idx = next(i for i, g in enumerate(plan_.groups)
               if len(g.members) > 1)
    g = plan_.groups[idx]
    # V-AREA: the recorded price disagrees with the shared model
    assert any(v.rule == "V-AREA"
               for v in verify_plan(_tamper(plan_, idx, area=g.area * 0.5),
                                    tmg))
    # V-GUARD: shared dearer than the private copies it replaces
    dearer = _tamper(plan_, idx, area=g.area_private * 2)
    assert any(v.rule == "V-GUARD" for v in verify_plan(dearer, tmg))
    # V-CAP: envelope no longer covers a member requirement
    shrunk = _tamper(plan_, idx, capacity=1)
    assert any(v.rule == "V-CAP" for v in verify_plan(shrunk, tmg))


def test_verifier_flags_merged_unsplittable():
    tmg = pipeline_tmg(["a", "b"], buffers=1)
    r0 = _mm2_requirement("a", 32768)
    r1 = PLMRequirement(component="b", capacity=0, word_bits=0, ports=1,
                        area_plm=0.0, area_logic=0.2)
    bad = MemoryPlan(groups=(MemoryGroup(
        members=("a", "b"), capacity=r0.capacity, word_bits=32, ports=2,
        area=r0.area_plm, area_private=r0.area_plm,
        requirements=(r0, r1)),),
        area_memory=r0.area_plm, area_logic=0.25)
    assert any(v.rule == "V-CAP" for v in verify_plan(bad, tmg))


def test_memory_plan_json_roundtrip():
    plan_, _ = _sound_two_member_plan()
    back = memory_plan_from_json(
        json.loads(json.dumps(memory_plan_to_json(plan_))))
    assert back == plan_


def test_session_strict_postpass_rejects_lying_planner():
    """verify_plans=True turns a dishonest memory planner into a loud
    failure instead of a silently-wrong front."""

    class LyingPlanner:
        def plan_point(self, tool, syntheses, schedule=None):
            reqs = [_mm2_requirement(n, 32768) for n in sorted(syntheses)]
            area, cap, bits, ports, banks = shared_area(reqs, MemGen())
            private = sum(r.area_plm for r in reqs)
            # claim a price neither the shared model nor the dominance
            # guard supports: dearer than the private copies it replaces
            lie = private * 1.5
            return MemoryPlan(groups=(MemoryGroup(
                members=tuple(sorted(syntheses)), capacity=cap,
                word_bits=bits, ports=ports, area=lie,
                area_private=private, banks=banks,
                requirements=tuple(reqs)),),
                area_memory=lie, area_logic=0.1)

    sess = build_session("fleet", "analytical", workers=1,
                         memory_planner=LyingPlanner(), verify_plans=True)
    with pytest.raises(PlanVerificationError):
        sess.run()


# ----------------------------------------------------------------------
# exhaustive optimal packing: the greedy optimality gate
# ----------------------------------------------------------------------
# recorded tolerance: across the gated <=8-component instances the
# greedy planner's worst observed gap to the certified optimum is 7.5%
# (path-compatibility instances, where seeding largest-first can split
# an optimal chain); the gate pins it below 8%.  Exactly optimal on the
# WAMI LK-clique sub-instance below and on 7 of the 10 random trials.
GREEDY_OPT_TOL = 1.08


def test_partitions_count_is_bell():
    assert sum(1 for _ in partitions(list("abcd"))) == 15    # Bell(4)
    assert sum(1 for _ in partitions([])) == 1


def test_optimal_packing_respects_certificates():
    tmg = pipeline_tmg(["a", "b", "c", "d"], buffers=1)    # path compat
    src = CompatSource.structural_for(tmg)
    reqs = [_mm2_requirement("a", 32768), _mm2_requirement("b", 30000),
            _mm2_requirement("c", 28000), _mm2_requirement("d", 26000)]
    best = optimal_plan(reqs, src)
    for g in best.groups:
        for i, u in enumerate(g.members):
            for v in g.members[i + 1:]:
                assert src.may_share(u, v)
    naive = sum(r.area_plm for r in reqs)
    assert best.area_memory <= naive + 1e-12


def test_greedy_within_tolerance_of_optimal():
    tmg = pipeline_tmg(["a", "b", "c", "d", "e", "f"], buffers=1)
    src = CompatSource.structural_for(tmg)
    rng = random.Random(11)
    planner = PLMPlanner(tmg)
    for trial in range(10):
        reqs = [_mm2_requirement(n, rng.randrange(4096, 131072, 1024),
                                 ports=rng.choice([1, 2, 4]))
                for n in "abcdef"]
        greedy = planner.plan(reqs)
        best = optimal_plan(reqs, src)
        assert greedy.area_memory >= best.area_memory - 1e-12, trial
        assert greedy.area_memory <= best.area_memory * GREEDY_OPT_TOL, \
            (trial, greedy.area_memory, best.area_memory)


def test_greedy_optimal_on_wami_lk_clique(wami_shared_session):
    """On the real WAMI LK-clique sub-instance (complete compatibility,
    6 components) greedy packing matches the exhaustive optimum."""
    sess = wami_shared_session
    lk = {"warp", "matrix_sub", "sd_update", "matrix_mul", "matrix_add",
          "matrix_resh"}
    planner = sess.memory_planner
    m = sess.mapped[0]
    synths = {o.component: o.synthesis for o in m.outcomes}
    reqs = [r for r in planner.requirements(sess.ledger, synths)
            if r.component in lk and r.capacity > 0]
    assert len(reqs) >= 5
    src = CompatSource.structural_for(sess.tmg)
    greedy = planner.plan(reqs)
    best = optimal_plan(reqs, src)
    assert greedy.area_memory <= best.area_memory * GREEDY_OPT_TOL
    assert math.isclose(greedy.area_memory, best.area_memory,
                        rel_tol=1e-9) or \
        greedy.area_memory <= best.area_memory


# ----------------------------------------------------------------------
# lint driver
# ----------------------------------------------------------------------
def test_lint_clean_on_checked_in_registry():
    import repro.apps.wami.pallas    # noqa: F401 — ensure registration
    import repro.apps.fleet          # noqa: F401
    assert lint_all() == []


def _broken_app(tmp_path):
    """An App seeded with one violation per rule family."""
    def tmg():
        return pipeline_tmg(["a", "b"], buffers=1)

    bad_store = tmp_path / "bad.json"
    bad_store.write_text(json.dumps(
        {"version": 1, "meta": {},
         "entries": {"a:p2:u1": 0.5, "nonsense-key": 1.0,
                     "a:p4:u1": -3.0}}))

    def spaces():
        return {"a": KnobSpace(clock_ns=1.0, min_ports=3, max_ports=3,
                               max_unrolls=2,
                               tile_sizes=(64, 64))}     # KNOB001+KNOB002
        # 'b' has no space and no fixed latency -> REG006

    return App(
        name="lint_seeded_test_app",
        description="deliberately violates one rule per family",
        tmg=tmg, knob_spaces=spaces,
        analytical=lambda: (_ for _ in ()).throw(RuntimeError("boom")),
        measurement_path=lambda t: str(tmp_path / ("missing.json"
                                                   if t == 7 else
                                                   "bad.json")),
        recorded_tiles=(7, 9),                           # 7 -> REG003
        default_tiles=(5,),                              # REG005
        parity_cases=lambda: [("x", 1, 2, ())],          # REG002
    )


def test_lint_catches_seeded_violations(tmp_path):
    findings = lint_app(_broken_app(tmp_path))
    rules = {f.rule for f in findings}
    assert {"REG001", "REG002", "REG003", "REG004", "REG005", "REG006",
            "KNOB001", "KNOB002"} <= rules
    # REG004 fired for both the malformed key and the negative wall
    reg4 = [f for f in findings if f.rule == "REG004"]
    assert len(reg4) == 2
    # findings render with their rule ID first (the CI log contract)
    assert all(str(f).startswith(f.rule) for f in findings)


def test_lint_cli_exit_codes(tmp_path, capsys):
    from repro.core.analysis import lint
    from repro.core import registry as reg
    assert lint.main(["--app", "wami"]) == 0
    app = _broken_app(tmp_path)
    reg.register_app(app)
    try:
        assert lint.main(["--app", app.name]) == 1
        err = capsys.readouterr().err
        assert "REG003" in err and "KNOB001" in err
    finally:
        reg._APPS.pop(app.name, None)


def test_lint_finding_is_stable_record():
    f = LintFinding("REG003", "wami", "tile=64", "missing")
    assert str(f) == "REG003 wami/tile=64: missing"


# ----------------------------------------------------------------------
# committed plan artifacts stay provable
# ----------------------------------------------------------------------
def test_committed_fig10_plan_artifacts_verify():
    """The checked-in fig10 share-plm sidecars re-prove from scratch —
    the same gate CI runs via `python -m repro.core.analysis.verify`."""
    from repro.core.analysis import verify as V
    fig10 = os.path.join(REPO, "artifacts", "bench", "fig10")
    files = [os.path.join(fig10, n) for n in sorted(os.listdir(fig10))
             if n.endswith(".plans.json")]
    assert files, "fig10 must commit *.plans.json sidecars"
    for path in files:
        n_points, violations = V.verify_plans_file(path)
        assert n_points > 0
        assert violations == [], path
