"""Differential battery: BatchPricer == scalar analytical path, bit-for-bit.

The pricing grid's contract (src/repro/core/pricing.py) is exact
equality, not approximation: every `Synthesis` a wrapped tool returns —
lam, area, states, feasibility, tile, detail dict — must equal the
scalar path's field-for-field, on the registered apps AND on randomized
component spaces / tile axes / noise seeds (the hypothesis property).
Ledger accounting must be equally invisible: a session run with
``batch_pricing=True`` keeps byte-identical fronts and invocation
counts under any worker count.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BatchPricer
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest
from repro.core.obs import LogicalClock, Tracer
from repro.core.registry import build_session, build_tool
from repro.core.xlatool import XLATool


def _pow2_ladder(top):
    return [1 << k for k in range(top.bit_length()) if (1 << k) <= top]


def _assert_same(pricer, tool, component, **kw):
    got = pricer.synthesize(component, **kw)
    want = tool.synthesize(component, **kw)
    assert got == want, (component, kw, got, want)
    return got


# ----------------------------------------------------------------------
# registered apps: wami (HLSTool) and fleet (XLATool), exhaustive planes
# ----------------------------------------------------------------------
def test_wami_hls_grid_bit_exact():
    tool = build_tool("wami")
    pricer = BatchPricer(tool)
    for component in tool.components:
        for ports in _pow2_ladder(8):
            for unrolls in range(1, 13):
                for cap in (None, 3, 7):
                    _assert_same(pricer, tool, component, unrolls=unrolls,
                                 ports=ports, max_states=cap)
    assert pricer.fallbacks == 0
    assert pricer.lookups > 0


def test_wami_tile_axis_and_clock_bit_exact():
    tool = build_tool("wami", share_plm=True)
    pricer = BatchPricer(tool)
    for component in list(tool.components)[:4]:
        for tile in (0, 64, 128, 256):
            for ports in (1, 4):
                for unrolls in (1, 5, 8):
                    for clock in (1.0, 0.75):
                        _assert_same(pricer, tool, component,
                                     unrolls=unrolls, ports=ports,
                                     tile=tile, clock_ns=clock)
    assert pricer.fallbacks == 0


def test_fleet_xla_grid_bit_exact():
    tool = build_tool("fleet")
    assert isinstance(tool, XLATool)
    pricer = BatchPricer(tool)
    for component in tool.components:
        for ports in range(1, 7):        # past max_ports=4: forces growth
            for unrolls in range(1, 11):
                for cap in (None, 5):    # XLATool ignores max_states
                    _assert_same(pricer, tool, component, unrolls=unrolls,
                                 ports=ports, max_states=cap)
    assert pricer.fallbacks == 0


def test_cdfg_facts_delegate_to_scalar_tool():
    tool = build_tool("wami")
    pricer = BatchPricer(tool)
    name = next(iter(tool.components))
    s = pricer.synthesize(name, unrolls=2, ports=2)
    assert pricer.cdfg_facts(name, s) == tool.cdfg_facts(name, s)


# ----------------------------------------------------------------------
# fallback paths: out-of-grid requests answer via the scalar tool
# ----------------------------------------------------------------------
def test_non_pow2_ports_fall_back_to_scalar():
    tool = build_tool("wami")
    pricer = BatchPricer(tool)
    name = next(iter(tool.components))
    before = pricer.fallbacks
    _assert_same(pricer, tool, name, unrolls=3, ports=3)
    assert pricer.fallbacks == before + 1


def test_xla_rejects_tile_knob_exactly_like_scalar():
    tool = build_tool("fleet")
    pricer = BatchPricer(tool)
    name = next(iter(tool.components))
    with pytest.raises(TypeError):
        tool.synthesize(name, unrolls=1, ports=1, tile=64)
    with pytest.raises(TypeError):
        pricer.synthesize(name, unrolls=1, ports=1, tile=64)


def test_unknown_component_raises_like_scalar():
    tool = build_tool("wami")
    pricer = BatchPricer(tool)
    with pytest.raises(KeyError):
        tool.synthesize("no-such", unrolls=1, ports=1)
    with pytest.raises(KeyError):
        pricer.synthesize("no-such", unrolls=1, ports=1)


# ----------------------------------------------------------------------
# wrap rules: grid only where the grid provably mirrors the tool
# ----------------------------------------------------------------------
def test_wrap_is_idempotent_and_selective():
    tool = build_tool("wami")
    pricer = BatchPricer.wrap(tool)
    assert isinstance(pricer, BatchPricer) and pricer.tool is tool
    assert BatchPricer.wrap(pricer) is pricer
    other = object()
    assert BatchPricer.wrap(other) is other


def test_wrap_passes_overridden_synthesize_through():
    """A subclass with its own synthesize (fault injection, gating,
    counting wrappers) carries semantics the grid cannot reproduce —
    wrap() must leave it scalar, and the constructor must refuse it."""

    class Broken(HLSTool):
        def synthesize(self, component, **kw):
            raise RuntimeError("seeded failure")

    spec = ComponentSpec("a", LoopNest(64, 2, 1, 8, 3, 6), 256, 256)
    broken = Broken({"a": spec})
    assert BatchPricer.wrap(broken) is broken
    with pytest.raises(TypeError):
        BatchPricer(broken)
    with pytest.raises(TypeError):
        BatchPricer(object())


# ----------------------------------------------------------------------
# observability: builds are memoized, grown by doubling, and traced
# ----------------------------------------------------------------------
def test_grid_builds_memoized_and_traced():
    tool = build_tool("wami")
    pricer = BatchPricer(tool)
    tr = Tracer(clock=LogicalClock())
    pricer.tracer = tr
    name = next(iter(tool.components))
    pricer.synthesize(name, unrolls=1, ports=1)
    assert pricer.grid_builds == 1
    first_points = pricer.grid_points_priced
    pricer.synthesize(name, unrolls=8, ports=8)   # inside the min extent
    assert pricer.grid_builds == 1
    pricer.synthesize(name, unrolls=17, ports=8)  # forces doubled rebuild
    assert pricer.grid_builds == 2
    assert pricer.grid_points_priced > first_points
    spans = tr.spans("pricing.batch")
    assert len(spans) == 2
    assert spans[0].attrs["component"] == name
    assert spans[0].attrs["n"] > 0


# ----------------------------------------------------------------------
# ledger invisibility: sessions with batch_pricing keep identical books
# ----------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 3])
def test_session_ledger_counts_and_front_identical(workers):
    plain = build_session("wami", workers=workers)
    res_plain = plain.run()
    batched = build_session("wami", workers=workers, batch_pricing=True)
    res_batched = batched.run()
    assert dict(plain.ledger.invocations) == dict(batched.ledger.invocations)
    assert dict(plain.ledger.failed) == dict(batched.ledger.failed)
    assert repr(res_plain.planned) == repr(res_batched.planned)
    assert repr(res_plain.mapped) == repr(res_batched.mapped)


# ----------------------------------------------------------------------
# property: randomized spaces, tiles, noise seeds — still bit-exact
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(trip=st.integers(1, 512), gamma_r=st.integers(0, 4),
       gamma_w=st.integers(0, 3), arith=st.integers(1, 32),
       dep=st.integers(1, 8), live=st.integers(1, 16),
       has_plm=st.booleans(), words=st.integers(16, 2048),
       noise=st.sampled_from([0.0, 1.0, 2.5]),
       seed=st.sampled_from(["cosmos", "alt"]),
       base_tile=st.sampled_from([0, 32]),
       max_ports=st.sampled_from([2, 4, 8]),
       max_unrolls=st.integers(2, 12))
def test_property_random_hls_space_bit_exact(
        trip, gamma_r, gamma_w, arith, dep, live, has_plm, words,
        noise, seed, base_tile, max_ports, max_unrolls):
    loop = LoopNest(trip, gamma_r, gamma_w, arith, dep, live, has_plm)
    spec = ComponentSpec("rand", loop, words, max(1, words // 2),
                         base_tile=base_tile)
    tool = HLSTool({"rand": spec}, noise=noise, seed=seed)
    pricer = BatchPricer(tool)
    tiles = (0, 16, 48) if base_tile else (0,)
    for tile in tiles:
        for ports in _pow2_ladder(max_ports):
            for unrolls in range(1, max_unrolls + 1):
                for cap in (None, dep):
                    _assert_same(pricer, tool, "rand", unrolls=unrolls,
                                 ports=ports, max_states=cap, tile=tile)
    assert pricer.fallbacks == 0
