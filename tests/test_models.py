"""Per-arch smoke tests (assignment requirement f) + serve consistency.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes
and the absence of NaNs; the serve test checks prefill+decode equals a
one-longer prefill (cache correctness).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, make_synthetic_batch

ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(built, arch):
    cfg, model, params = built[arch]
    batch = make_synthetic_batch(cfg, 2, 32)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random init => loss near ln(vocab)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(built, arch):
    cfg, model, params = built[arch]
    batch = make_synthetic_batch(cfg, 2, 16)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(built, arch):
    cfg, model, params = built[arch]
    batch = make_synthetic_batch(cfg, 2, 9)
    toks = batch["tokens"]
    b8 = dict(batch, tokens=toks[:, :8])
    if "mrope_positions" in batch:
        b8["mrope_positions"] = batch["mrope_positions"][:, :, :8]
    logits_a, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len=16))(
        params, batch)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=16))(
        params, b8)
    logits_b, cache2 = jax.jit(model.decode_step)(params, toks[:, 8:9], cache)
    scale = float(jnp.abs(logits_a).max()) + 1e-9
    rel = float(jnp.abs(logits_a - logits_b).max()) / scale
    tol = 5e-2 if cfg.n_experts else 5e-5   # MoE capacity drops differ
    assert rel < tol, f"{arch}: rel={rel}"
    assert int(cache2["len"]) == 9


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-2.7b"])
def test_ssm_decode_chain_matches_prefill(built, arch):
    """Token-by-token decode must reproduce the prefill logits path."""
    cfg, model, params = built[arch]
    batch = make_synthetic_batch(cfg, 1, 6)
    toks = batch["tokens"]
    # full prefill logits at last position
    full, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len=8))(
        params, batch)
    # prefill 1 token, decode the rest
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=8))(
        params, dict(batch, tokens=toks[:, :1]))
    logits = None
    for t in range(1, 6):
        logits, cache = jax.jit(model.decode_step)(params, toks[:, t:t + 1],
                                                   cache)
    rel = float(jnp.abs(full - logits).max()) / (float(jnp.abs(full).max()) + 1e-9)
    assert rel < 1e-3, f"{arch}: rel={rel}"


def test_param_counts_sane():
    """Full configs must land near their nameplate parameter counts."""
    approx = {
        "qwen2-0.5b": 0.5e9, "gemma2-9b": 9e9, "starcoder2-7b": 7e9,
        "nemotron-4-15b": 15e9, "kimi-k2-1t-a32b": 1.0e12,
        "phi3.5-moe-42b-a6.6b": 42e9, "mamba2-780m": 0.78e9,
        "qwen2-vl-72b": 72e9, "zamba2-2.7b": 2.7e9,
        "whisper-large-v3": 1.5e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.8 * want, f"{arch}: {got:.2e} vs {want:.2e}"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert active < 0.1 * cfg.param_count()
    assert 15e9 < active < 60e9          # nameplate: ~32B active
