"""SoC composition: budgets, traffic mixes, the greedy-vs-exhaustive
allocators, independent re-verification, and the SOC001 provenance lint
(docs/soc.md).

The expensive part — resolving each app's system-level Pareto front
through the registry — happens once per module (the ``fronts`` fixture
runs one fresh :class:`SoCComposer` front resolution); a second fresh
resolution inside the determinism test pins the whole pipeline
byte-identical across independent runs.
"""

import dataclasses
import json

import pytest

from repro.core.registry import list_apps
from repro.core.soc import (BUDGET_PRESETS, DEFAULT_DEMANDS, SoCBudget,
                            TrafficMix, get_budget)
from repro.core.soc.budget import REF_TECH_NM, TECH_NODES
from repro.core.soc.compose import (Allocation, BudgetInfeasibleError,
                                    Composition, SoCComposer,
                                    greedy_composition, operating_points,
                                    optimal_composition)
from repro.core.soc.verify import (CompositionVerificationError,
                                   assert_composition_sound,
                                   verify_composition)

MIX_SPEC = "wami=0.6,fleet=0.4"

#: gate budgets where replica granularity does not bite — greedy must
#: equal the exhaustive packer exactly (tests pin this, the bench pins
#: the one budget where granularity does: gap <= 0.40% at (40, 16, 64))
EXACT_GATES = ((30.0, 12.0, 64.0), (60.0, 25.0, 64.0), (25.0, 10.0, 32.0),
               (80.0, 30.0, 96.0), (100.0, 40.0, 128.0))
PINNED_GAP_GATE = (40.0, 16.0, 64.0)
PINNED_MAX_GAP = 0.004


@pytest.fixture(scope="module")
def mix():
    return TrafficMix.parse(MIX_SPEC, name="wami60_fleet40")


@pytest.fixture(scope="module")
def fronts(mix):
    """One fresh front resolution for the committed two-app mix —
    every allocator test prices against these."""
    return SoCComposer(get_budget("sys_medium"), mix, workers=8).fronts()


def _gate(area, power, bw):
    return SoCBudget(name="gate", area_mm2=area, power_w=power, bw_gbps=bw)


# ----------------------------------------------------------------------
# budgets: presets, validation, tech-node scaling
# ----------------------------------------------------------------------
def test_budget_presets_resolve_and_validate():
    assert set(BUDGET_PRESETS) == {"sys_small", "sys_medium", "sys_large"}
    b = get_budget("sys_medium")
    assert (b.area_mm2, b.power_w, b.bw_gbps) == (200.0, 80.0, 256.0)
    assert b.tech_nm == REF_TECH_NM
    with pytest.raises(KeyError, match="sys_small"):
        get_budget("sys_huge")          # listing error names the presets
    with pytest.raises(ValueError, match="area_mm2"):
        SoCBudget(name="bad", area_mm2=-1.0, power_w=1.0, bw_gbps=1.0)
    with pytest.raises(KeyError, match="known nodes"):
        SoCBudget(name="bad", area_mm2=1.0, power_w=1.0, bw_gbps=1.0,
                  tech_nm=28)


def test_tech_scaling_shrinks_area_and_boosts_bandwidth():
    b45 = get_budget("sys_medium")
    b22 = b45.at_tech(22)
    # the chip envelopes are fixed silicon/thermal limits; re-anchoring
    # scales what a design *charges*, and the DRAM interface speedup
    assert (b22.area_mm2, b22.power_w) == (b45.area_mm2, b45.power_w)
    assert b22.bw_gbps > b45.bw_gbps
    # a fixed reference-node design gets cheaper and cooler at 22nm
    assert b22.scale_area(10.0) < b45.scale_area(10.0)
    assert b22.power_of(10.0) < b45.power_of(10.0)
    assert b45.scale_area(10.0) == 10.0
    for nm in TECH_NODES:
        b45.at_tech(nm)                 # every table row resolves
    # JSON round-trip preserves the anchor
    assert SoCBudget.from_json(b22.to_json()) == b22


# ----------------------------------------------------------------------
# traffic mixes: parsing, pricing defaults, registry resolution
# ----------------------------------------------------------------------
def test_traffic_mix_parse_applies_default_pricing():
    m = TrafficMix.parse(MIX_SPEC)
    assert m.name == "wami60_fleet40"   # derived from the shares
    assert m.shares() == {"wami": 0.6, "fleet": 0.4}
    wami = m.demand("wami")
    assert wami.share_plm and wami.area_scale == 1.0
    assert wami.bytes_per_request == DEFAULT_DEMANDS["wami"][
        "bytes_per_request"]
    fleet = m.demand("fleet")
    assert fleet.area_scale == pytest.approx(2.0e-12)
    # per-call overrides beat the defaults
    m2 = TrafficMix.parse(MIX_SPEC, wami={"share_plm": False})
    assert not m2.demand("wami").share_plm
    assert TrafficMix.from_json(m.to_json()) == m


def test_traffic_mix_rejects_malformed_specs():
    with pytest.raises(ValueError, match="app=share"):
        TrafficMix.parse("wami:0.6")
    with pytest.raises(ValueError, match="empty mix"):
        TrafficMix.parse(",")
    with pytest.raises(ValueError, match="duplicate"):
        TrafficMix.parse("wami=0.5,wami=0.5")
    with pytest.raises(ValueError, match="share must be positive"):
        TrafficMix.parse("wami=0")
    m = TrafficMix.parse(MIX_SPEC)
    with pytest.raises(KeyError, match="apps in mix"):
        m.demand("autoshard")


def test_unknown_app_raises_the_registry_listing_error():
    m = TrafficMix.parse("nosuchapp=1.0")
    with pytest.raises(KeyError, match="wami"):
        m.resolve()                     # listing names the real apps


# ----------------------------------------------------------------------
# infeasibility: the violated envelope is named
# ----------------------------------------------------------------------
def test_infeasible_mix_names_the_violated_budget(mix, fronts):
    tiny = _gate(1.0, 100.0, 100.0)     # even one replica each overflows
    with pytest.raises(BudgetInfeasibleError) as ei:
        greedy_composition(tiny, mix, fronts)
    e = ei.value
    assert e.budget_field == "area_mm2"
    assert e.mix_name == "wami60_fleet40" and e.budget_name == "gate"
    assert e.need > e.limit == 1.0
    assert "area_mm2" in str(e) and "'gate'" in str(e)
    # the exhaustive packer refuses identically, and the envelopes are
    # checked in deterministic (area, power, bw) order
    with pytest.raises(BudgetInfeasibleError):
        optimal_composition(tiny, mix, fronts)
    with pytest.raises(BudgetInfeasibleError) as ei2:
        greedy_composition(_gate(100.0, 0.5, 100.0), mix, fronts)
    assert ei2.value.budget_field == "power_w"
    with pytest.raises(BudgetInfeasibleError) as ei3:
        greedy_composition(_gate(100.0, 100.0, 0.01), mix, fronts)
    assert ei3.value.budget_field == "bw_gbps"


# ----------------------------------------------------------------------
# greedy vs exhaustive: exact on granularity-free gates, pinned gap
# where replica packing bites
# ----------------------------------------------------------------------
def test_greedy_matches_exhaustive_on_small_instances(mix, fronts):
    for area, power, bw in EXACT_GATES:
        gate = _gate(area, power, bw)
        g = greedy_composition(gate, mix, fronts)
        o = optimal_composition(gate, mix, fronts)
        assert g.sustained_throughput == pytest.approx(
            o.sustained_throughput, rel=1e-12), (area, power, bw)
        assert_composition_sound(g, fronts=fronts)
        assert_composition_sound(o, fronts=fronts)
        assert g.method == "greedy" and o.method == "exhaustive"


def test_pinned_gap_where_replica_granularity_bites(mix, fronts):
    gate = _gate(*PINNED_GAP_GATE)
    g = greedy_composition(gate, mix, fronts)
    o = optimal_composition(gate, mix, fronts)
    gap = ((o.sustained_throughput - g.sustained_throughput)
           / o.sustained_throughput)
    # greedy is never better than the certified optimum, and the gap is
    # the documented replica-granularity artifact, within its pin
    assert 0.0 <= gap <= PINNED_MAX_GAP
    assert_composition_sound(g, fronts=fronts)


def test_exhaustive_guards_mirror_packing(mix, fronts):
    demands = mix.demands + tuple(
        dataclasses.replace(mix.demands[0], app=f"ghost{i}")
        for i in range(3))
    wide = TrafficMix(name="wide", demands=demands)
    ghost_fronts = dict(fronts, **{f"ghost{i}": fronts["wami"]
                                   for i in range(3)})
    with pytest.raises(ValueError, match="max_apps"):
        optimal_composition(get_budget("sys_large"), wide, ghost_fronts)
    with pytest.raises(ValueError, match="max_configs"):
        optimal_composition(get_budget("sys_large"), mix, fronts,
                            max_configs=3)


# ----------------------------------------------------------------------
# determinism: two independent end-to-end runs, byte-identical
# ----------------------------------------------------------------------
def test_composition_is_byte_identical_across_fresh_runs(mix, fronts):
    budget = get_budget("sys_medium")
    ref = greedy_composition(budget, mix, fronts)
    # a second, completely fresh pipeline: new composer, its own
    # registry-resolved fronts, its own allocation walk
    fresh = SoCComposer(budget, TrafficMix.parse(MIX_SPEC,
                                                 name="wami60_fleet40"),
                        workers=8).compose()
    assert (json.dumps(fresh.to_json(), sort_keys=True)
            == json.dumps(ref.to_json(), sort_keys=True))
    # and the headline numbers are the committed trajectory's
    assert fresh.sustained_throughput == pytest.approx(8.26146, rel=1e-4)
    assert fresh.area_mm2 == pytest.approx(159.281, rel=1e-4)
    assert fresh.power_w <= budget.power_w        # power-bound chip
    assert fresh.throughput_per_area == pytest.approx(0.0518673, rel=1e-4)
    rt = Composition.from_json(fresh.to_json())
    assert (json.dumps(rt.to_json(), sort_keys=True)
            == json.dumps(fresh.to_json(), sort_keys=True))


# ----------------------------------------------------------------------
# registry round-trip: every registered app composes solo
# ----------------------------------------------------------------------
def test_every_registered_app_composes_solo(fronts):
    for app in list_apps():
        solo = TrafficMix.parse(f"{app.name}=1.0", name=f"{app.name}_solo")
        composer = SoCComposer(
            get_budget("sys_large"), solo,
            fronts={app.name: fronts[app.name]} if app.name in fronts
            else None, workers=8)
        comp = composer.compose()
        assert_composition_sound(comp, fronts=composer.fronts())
        (alloc,) = comp.allocations
        assert alloc.app == app.name and alloc.replicas >= 1
        assert comp.sustained_throughput == pytest.approx(alloc.capacity)


# ----------------------------------------------------------------------
# the independent re-checker catches tampering
# ----------------------------------------------------------------------
def _rules(comp, fronts=None):
    return sorted({v.rule for v in verify_composition(comp,
                                                      fronts=fronts)})


def test_verify_passes_the_real_composition(mix, fronts):
    comp = greedy_composition(get_budget("sys_medium"), mix, fronts)
    assert verify_composition(comp, fronts=fronts) == []


def test_verify_catches_tampering(mix, fronts):
    comp = greedy_composition(get_budget("sys_medium"), mix, fronts)

    # inflate the throughput claim -> C-THETA
    lied = dataclasses.replace(
        comp, sustained_throughput=comp.sustained_throughput * 2)
    assert "C-THETA" in _rules(lied)

    # shrink the budget after the fact -> the totals no longer fit
    shrunk = dataclasses.replace(
        comp, budget=dataclasses.replace(comp.budget, area_mm2=10.0))
    assert "C-AREA" in _rules(shrunk)

    # drop an allocation -> C-REPL (a demand goes unserved)
    dropped = dataclasses.replace(comp,
                                  allocations=comp.allocations[:1])
    assert "C-REPL" in _rules(dropped)

    # tamper a point's recorded area charge -> C-PRICE
    a0 = comp.allocations[0]
    priced = dataclasses.replace(comp, allocations=(
        dataclasses.replace(a0, point=dataclasses.replace(
            a0.point, area_mm2=a0.point.area_mm2 * 0.5)),
    ) + comp.allocations[1:])
    assert "C-PRICE" in _rules(priced)

    # a point that is not on the app's front -> C-FRONT
    off = dataclasses.replace(comp, allocations=(
        dataclasses.replace(a0, point=dataclasses.replace(
            a0.point, theta=a0.point.theta * 1.5)),
    ) + comp.allocations[1:])
    assert "C-FRONT" in _rules(off, fronts)

    with pytest.raises(CompositionVerificationError, match="C-THETA"):
        assert_composition_sound(lied)


def test_operating_points_drop_unusable_points(mix, fronts):
    budget = get_budget("sys_medium")
    demand = mix.demand("wami")
    pts = operating_points(fronts["wami"], demand, budget)
    assert [p.index for p in pts] == sorted(p.index for p in pts)
    assert all(p.theta > 0 and p.area_mm2 > 0 for p in pts)
    with pytest.raises(ValueError, match="no usable operating point"):
        operating_points([], demand, budget)


# ----------------------------------------------------------------------
# SOC001: committed artifacts must carry their provenance
# ----------------------------------------------------------------------
def test_soc001_flags_artifacts_without_provenance(tmp_path, mix, fronts):
    from repro.core.analysis.lint import _lint_soc_artifacts
    comp = greedy_composition(get_budget("sys_medium"), mix, fronts)
    good = tmp_path / "good.composition.json"
    good.write_text(json.dumps(comp.to_json(), sort_keys=True))
    doc = comp.to_json()
    del doc["budget"]
    doc["mix"] = {"name": "anonymous"}       # no demands either
    bad = tmp_path / "bad.composition.json"
    bad.write_text(json.dumps(doc, sort_keys=True))

    findings = []
    _lint_soc_artifacts(findings, root=str(tmp_path))
    assert all(f.rule == "SOC001" for f in findings)
    subjects = {f.subject for f in findings}
    assert subjects == {"bad.composition.json"}
    details = " ".join(f.detail for f in findings)
    assert "budget" in details and "demands" in details


def test_soc001_accepts_the_committed_artifacts():
    """The checked-in bench artifacts satisfy the provenance rule (the
    repo-level lint runs over them on every push)."""
    from repro.core.analysis.lint import _lint_soc_artifacts
    findings = []
    _lint_soc_artifacts(findings)
    assert [str(f) for f in findings] == []
