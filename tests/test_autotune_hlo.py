"""COSMOS-TPU autotune pricing + the trip-count-aware HLO analyzer."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.configs import SHAPES, get_config
from repro.core.autotune import (HBM_BYTES_PER_CHIP, XLAOracle,
                                 choose_train_knobs, price_train_step)
from repro.core.oracle import OracleLedger
from repro.launch.hlo_analysis import (CollectiveStats, analyze_hlo,
                                       parse_collectives, roofline_terms)
from repro.optim import (AdamWConfig, apply_updates, apply_updates_q8,
                         init_opt, init_opt_q8)

MESH = {"data": 16, "model": 16}
TRAIN = SHAPES[0]


# ----------------------------------------------------------------------
# autotune pricing
# ----------------------------------------------------------------------
def test_price_monotone_in_microbatches():
    cfg = get_config("gemma2-9b")
    prices = [price_train_step(cfg, TRAIN, MESH, microbatches=mb,
                               remat="full").est_bytes
              for mb in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(prices, prices[1:]))


def test_choose_knobs_fits_when_possible():
    for arch in ("gemma2-9b", "qwen2-0.5b", "zamba2-2.7b", "mamba2-780m"):
        plan = choose_train_knobs(get_config(arch), TRAIN, MESH)
        assert plan.est_bytes <= HBM_BYTES_PER_CHIP, arch


def test_choose_knobs_reports_honest_deficit():
    """kimi-k2 at 256 chips cannot fit — the planner must say so, not lie."""
    plan = choose_train_knobs(get_config("kimi-k2-1t-a32b"), TRAIN, MESH)
    assert plan.est_bytes > HBM_BYTES_PER_CHIP
    assert not plan.fits


def test_choose_knobs_matches_manual_ladder_walk():
    """The XLAOracle walk must reproduce the seed's sequential ladder."""
    from repro.core.autotune import _LADDER, _mesh_sizes
    for arch in ("gemma2-9b", "zamba2-2.7b", "kimi-k2-1t-a32b"):
        cfg = get_config(arch)
        accum = "bfloat16" if cfg.param_count() > 30e9 else "float32"
        dp, _ = _mesh_sizes(MESH)
        want = None
        for rung in _LADDER:
            if TRAIN.global_batch // dp < rung["microbatches"]:
                break
            plan = price_train_step(cfg, TRAIN, MESH,
                                    microbatches=rung["microbatches"],
                                    remat=rung["remat"], accum_dtype=accum)
            want = plan
            if plan.est_bytes <= HBM_BYTES_PER_CHIP * 0.90:
                break
        got = choose_train_knobs(cfg, TRAIN, MESH)
        assert got == want, arch


def test_choose_knobs_shared_ledger_caches_replans():
    """Planning the same stage twice through one ledger is free."""
    led = OracleLedger(XLAOracle())
    choose_train_knobs(get_config("gemma2-9b"), TRAIN, MESH, ledger=led)
    n = led.total()
    assert n > 0
    plan = choose_train_knobs(get_config("gemma2-9b"), TRAIN, MESH,
                              ledger=led)
    assert led.total() == n               # all rungs were cache hits
    assert plan == choose_train_knobs(get_config("gemma2-9b"), TRAIN, MESH)
    # a mesh change is a new stage: characterization-style re-pricing
    choose_train_knobs(get_config("gemma2-9b"), TRAIN,
                       {"data": 8, "model": 16}, ledger=led)
    assert led.total() > n


def test_remat_ladder_ordering():
    cfg = get_config("gemma2-9b")
    dots = price_train_step(cfg, TRAIN, MESH, microbatches=8, remat="dots")
    full = price_train_step(cfg, TRAIN, MESH, microbatches=8, remat="full")
    none = price_train_step(cfg, TRAIN, MESH, microbatches=8, remat="none")
    assert full.est_bytes < dots.est_bytes < none.est_bytes


# ----------------------------------------------------------------------
# HLO analyzer
# ----------------------------------------------------------------------
def _flops(fn, *specs):
    txt = jax.jit(fn).lower(*specs).compile().as_text()
    return analyze_hlo(txt)


def test_analyzer_scan_equals_unrolled():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scan_mm(x, w):
        return lax.scan(lambda c, _: (c @ w, None), x, None, length=7)[0]

    def unroll_mm(x, w):
        for _ in range(7):
            x = x @ w
        return x

    a = _flops(scan_mm, x, w)
    b = _flops(unroll_mm, x, w)
    want = 7 * 2 * 128 ** 3
    assert a.flops == pytest.approx(want, rel=1e-6)
    assert b.flops == pytest.approx(want, rel=1e-6)


def test_analyzer_nested_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            return lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                            length=5)[0], None
        return lax.scan(outer, x, None, length=3)[0]

    a = _flops(nested, x, w)
    assert a.flops == pytest.approx(15 * 2 * 64 ** 3, rel=1e-6)


def test_collective_ring_model():
    s = CollectiveStats()
    s.add("all-reduce", 1000.0, 4)     # 2*(3/4)*1000
    s.add("all-gather", 1000.0, 4)     # (3/4)*1000
    s.add("collective-permute", 1000.0, 4)
    assert s.per_op["all-reduce"] == pytest.approx(1500.0)
    assert s.per_op["all-gather"] == pytest.approx(750.0)
    assert s.per_op["collective-permute"] == pytest.approx(1000.0)


def test_roofline_bound_selection():
    t = roofline_terms(flops_per_device=197e12, bytes_per_device=0,
                       collective_bytes=0)
    assert t["bound"] == "compute" and t["t_compute_s"] == pytest.approx(1.0)
    t = roofline_terms(flops_per_device=0, bytes_per_device=819e9,
                       collective_bytes=0)
    assert t["bound"] == "memory"
    t = roofline_terms(flops_per_device=0, bytes_per_device=0,
                       collective_bytes=50e9)
    assert t["bound"] == "collective"


# ----------------------------------------------------------------------
# 8-bit moments
# ----------------------------------------------------------------------
def test_q8_matches_fp32_trajectory():
    params = {"w": jnp.array([[3.0, -2.0, 1.0, 4.0]] * 2)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p32, s32 = params, init_opt(params)
    pq8, sq8 = params, init_opt_q8(params)
    for _ in range(100):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p32)
        p32, s32, _ = apply_updates(cfg, p32, g, s32)
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(pq8)
        pq8, sq8, _ = apply_updates_q8(cfg, pq8, g, sq8)
    # both converge to ~0; trajectories agree loosely
    assert float(jnp.abs(p32["w"]).max()) < 0.05
    assert float(jnp.abs(pq8["w"]).max()) < 0.05


def test_q8_state_is_4x_smaller():
    params = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
    b32 = sum(x.size * x.dtype.itemsize
              for x in jax.tree.leaves(init_opt(params)))
    bq8 = sum(x.size * x.dtype.itemsize
              for x in jax.tree.leaves(init_opt_q8(params)))
    assert b32 / bq8 > 3.9


def test_q8_trains_real_lm():
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train import TrainStepConfig, make_train_step
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3),
        TrainStepConfig(remat="none", quantized_moments=True,
                        total_steps=40)))
    opt = init_opt_q8(params)
    src = SyntheticLM(vocab=cfg.vocab, seed=5)
    losses = []
    for i in range(30):
        b = src.batch(step=i, shard=0, n_shards=1, batch=8, seq=32)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.02
