"""The fleet app (flash_attention + ssd_scan): the first non-WAMI
workload through the full cosmos_dse + PLM-planner path, on both the
analytical and the calibrated-measured backends."""

import pytest

from repro.apps.fleet import (fleet_calibrated_tool, fleet_kernel_specs,
                              fleet_knob_spaces, fleet_pallas_oracle,
                              fleet_session, fleet_tmg, fleet_unit_system,
                              fleet_xla_tool)
from repro.core import build_session, build_tool, get_app
from repro.core.plm.compat import exclusive_pairs


def _front(res):
    return [(p.perf, p.cost) for p in res.pareto()]


# ----------------------------------------------------------------------
# system model
# ----------------------------------------------------------------------
def test_fleet_tmg_certifies_the_stages_exclusive():
    """buffers=1 channels serialize the two stages, so the PLM planner
    may pack both onto one shared VMEM pool."""
    assert frozenset(("flash_attention", "ssd_scan")) \
        in exclusive_pairs(fleet_tmg())


def test_kernel_specs_divisibility_matches_the_real_grids():
    specs = fleet_kernel_specs()
    fa, ssd = specs["flash_attention"], specs["ssd_scan"]
    assert fa.divisible(2, 4) and fa.divisible(4, 8)
    assert not fa.divisible(3, 4) and not fa.divisible(2, 5)
    assert ssd.divisible(4, 8) and not ssd.divisible(4, 5)


# ----------------------------------------------------------------------
# end-to-end, both backends
# ----------------------------------------------------------------------
def test_fleet_analytical_end_to_end():
    res = build_session("fleet", "analytical", workers=4).run()
    assert len(res.mapped) >= 5
    assert set(res.invocations) == {"flash_attention", "ssd_scan"}
    assert all(res.invocations[n] > 0 for n in res.invocations)
    assert res.theta_max > res.theta_min > 0


@pytest.mark.slow
def test_fleet_calibrated_measured_end_to_end_deterministic():
    """The checked-in interpret recording drives the measured backend
    deterministically (replay == replay, byte for byte), with the
    Fig. 11 ledger counting both stages."""
    r1 = fleet_session(backend="pallas", workers=4).run()
    r2 = fleet_session(backend="pallas", workers=4).run()
    assert _front(r1) == _front(r2)
    assert r1.invocations == r2.invocations
    assert set(r1.invocations) == {"flash_attention", "ssd_scan"}
    # at least one mapped point per stage replayed a measured wall
    for comp in ("flash_attention", "ssd_scan"):
        assert any("wall_s" in (o.synthesis.detail or {})
                   for m in r1.mapped for o in m.outcomes
                   if o.component == comp)


@pytest.mark.slow
def test_fleet_share_plm_groups_the_stages():
    """share_plm on the measured backend: the certified-exclusive
    stages share one VMEM pool and the planned cost dominates the
    naive sum pointwise (strictly somewhere)."""
    tool = fleet_pallas_oracle("replay")
    res = build_session("fleet", "pallas", tool=tool, share_plm=True,
                        workers=4).run()
    assert all(m.cost_actual <= m.cost_unshared + 1e-9 for m in res.mapped)
    assert any(m.cost_actual < m.cost_unshared * (1 - 1e-12)
               for m in res.mapped)
    groups = {g for m in res.mapped for g in m.plm_groups}
    assert ("flash_attention", "ssd_scan") in groups


# ----------------------------------------------------------------------
# calibration
# ----------------------------------------------------------------------
def test_fleet_calibration_fits_from_the_recording():
    units = fleet_unit_system()
    assert units.unit == "bytes"
    assert units.area_scale > 0 and units.area_points > 0
    for comp in ("flash_attention", "ssd_scan"):
        assert units.lam.scale(comp) > 0
        assert units.lam.points[comp] > 0
    cal = fleet_calibrated_tool()
    raw = fleet_xla_tool().synthesize("ssd_scan", unrolls=2, ports=2)
    scaled = cal.synthesize("ssd_scan", unrolls=2, ports=2)
    assert scaled.lam == pytest.approx(
        raw.lam * units.lam.scale("ssd_scan"))
    assert scaled.area == pytest.approx(raw.area * units.area_scale)


def test_fleet_registry_round_trip():
    app = get_app("fleet")
    assert app.kernel_specs is not None
    assert set(app.knob_spaces()) == set(fleet_knob_spaces())
    oracle = build_tool("fleet", "pallas", missing="fallback")
    s = oracle.synthesize("flash_attention", unrolls=1, ports=1)
    assert s.feasible and "wall_s" in s.detail      # the recorded point
