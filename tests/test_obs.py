"""Observability layer: tracer/metrics units, determinism, reconciliation.

The contract under test (docs/observability.md):

  * two identical runs under a :class:`LogicalClock` export
    byte-identical JSONL and Chrome ``trace_event`` artifacts — the CI
    determinism gate;
  * span nesting mirrors the session's phase structure;
  * every evaluated point carries exactly one outcome tag from
    ``fresh | cache_hit | inflight_join | replay``, and the traced
    tags reconcile with the ledger's Fig. 11 invocation totals;
  * the metrics registry is lock-consistent and create-on-first-use,
    with type conflicts rejected loudly.
"""

import json
import threading

import pytest

from repro.core import (DSEQuery, ExplorationSession, HLSTool, KnobSpace,
                        LogicalClock, MetricsRegistry, NULL_TRACER,
                        OracleLedger, PersistentOracleCache, SharedOracle,
                        Tracer, pipeline_tmg)
from repro.core.hlsim import ComponentSpec, LoopNest
from repro.core.obs import OUTCOMES, validate_chrome, validate_jsonl
from repro.core.oracle import InvocationRequest
from repro.core.registry import _APPS, App, register_app
from repro.serve import DSEService


def _system():
    specs = {
        "a": ComponentSpec("a", LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
        "b": ComponentSpec("b", LoopNest(128, 1, 1, 4, 2, 4), 512, 512),
    }
    tmg = pipeline_tmg(list(specs), buffers=2)
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
              for n in specs}
    return specs, tmg, spaces


def _traced_run(tracer=None):
    specs, tmg, spaces = _system()
    tracer = tracer or Tracer(clock=LogicalClock())
    s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3,
                           tracer=tracer)
    s.run()
    return s, tracer


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("depth")
    g.set(3)
    g.add(-1)
    assert g.value == 2
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["n"] == 5
    assert snap["depth"] == 2
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["buckets"] == {"le_0.1": 1, "le_1": 1, "le_inf": 1}
    assert snap["lat"]["sum"] == pytest.approx(5.55)


def test_registry_create_on_first_use_and_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")       # same instance
    with pytest.raises(TypeError):
        reg.gauge("x")                                # wrong type
    with pytest.raises(ValueError):
        reg.histogram("h", buckets=(1.0, 2.0)) and \
            reg.histogram("h", buckets=(1.0, 3.0))    # bucket mismatch


def test_counter_thread_consistency():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    threads = [threading.Thread(target=lambda: [c.inc() for _ in range(500)])
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 4000


# ----------------------------------------------------------------------
# tracer units
# ----------------------------------------------------------------------
def test_span_nesting_follows_with_stack():
    tr = Tracer(clock=LogicalClock())
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            assert tr.current() is inner
        assert tr.current() is outer
    assert tr.current() is None
    [i] = tr.spans("inner")
    assert i.parent_id == outer.span_id


def test_span_error_status_recorded_and_not_swallowed():
    tr = Tracer(clock=LogicalClock())
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("seeded")
    [sp] = tr.spans("boom")
    assert sp.status == "error"
    assert "seeded" in sp.error


def test_null_tracer_is_inert():
    with NULL_TRACER.span("anything", k=1) as sp:
        sp.set("more", 2)
    NULL_TRACER.instant("evt")


def test_exports_are_valid_and_schema_checked():
    _, tr = _traced_run()
    assert validate_jsonl(tr.export_jsonl()) == []
    doc = tr.export_chrome()
    assert doc["displayTimeUnit"] == "ms"
    assert validate_chrome(doc) == []
    # round-trips through JSON
    assert validate_chrome(json.loads(json.dumps(doc))) == []


def test_schema_rejects_bad_documents():
    assert validate_chrome({"traceEvents": "nope"})
    # a complete event missing dur
    bad = {"displayTimeUnit": "ms",
           "traceEvents": [{"name": "x", "cat": "x", "ph": "X", "pid": 1,
                            "tid": 0, "ts": 1.0, "args": {}}]}
    assert validate_chrome(bad)
    # an oracle.point event without an outcome tag
    bad = {"displayTimeUnit": "ms",
           "traceEvents": [{"name": "oracle.point", "cat": "oracle",
                            "ph": "X", "pid": 1, "tid": 0, "ts": 1.0,
                            "dur": 1.0, "args": {}}]}
    assert validate_chrome(bad)
    assert validate_jsonl("not json\n")


# ----------------------------------------------------------------------
# determinism: the CI byte-equality gate in miniature
# ----------------------------------------------------------------------
def test_two_logical_clock_runs_export_identical_bytes():
    _, tr1 = _traced_run()
    _, tr2 = _traced_run()
    assert tr1.export_jsonl() == tr2.export_jsonl()
    assert (json.dumps(tr1.export_chrome(), sort_keys=True)
            == json.dumps(tr2.export_chrome(), sort_keys=True))


# ----------------------------------------------------------------------
# session phases <-> spans
# ----------------------------------------------------------------------
def test_session_spans_mirror_phases():
    s, tr = _traced_run()
    names = {sp.name for sp in tr.spans()}
    assert {"session.characterize", "session.component", "session.plan",
            "session.map", "session.map_point",
            "oracle.point", "tool.point"} <= names
    [char] = tr.spans("session.characterize")
    comps = tr.spans("session.component")
    assert {c.attrs["component"] for c in comps} == {"a", "b"}
    assert all(c.parent_id == char.span_id for c in comps)
    [mapped] = tr.spans("session.map")
    points = tr.spans("session.map_point")
    assert len(points) == len(s.planned)
    assert all(p.parent_id == mapped.span_id for p in points)


def test_progress_instants_match_events():
    specs, tmg, spaces = _system()
    events = []
    tr = Tracer(clock=LogicalClock())
    s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3,
                           on_event=events.append, tracer=tr)
    s.run()
    instants = tr.spans("session.progress")
    assert len(instants) == len(events)
    assert ([(i.attrs["phase"], i.attrs["label"]) for i in instants]
            == [(e.phase, e.label) for e in events])


# ----------------------------------------------------------------------
# outcome partition <-> ledger reconciliation (Fig. 11)
# ----------------------------------------------------------------------
def test_ledger_outcomes_reconcile_with_totals():
    s, tr = _traced_run()
    counts = s.ledger.outcome_counts()
    assert set(counts) == set(OUTCOMES)
    assert counts["fresh"] + counts["replay"] == s.ledger.total()
    assert counts["cache_hit"] > 0                 # repeats within phases
    traced = tr.outcome_counts("oracle.point")
    assert {o: n for o, n in counts.items() if n} == traced
    assert sum(counts.values()) == len(tr.spans("oracle.point"))


def test_replay_outcome_from_persistent_restore(tmp_path):
    specs, tmg, spaces = _system()

    def run_once(tracer):
        cache = PersistentOracleCache(str(tmp_path / "c"), flush_every=1)
        ledger = OracleLedger(HLSTool(dict(specs)), cache=cache,
                              tracer=tracer)
        s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3,
                               ledger=ledger)
        s.run()
        return ledger

    cold = run_once(Tracer(clock=LogicalClock()))
    assert cold.outcome_counts()["replay"] == 0

    tr = Tracer(clock=LogicalClock())
    warm = run_once(tr)
    counts = warm.outcome_counts()
    assert counts["fresh"] == 0                    # everything restored
    assert counts["replay"] > 0
    assert counts["replay"] == warm.total()
    assert tr.outcome_counts("oracle.point") == \
        {o: n for o, n in counts.items() if n}


def test_shared_oracle_outcomes_and_inflight_join():
    specs, _, _ = _system()
    tr = Tracer(clock=LogicalClock())
    gate = threading.Event()

    class SlowTool(HLSTool):
        def synthesize(self, component, **kw):
            gate.wait(timeout=30)
            return super().synthesize(component, **kw)

    shared = SharedOracle(SlowTool(dict(specs)),
                          cache=PersistentOracleCache(None), tracer=tr)
    req = InvocationRequest("a", 2, 2)
    results = []
    threads = [threading.Thread(
        target=lambda: results.append(shared.evaluate(req)))
        for _ in range(3)]
    for t in threads:
        t.start()
    while shared.outcome_counts().get("inflight_join", 0) < 2:
        if not any(t.is_alive() for t in threads):
            break
        gate.wait(0.01)
    gate.set()
    for t in threads:
        t.join()
    counts = shared.outcome_counts()
    assert counts["fresh"] == 1
    assert counts["inflight_join"] == 2
    assert shared.evaluate(req) is not None
    assert shared.outcome_counts()["cache_hit"] == 1
    assert tr.outcome_counts("shared.point") == \
        {o: n for o, n in shared.outcome_counts().items() if n}
    assert len({id(r) for r in results}) >= 1 and len(results) == 3


# ----------------------------------------------------------------------
# service-level reconciliation
# ----------------------------------------------------------------------
@pytest.fixture
def _toy_app():
    specs, _, _ = _system()
    app = App(
        name="obs-toy",
        description="runnable toy for the observability battery",
        tmg=lambda: pipeline_tmg(["a", "b"], buffers=2),
        knob_spaces=lambda **_: {n: KnobSpace(clock_ns=1.0, max_ports=4,
                                              max_unrolls=8)
                                 for n in ("a", "b")},
        analytical=lambda: HLSTool(dict(specs)),
    )
    register_app(app)
    try:
        yield app
    finally:
        _APPS.pop("obs-toy", None)


def test_service_stats_embed_metrics_and_partition(_toy_app):
    tr = Tracer(clock=LogicalClock())
    with DSEService(max_pending=4, workers=1, tracer=tr) as svc:
        h1 = svc.submit(DSEQuery(app="obs-toy", backend="analytical",
                                 tenant="t0"))
        h1.result(timeout=120)
        h2 = svc.submit(DSEQuery(app="obs-toy", backend="analytical",
                                 tenant="t1"))
        h2.result(timeout=120)
        stats = svc.stats()

    m = stats["metrics"]
    assert m["service.submitted"] == 2
    assert m["service.done"] == 2
    assert m["service.queue_wait_s"]["count"] == 2
    assert m["service.latency_s"]["count"] == 2

    # every tenant-fresh point reaches the shared oracle exactly once,
    # and the shared fresh count is the real tool-invocation total
    tenant_fresh = sum(h.outcome_counts()["fresh"] for h in (h1, h2))
    pool_outcomes = {}
    for p in stats["pools"].values():
        for o, n in p["outcomes"].items():
            pool_outcomes[o] = pool_outcomes.get(o, 0) + n
    assert sum(pool_outcomes.values()) == tenant_fresh
    assert pool_outcomes["fresh"] == stats["shared_invocations"]
    assert pool_outcomes["cache_hit"] > 0          # t1 reuses t0's work
    # the trace saw the same partition at both levels
    assert tr.outcome_counts("shared.point") == \
        {o: n for o, n in pool_outcomes.items() if n}
    svc_q = tr.spans("service.query")
    assert len(svc_q) == 2
    assert all(sp.attrs.get("status") != "failed" for sp in svc_q)
