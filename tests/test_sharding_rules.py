"""Sharding-rule resolution (structure-level; runs on 1 CPU device)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardingRules, _resolve, lm_rules, tree_paths


@pytest.fixture(scope="module")
def mesh():
    # trivial mesh: resolution logic is shape-independent of axis sizes
    # except for divisibility, which a (1, 1) mesh never triggers.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_basic(mesh):
    assert _resolve(("data", None), mesh) == P("data", None)
    assert _resolve(("model",), mesh) == P("model")
    assert _resolve(("bogus", None), mesh) == P(None, None)


def test_resolve_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # with axis size 1 everything divides; emulate a larger axis via the
    # production mesh shape is covered in the dry-run — here check the
    # 'None on mismatch' path using shape=0-free dims
    assert _resolve(("data",), mesh, (7,)) == P("data")   # 7 % 1 == 0


def test_lm_rules_paths(mesh):
    rules = lm_rules("dense")
    spec = rules.spec("embed", 2, mesh, (1024, 64))
    assert spec.spec == P("model", None)
    spec = rules.spec("layers/attn/wq", 3, mesh, (4, 64, 64))
    assert spec.spec == P(None, None, "model")      # left-padded layer dim
    spec = rules.spec("layers/mlp/w_down", 3, mesh, (4, 128, 64))
    assert spec.spec == P(None, "model", None)
    spec = rules.spec("final_norm/scale", 1, mesh, (64,))
    assert spec.spec == P(None)


def test_moe_2d_rules(mesh):
    r1 = lm_rules("moe")
    r2 = lm_rules("moe", two_d_experts=True)
    s1 = r1.spec("layers/moe/w_gate", 4, mesh, (4, 8, 64, 64))
    s2 = r2.spec("layers/moe/w_gate", 4, mesh, (4, 8, 64, 64))
    assert s1.spec == P(None, "model", None, None)
    assert s2.spec == P(None, "model", None, "data")


def test_tree_paths_structure():
    tree = {"a": {"b": jnp.zeros(2)}, "c": [jnp.zeros(1), jnp.zeros(1)]}
    paths = tree_paths(tree)
    assert paths["a"]["b"] == "a/b"
    assert paths["c"][0] == "c/0"


def test_rules_tree_covers_model_params(mesh):
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = lm_rules("dense").tree(params, mesh)
    # every leaf got a NamedSharding
    n = len(jax.tree.leaves(shardings))
    assert n == len(jax.tree.leaves(params))
