"""TMG model: cycle time, throughput, incidence (paper Section 2.2)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TMG, Place, Transition, pipeline_tmg


def simple_loop(delays, tokens=1):
    names = list(delays)
    ts = [Transition(n) for n in names]
    places = [Place(f"p{i}", names[i], names[(i + 1) % len(names)],
                    tokens=(tokens if i == len(names) - 1 else 0))
              for i in range(len(names))]
    return TMG(ts, places)


def test_single_cycle_min_cycle_time():
    tmg = simple_loop({"a": 0, "b": 0, "c": 0}, tokens=2)
    delays = {"a": 3.0, "b": 5.0, "c": 2.0}
    # one cycle: D = 10, N = 2
    assert tmg.min_cycle_time(delays) == pytest.approx(5.0)
    assert tmg.throughput(delays) == pytest.approx(0.2)


def test_zero_token_cycle_deadlocks():
    tmg = simple_loop({"a": 0, "b": 0}, tokens=0)
    assert tmg.min_cycle_time({"a": 1.0, "b": 1.0}) == float("inf")
    assert tmg.throughput({"a": 1.0, "b": 1.0}) == 0.0 or \
        tmg.throughput({"a": 1.0, "b": 1.0}) == pytest.approx(0.0)


def test_pipeline_ping_pong_overlap():
    """With 2-token capacity places, a pipeline sustains 1/max(lam)
    (Fig. 3's overlapped execution); with 1 token adjacent stages
    serialize."""
    names = ["s1", "s2", "s3"]
    delays = {"s1": 2.0, "s2": 5.0, "s3": 3.0}
    fast = pipeline_tmg(names, buffers=2)
    slow = pipeline_tmg(names, buffers=1)
    th_fast = fast.throughput(delays)
    th_slow = slow.throughput(delays)
    assert th_fast == pytest.approx(1.0 / 5.0)
    assert th_slow == pytest.approx(1.0 / 8.0)  # s2+s3 serialize
    assert th_fast > th_slow


def test_incidence_matrix_signs():
    tmg = simple_loop({"a": 0, "b": 0}, tokens=1)
    A = tmg.incidence_matrix()
    B = tmg.input_delay_selector()
    # each place row: +1 for consumer, -1 for producer
    assert A.shape == (2, 2)
    assert np.all(A.sum(axis=1) == 0)
    assert np.all(B.sum(axis=1) == 1)


def test_strongly_connected():
    tmg = simple_loop({"a": 0, "b": 0}, tokens=1)
    assert tmg.strongly_connected()
    ts = [Transition("a"), Transition("b")]
    open_tmg = TMG(ts, [Place("p", "a", "b", 1)])
    assert not open_tmg.strongly_connected()


def test_criticality_sums_to_one():
    tmg = pipeline_tmg(["a", "b", "c"], buffers=2)
    crit = tmg.criticality({"a": 1.0, "b": 10.0, "c": 1.0})
    assert sum(crit.values()) == pytest.approx(1.0)
    assert max(crit, key=crit.get) == "b"


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=6),
       st.integers(1, 4))
def test_throughput_scaling_property(delays, tokens):
    """theta(c * lam) == theta(lam) / c for any positive scale c."""
    names = [f"t{i}" for i in range(len(delays))]
    tmg = simple_loop(dict.fromkeys(names, 0), tokens=tokens)
    d1 = dict(zip(names, delays))
    d2 = {k: 2.0 * v for k, v in d1.items()}
    th1, th2 = tmg.throughput(d1), tmg.throughput(d2)
    assert th2 == pytest.approx(th1 / 2.0, rel=1e-9)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=5))
def test_throughput_monotone_in_delays(delays):
    """Increasing any latency can never increase throughput."""
    names = [f"t{i}" for i in range(len(delays))]
    tmg = pipeline_tmg(names, buffers=2)
    d1 = dict(zip(names, delays))
    d2 = dict(d1)
    d2[names[0]] *= 3.0
    assert tmg.throughput(d2) <= tmg.throughput(d1) + 1e-12
