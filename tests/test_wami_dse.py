"""The paper's WAMI experiment, as acceptance tests (Table 1 / Figs 10-11)."""

import statistics

import pytest

from repro.apps.wami import (wami_cosmos, wami_exhaustive, wami_knob_spaces)
from repro.apps.wami.pipeline import wami_cosmos_no_memory


@pytest.fixture(scope="module")
def cosmos():
    return wami_cosmos(delta=0.25)


@pytest.fixture(scope="module")
def exhaustive():
    return wami_exhaustive()


def test_all_components_characterized(cosmos):
    assert len(cosmos.characterizations) == 12   # matrix_inv is software


def test_table1_memory_codesign_widens_spans(cosmos):
    nm = wami_cosmos_no_memory(delta=0.25)
    lam_c = statistics.mean(c.lam_span for c in cosmos.characterizations.values())
    lam_n = statistics.mean(c.lam_span for c in nm.characterizations.values())
    area_c = statistics.mean(c.area_span for c in cosmos.characterizations.values())
    area_n = statistics.mean(c.area_span for c in nm.characterizations.values())
    # paper: 4.06x vs 1.73x and 2.58x vs 1.22x — require the same ordering
    # with comfortable margins
    assert lam_c > 2.0 * lam_n
    assert area_c > 1.2 * area_n


def test_fig11_invocation_reduction(cosmos, exhaustive):
    red = exhaustive.total_invocations / cosmos.total_invocations
    assert red > 4.0            # paper: 6.7x average
    per = [exhaustive.invocations[n] / max(1, cosmos.invocations.get(n, 1))
           for n in exhaustive.invocations]
    assert max(per) > 6.0       # paper: up to 14.6x


def test_fig10_planned_vs_mapped(cosmos):
    assert len(cosmos.mapped) >= 5
    sigmas = [m.sigma_mismatch for m in cosmos.mapped]
    # extremes must match tightly; the paper shows larger mid-curve sigmas
    assert sigmas[0] < 0.05 and sigmas[-1] < 0.05
    assert statistics.median(sigmas) < 0.25
    # mapping is conservative on throughput
    for m in cosmos.mapped:
        assert m.theta_actual >= m.theta_planned * 0.98


def test_exhaustive_composition_is_intractable(exhaustive):
    # paper: > 9e12 combinations for WAMI
    assert exhaustive.combinations() > 1e9
