"""Surrogate-guided frugality: byte-identical fronts, fewer invocations.

The guarantee under test (src/repro/core/surrogate.py): a guided
session emits exactly the front an unguided session emits — the grid
walk plus one oracle confirmation per component replaces the real
corner walk, and ANY grid/oracle disagreement falls back to the full
unguided walk.  Frugality is the whole point, so the ledger spend must
strictly drop, and on WAMI beat the paper's 14.6x headline (Fig. 11)
against the exhaustive baseline.
"""

import dataclasses

import pytest

from repro.apps.wami import wami_exhaustive
from repro.core import (BatchPricer, KnobSpace, OracleLedger,
                        RidgeSurrogate, characterize_component,
                        guided_characterize_component)
from repro.core.hlsim import ComponentSpec, HLSTool, LoopNest
from repro.core.registry import build_session, list_apps


def _run(app, **kw):
    s = build_session(app, **kw)
    return s, s.run()


def _front(res):
    return repr(res.planned), repr(res.mapped)


def _spend(session):
    return sum(session.ledger.invocations.values())


# every registered app gets a guided analytical cell, plus the
# memory-co-design cell (tile axis) for wami
_CELLS = [(a.name, {}) for a in list_apps()] + [("wami", {"share_plm": True})]


@pytest.mark.parametrize("app,opts", _CELLS,
                         ids=[f"{a}{'-share_plm' if o else ''}"
                              for a, o in _CELLS])
def test_guided_front_byte_identical_and_strictly_cheaper(app, opts):
    plain_s, plain = _run(app, **opts)
    guided_s, guided = _run(app, guided=True, **opts)
    assert _front(guided) == _front(plain)
    assert _spend(guided_s) < _spend(plain_s)
    stats = guided_s.guided
    assert stats and set(stats) == set(plain_s.characterizations)
    assert not any(v["fell_back"] for v in stats.values())
    # per-component books stay per-run deltas in the guided path too
    for name, char in guided_s.characterizations.items():
        assert char.invocations <= plain_s.characterizations[name].invocations


@pytest.mark.parametrize("workers", [1, 4])
def test_guided_is_deterministic_across_worker_counts(workers):
    base_s, base = _run("wami", guided=True)
    par_s, par = _run("wami", guided=True, workers=workers)
    assert _front(par) == _front(base)
    assert dict(par_s.ledger.invocations) == dict(base_s.ledger.invocations)


@pytest.mark.slow
def test_wami_guided_beats_the_paper_frugality_headline():
    """Fig. 11 acceptance: exhaustive WAMI spend over the guided
    session's whole-ledger spend (characterize + map confirmations)
    must beat the paper's best per-component ratio, 14.6x."""
    exhaustive = wami_exhaustive()
    guided_s, _ = _run("wami", guided=True)
    ratio = exhaustive.total_invocations / _spend(guided_s)
    assert ratio >= 14.6


# ----------------------------------------------------------------------
# poisoning: neither a bad ranker nor a bad grid may change the front
# ----------------------------------------------------------------------
class _PoisonedSurrogate(RidgeSurrogate):
    """Always 'fitted', adversarially inverted ranking."""

    @property
    def fitted(self):
        return True

    def predict(self, component, unrolls, ports, tile):
        return -float(unrolls * 31 + ports * 7 + tile)


def test_poisoned_surrogate_cannot_change_the_front():
    plain_s, plain = _run("wami")
    guided_s, guided = _run("wami", guided=True,
                            surrogate=_PoisonedSurrogate())
    assert _front(guided) == _front(plain)
    assert _spend(guided_s) < _spend(plain_s)


def _toy_tool():
    return HLSTool({
        "a": ComponentSpec("a", LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
    })


class _PoisonedPricer:
    """Grid facade whose feasible latencies are subtly wrong — the
    oracle confirmation must catch the disagreement."""

    def __init__(self, pricer):
        self._p = pricer

    def synthesize(self, component, **kw):
        s = self._p.synthesize(component, **kw)
        if s.feasible:
            return dataclasses.replace(s, lam=s.lam * (1.0 + 1e-6))
        return s

    def cdfg_facts(self, component, synth):
        return self._p.cdfg_facts(component, synth)


def test_poisoned_grid_is_caught_and_falls_back_to_exact_front():
    space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
    ref = characterize_component(OracleLedger(_toy_tool()), "a", space)

    tool = _toy_tool()
    gc = guided_characterize_component(
        OracleLedger(tool), "a", space,
        pricer=_PoisonedPricer(BatchPricer(tool)))
    assert gc.fell_back and gc.confirmed == 1
    assert repr(gc.result.regions) == repr(ref.regions)
    assert repr(gc.result.points) == repr(ref.points)
    # the wasted confirmation is the unguided walk's own corner request,
    # so the fallback re-walk serves it from cache: same total spend
    assert gc.result.invocations == ref.invocations


def test_healthy_grid_confirms_one_invocation_per_component():
    space = KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
    ref = characterize_component(OracleLedger(_toy_tool()), "a", space)

    tool = _toy_tool()
    gc = guided_characterize_component(
        OracleLedger(tool), "a", space, pricer=BatchPricer(tool))
    assert not gc.fell_back and gc.confirmed == 1
    assert repr(gc.result.regions) == repr(ref.regions)
    assert repr(gc.result.points) == repr(ref.points)
    assert gc.result.invocations == 1           # one confirmation paid
    assert gc.grid_invocations == ref.invocations   # walk absorbed by grid


def test_surrogate_fits_online_and_ranks():
    tool = _toy_tool()
    ledger = OracleLedger(tool)
    space = KnobSpace(clock_ns=1.0, max_ports=16, max_unrolls=32)
    characterize_component(ledger, "a", space)   # generate records
    sur = RidgeSurrogate()
    assert not sur.fitted
    with pytest.raises(RuntimeError):
        sur.predict("a", 1, 1, 0)
    assert sur.fit(ledger.records)
    assert sur.fitted
    # more parallelism must not predict slower on this monotone toy
    fast = sur.predict("a", 8, 4, 0)
    slow = sur.predict("a", 1, 1, 0)
    assert fast <= slow
