"""ExplorationSession: phases, events, serialization, WAMI acceptance."""

import os

import pytest

from repro.apps.wami import (wami_cosmos, wami_hls_tool, wami_knob_spaces,
                             wami_session, wami_tmg, WAMI_KNOB_TABLE,
                             MATRIX_INV_LATENCY_S)
from repro.core import (ExplorationSession, HLSTool, KnobSpace, OracleLedger,
                        PersistentOracleCache, pipeline_tmg)
from repro.core.hlsim import ComponentSpec, LoopNest


def _system():
    specs = {
        "a": ComponentSpec("a", LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
        "b": ComponentSpec("b", LoopNest(128, 1, 1, 4, 2, 4), 512, 512),
    }
    tmg = pipeline_tmg(list(specs), buffers=2)
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
              for n in specs}
    return specs, tmg, spaces


# ----------------------------------------------------------------------
# Phase API + events
# ----------------------------------------------------------------------
def test_explicit_phases():
    specs, tmg, spaces = _system()
    s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3)
    chars = s.characterize()
    assert set(chars) == set(specs)
    char_invocations = s.ledger.total()
    assert char_invocations > 0
    planned = s.plan()
    assert s.ledger.total() == char_invocations   # planning is LP-only
    assert len(planned) >= 2
    mapped = s.map()
    assert len(mapped) == len(planned)
    res = s.result()
    assert res.total_invocations == s.ledger.total()


def test_progress_events():
    specs, tmg, spaces = _system()
    events = []
    s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3,
                           on_event=events.append)
    s.run()
    phases = [e.phase for e in events]
    # phases appear in order and each completes
    assert phases.index("characterize") < phases.index("plan") < phases.index("map")
    chars = [e for e in events if e.phase == "characterize" and e.done]
    assert {e.label for e in chars} == set(specs)
    maps = [e for e in events if e.phase == "map" and e.done]
    assert maps[-1].done == maps[-1].total == len(s.planned)


def test_result_before_map_raises():
    specs, tmg, spaces = _system()
    s = ExplorationSession(tmg, HLSTool(dict(specs)), spaces)
    with pytest.raises(RuntimeError):
        s.result()


# ----------------------------------------------------------------------
# Mid-run serialize / restore
# ----------------------------------------------------------------------
def test_save_restore_after_characterize(tmp_path):
    specs, tmg, spaces = _system()
    root = os.path.join(tmp_path, "session")
    s1 = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3)
    s1.characterize()
    s1.save(root)
    ref = s1.run()

    s2 = ExplorationSession.restore(root, tmg, HLSTool(dict(specs)),
                                    spaces, delta=0.3)
    assert s2.characterizations is not None
    # restored regions/points are exactly the originals
    assert repr(s2.characterizations) == repr(s1.characterizations)
    res = s2.run()
    assert repr(res.mapped) == repr(ref.mapped)
    # only the mapping invocations were re-paid
    assert s2.ledger.total() < s1.ledger.total()


def test_restore_with_persistent_cache_reinvokes_nothing(tmp_path):
    specs, tmg, spaces = _system()
    sroot = os.path.join(tmp_path, "session")
    croot = os.path.join(tmp_path, "cache")
    s1 = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3,
                            cache=PersistentOracleCache(croot))
    ref = s1.run()
    s1.save(sroot)

    calls = []

    class Spy(HLSTool):
        def synthesize(self, *a, **k):
            calls.append(a)
            return super().synthesize(*a, **k)

    s2 = ExplorationSession.restore(sroot, tmg, Spy(dict(specs)), spaces,
                                    delta=0.3,
                                    cache=PersistentOracleCache(croot))
    res = s2.run()
    assert calls == []                    # nothing re-invoked
    assert repr(res.mapped) == repr(ref.mapped)
    assert res.invocations == ref.invocations


class _PoisonTool:
    """A restore that needs ANY tool traffic is a serialization bug."""

    def synthesize(self, *a, **k):
        raise AssertionError("restore must not invoke the tool")

    def cdfg_facts(self, *a, **k):
        raise AssertionError("restore must not invoke the tool")


def test_save_after_map_restores_without_any_tool(tmp_path):
    """Regression: version-1 snapshots dropped the mapped points, so a
    save-after-map restore silently re-ran the whole map phase (and
    with it, tool invocations).  Version 2 restores the full result —
    schedules included — without a single call."""
    specs, tmg, spaces = _system()
    root = os.path.join(tmp_path, "session")
    s1 = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3)
    ref = s1.run()
    s1.save(root)

    s2 = ExplorationSession.restore(root, tmg, _PoisonTool(), spaces,
                                    delta=0.3)
    res = s2.run()                        # everything answered from state
    assert repr(res.mapped) == repr(ref.mapped)
    assert repr(res.planned) == repr(ref.planned)
    # the LP schedule survived the round trip on both surfaces
    assert all(p.schedule is not None for p in res.planned)
    assert [m.schedule.tag() for m in res.mapped] == \
        [m.schedule.tag() for m in ref.mapped]


def test_state_round_trips_schedule_and_compat_tag():
    """PR-6 fields through the JSON snapshot: ``SystemPoint.schedule``
    and ``MemoryPlan.compat_tag`` must survive byte-identically (a
    share-plm session carries both on every mapped point)."""
    import json

    from repro.core.registry import build_session

    s1 = build_session("wami", "analytical", share_plm=True)
    ref = s1.run()
    state = json.loads(json.dumps(s1.state()))   # force a real JSON trip
    assert state["version"] == 2

    s2 = build_session("wami", "analytical", share_plm=True,
                       tool=_PoisonTool())
    s2.load_state(state)
    res = s2.result()
    assert repr(res.mapped) == repr(ref.mapped)
    for got, want in zip(res.mapped, ref.mapped):
        assert got.memory_plan is not None
        assert got.memory_plan.compat_tag == want.memory_plan.compat_tag
        assert got.schedule.tag() == want.schedule.tag()
        assert got.memory_plan.compat_tag == got.schedule.tag()


def test_version1_snapshot_still_loads():
    """Old snapshots (no ``mapped`` key) keep loading: the session
    re-maps from the restored characterizations as before."""
    specs, tmg, spaces = _system()
    s1 = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3)
    ref = s1.run()
    state = s1.state()
    v1 = {k: v for k, v in state.items() if k != "mapped"}
    v1["version"] = 1
    s2 = ExplorationSession(tmg, HLSTool(dict(specs)), spaces, delta=0.3)
    s2.load_state(v1)
    assert s2.mapped is None              # v1 cannot restore the map
    res = s2.run()                        # ...but re-maps to the same front
    assert repr(res.mapped) == repr(ref.mapped)


# ----------------------------------------------------------------------
# Acceptance: WAMI batched == sequential, through the session API
# ----------------------------------------------------------------------
@pytest.mark.parametrize("workers", [4])
def test_wami_batched_identical_to_sequential(workers):
    seq = wami_cosmos(delta=0.25, workers=1)
    par = wami_cosmos(delta=0.25, workers=workers)
    assert seq.invocations == par.invocations
    assert repr(seq.planned) == repr(par.planned)
    assert repr(seq.mapped) == repr(par.mapped)
    assert repr(seq.pareto()) == repr(par.pareto())
    assert (seq.theta_min, seq.theta_max) == (par.theta_min, par.theta_max)


def test_wami_session_object_api():
    s = wami_session(delta=0.25, workers=8)
    chars = s.characterize()
    assert set(chars) == set(WAMI_KNOB_TABLE)     # 12 components, no matrix_inv
    assert "matrix_inv" not in chars
    res = s.run()
    assert len(res.mapped) >= 5


def test_knob_table_matches_knob_spaces():
    spaces = wami_knob_spaces()
    assert set(spaces) == set(WAMI_KNOB_TABLE)
    for name, (max_ports, max_unrolls) in WAMI_KNOB_TABLE.items():
        assert spaces[name].max_ports == max_ports
        assert spaces[name].max_unrolls == max_unrolls
