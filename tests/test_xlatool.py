"""COSMOS over the XLA-priced oracle: full methodology on an ML pipeline."""

import pytest

from repro.configs import SHAPES, get_config
from repro.core import (CountingTool, KnobSpace, cosmos_dse, pipeline_tmg)
from repro.core.xlatool import XLATool


@pytest.fixture(scope="module")
def result():
    # two-stage training system: a 9B dense stage and a 2.7B hybrid stage
    # (multi-model pipeline, e.g. draft+target or distillation teacher)
    comps = {
        "gemma2": (get_config("gemma2-9b"), SHAPES[0]),
        "zamba2": (get_config("zamba2-2.7b"), SHAPES[0]),
    }
    tool = XLATool(comps)
    tmg = pipeline_tmg(list(comps), buffers=2)
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=5, max_unrolls=8)
              for n in comps}
    return cosmos_dse(tmg, tool, spaces, delta=0.3)


def test_characterization_finds_tp_regions(result):
    for name, c in result.characterizations.items():
        assert len(c.regions) >= 2, name
        # more TP (ports) reaches faster lambda
        lam_mins = [r.lam_min for r in c.regions]
        assert lam_mins == sorted(lam_mins, reverse=True)


def test_pareto_curve_exists(result):
    assert len(result.mapped) >= 3
    front = result.pareto()
    assert len(front) >= 2
    # throughput up the curve costs HBM
    assert front[-1].cost > front[0].cost
    assert front[-1].perf > front[0].perf


def test_mapping_conservative_on_throughput(result):
    for m in result.mapped:
        assert m.theta_actual >= m.theta_planned * 0.98


def test_invocations_frugal(result):
    # exhaustive would price 5 ports x 8 unrolls = 40 per component
    assert result.total_invocations < 2 * 40
