"""COSMOS over the *measured* backend: the WAMI DSE driven by a
PallasOracle that prices each (component, knob) point by compiling and
timing the stage's knob-parameterized Pallas kernel (interpret mode on
CPU, the real grid on TPU).

Default run replays the recording checked in under
``artifacts/measurements/`` — fully deterministic, no TPU needed — then
fits the analytical HLSTool's latency constants to the measured points
and reports both backends' Pareto views side by side.

    PYTHONPATH=src python examples/wami_pallas.py            # replay
    PYTHONPATH=src python examples/wami_pallas.py --record   # re-measure
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help="re-measure every point this drive touches and "
                         "rewrite the measurement recording")
    ap.add_argument("--tile", type=int, default=None,
                    help="PLM tile edge (default: the WAMI 128)")
    ap.add_argument("--delta", type=float, default=0.25)
    args = ap.parse_args()

    from repro.apps.wami import wami_hls_tool
    from repro.apps.wami.components import TILE
    from repro.apps.wami.pallas import (wami_pallas_oracle,
                                        wami_pallas_session)
    from repro.core import ExplorationSession, calibrate_to_records
    from repro.core.calibrate import CalibratedTool

    tile = args.tile or TILE
    mode = "record" if args.record else "replay"
    oracle = wami_pallas_oracle(mode, tile=tile)
    t0 = time.time()
    session = wami_pallas_session(args.delta, oracle=oracle,
                                  workers=1 if args.record else 8)
    res = session.run()
    saved = oracle.flush()
    wall = time.time() - t0

    print(f"[pallas] {mode} drive: {res.total_invocations} oracle "
          f"invocations, {len(res.mapped)} mapped points, {wall:.1f}s")
    if saved:
        print(f"[pallas] recording saved: {saved} "
              f"({len(oracle.store)} measured points)")
    by_phase = session.ledger.records_by_phase()
    print("[pallas] invocations by phase: "
          + ", ".join(f"{k}={v}" for k, v in sorted(by_phase.items())))
    print(f"[pallas] Pareto front (theta in [{res.theta_min:.2f}, "
          f"{res.theta_max:.2f}] frames/s; cost = VMEM bytes + fallback "
          f"mm^2):")
    for pt in res.pareto():
        print(f"   theta {pt.perf:8.2f} fps   cost {pt.cost:12.1f}")

    # ---- calibrate the analytical backend to the measured points -------
    measured_comps = set(oracle.components)
    hls = wami_hls_tool()
    fit = calibrate_to_records(
        hls, [r for r in session.ledger.records
              if r.component in measured_comps])
    print("[calibrate] per-component latency scale (measured / analytical):")
    for name in sorted(fit.scales):
        print(f"   {name:14s} x{fit.scales[name]:10.3g}   "
              f"({fit.points[name]} pts, residual spread "
              f"x{fit.lam_spread[name]:.2f})")

    cal_session = ExplorationSession(
        session.tmg, CalibratedTool(hls, fit), session.spaces,
        delta=args.delta, fixed=session.fixed, workers=8)
    cal = cal_session.run()
    print(f"[calibrate] theta range, calibrated analytical: "
          f"[{cal.theta_min:.2f}, {cal.theta_max:.2f}] fps "
          f"vs measured: [{res.theta_min:.2f}, {res.theta_max:.2f}] fps")


if __name__ == "__main__":
    main()
