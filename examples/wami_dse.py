"""The paper's experiment in one command: COSMOS DSE of the WAMI
accelerator — Table 1 spans, Fig. 10 Pareto curve, Fig. 11 invocations —
plus a functional run of the accelerator itself (Lucas-Kanade alignment
+ change detection on synthetic frames).

The DSE runs through the batched ``ExplorationSession`` API: all 12
components characterize concurrently, all plan points map concurrently,
and the results (fronts AND invocation counts) are identical to the
sequential drive.

    PYTHONPATH=src python examples/wami_dse.py        # or pip install -e .
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import statistics

import jax
import jax.numpy as jnp

from repro.apps.wami import (WAMI_KNOB_TABLE, wami_app, wami_exhaustive,
                             wami_session)
from repro.apps.wami.pipeline import wami_cosmos_no_memory


def main():
    # ---- the accelerator actually runs ---------------------------------
    key = jax.random.PRNGKey(0)
    import jax.scipy.signal as jsig
    base = jsig.convolve2d(jax.random.uniform(key, (64, 64)),
                           jnp.ones((5, 5)) / 25, mode="same") * 100
    moving = base.at[20:28, 20:28].add(180.0)
    masks, ps = wami_app(jnp.stack([base, base, moving]), n_iters=4)
    print(f"[wami] change-detection foreground on moved frame: "
          f"{float(masks[1][20:28, 20:28].mean()):.0%} inside, "
          f"{float(masks[1].mean()):.1%} overall")

    # ---- the paper's DSE, batched through ExplorationSession -----------
    def on_event(e):
        if e.done in (0, e.total):
            print(f"[session] {e.phase:12s} {e.done}/{e.total} {e.label}")

    session = wami_session(delta=0.25, workers=8, on_event=on_event)
    cos = session.run()
    nm = wami_cosmos_no_memory(delta=0.25)
    exh = wami_exhaustive(workers=8)

    lam = statistics.mean(c.lam_span for c in cos.characterizations.values())
    lam_nm = statistics.mean(c.lam_span for c in nm.characterizations.values())
    area = statistics.mean(c.area_span for c in cos.characterizations.values())
    area_nm = statistics.mean(c.area_span for c in nm.characterizations.values())
    print(f"[table1] knob table: "
          f"{', '.join(f'{n}={p}p/{u}u' for n, (p, u) in WAMI_KNOB_TABLE.items())}")
    print(f"[table1] spans with memory co-design: lambda {lam:.2f}x, "
          f"area {area:.2f}x   (paper: 4.06x / 2.58x)")
    print(f"[table1] spans dual-port only:        lambda {lam_nm:.2f}x, "
          f"area {area_nm:.2f}x   (paper: 1.73x / 1.22x)")

    red = exh.total_invocations / cos.total_invocations
    per = max(exh.invocations[n] / max(1, cos.invocations.get(n, 1))
              for n in exh.invocations)
    by_phase = session.ledger.records_by_phase()
    print(f"[fig11] invocations: exhaustive {exh.total_invocations} vs "
          f"COSMOS {cos.total_invocations} = {red:.1f}x avg, "
          f"up to {per:.1f}x   (paper: 6.7x avg, up to 14.6x)")
    print(f"[fig11] COSMOS breakdown by phase: "
          + ", ".join(f"{k}={v}" for k, v in by_phase.items()))

    print(f"[fig10] Pareto curve ({len(cos.mapped)} points, "
          f"theta in [{cos.theta_min:.1f}, {cos.theta_max:.1f}] frames/s):")
    for m in cos.mapped:
        print(f"   theta {m.theta_actual:7.1f} fps  area "
              f"{m.cost_actual:6.2f} mm^2  sigma {m.sigma_mismatch:6.1%}")


if __name__ == "__main__":
    main()
