"""Memory co-design on WAMI: tile as a knob + cross-component PLM sharing.

The walkthrough for the system-level PLM planner (docs/memory.md), all
deterministic from the checked-in tile-128 recording — no TPU needed:

  1. fit the unit system: per-component latency scales plus one global
     bytes-per-mm² area rate, so the analytical fallback prices in the
     measured backend's cost unit;
  2. derive the memory compatibility graph from the Fig. 8 TMG — the
     one-token LK refinement cycle certifies six components mutually
     exclusive;
  3. run the DSE with the tile knob open (native 128 replays the
     recording, tile 64 is priced by the calibrated fallback) and the
     PLM planner pricing the memory subsystem per mapped point;
  4. show the system front against the paper's naive per-component sum:
     the shared-PLM front dominates or equals it everywhere.

    PYTHONPATH=src python examples/wami_plm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.apps.wami.pallas import wami_plm_session, wami_unit_system
    from repro.apps.wami.pipeline import wami_tmg
    from repro.core.plm import MemoryCompatGraph

    # ---- 1. one cost unit per system ---------------------------------
    units = wami_unit_system()
    print(f"[units] area: 1 mm² == {units.area_scale:.4g} VMEM bytes "
          f"({units.area_points} fitted points, residual spread "
          f"x{units.area_spread:.1f})")
    for name in sorted(units.lam.scales):
        print(f"[units]   lam {name:14s} x{units.lam.scales[name]:.3g}")

    # ---- 2. who may share --------------------------------------------
    compat = MemoryCompatGraph(wami_tmg())
    shareable = sorted(n for n in compat.names if compat.neighbours(n))
    print(f"[compat] mutually exclusive (one-token LK cycle): "
          f"{', '.join(shareable)}")

    # ---- 3. the co-design drive --------------------------------------
    session = wami_plm_session(0.25, workers=8)
    res = session.run()
    print(f"[dse] {res.total_invocations} oracle invocations, "
          f"{len(res.mapped)} mapped points, theta in "
          f"[{res.theta_min:.1f}, {res.theta_max:.1f}] fps")
    for name, ch in sorted(res.characterizations.items()):
        tiles = sorted({dict(p.knobs).get("tile", 0)
                        for p in ch.points} - {0})
        if len(tiles) >= 2:
            print(f"[dse]   {name:14s} tile axis {tiles}, "
                  f"{len(ch.regions)} regions")

    # ---- 4. shared front vs per-component sum ------------------------
    print("[front] theta_fps   shared_cost   naive_sum   saved   groups")
    for m in sorted(res.mapped, key=lambda m: m.theta_actual):
        groups = ";".join("+".join(g) for g in m.plm_groups) or "-"
        print(f"[front] {m.theta_actual:9.2f}  {m.cost_actual:12.0f}  "
              f"{m.cost_unshared:10.0f}  {m.cost_unshared - m.cost_actual:6.0f}"
              f"   {groups}")
    assert all(m.cost_actual <= m.cost_unshared + 1e-9 for m in res.mapped)
    print("[front] shared-PLM front dominates or equals the naive sum "
          "at every point")


if __name__ == "__main__":
    main()
