"""Quickstart: build a zoo model, train a few steps, generate — then run
a tiny COSMOS exploration through the batched ExplorationSession API.

    pip install -e .          # or: PYTHONPATH=src python examples/quickstart.py
    python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt
from repro.serve import generate
from repro.train import TrainStepConfig, make_train_step


def main():
    print("available archs:", ", ".join(list_archs()))
    cfg = get_config("gemma2-9b").reduced()     # same family, CPU-sized
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                   TrainStepConfig(remat="none",
                                                   total_steps=30)))
    opt = init_opt(params)
    src = SyntheticLM(vocab=cfg.vocab, seed=0)
    for i in range(30):
        b = src.batch(step=i, shard=0, n_shards=1, batch=8, seq=64)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    prompt = jnp.asarray(b["tokens"][:2, :16])
    out = generate(model, params, {"tokens": prompt}, max_new=12)
    print("generated:", out.tolist()[0])

    # ---- a 30-second COSMOS exploration (the paper's engine) ----------
    from repro.core import (ExplorationSession, HLSTool, KnobSpace,
                            pipeline_tmg)
    from repro.core.hlsim import ComponentSpec, LoopNest
    specs = {
        "stage_a": ComponentSpec("stage_a",
                                 LoopNest(256, 2, 1, 8, 3, 6), 1024, 1024),
        "stage_b": ComponentSpec("stage_b",
                                 LoopNest(128, 1, 1, 4, 2, 4), 512, 512),
    }
    session = ExplorationSession(
        pipeline_tmg(list(specs), buffers=2), HLSTool(specs),
        {n: KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
         for n in specs},
        delta=0.3, workers=4)
    res = session.run()
    print(f"cosmos: {len(res.mapped)} mapped points from "
          f"{res.total_invocations} oracle invocations "
          f"(theta in [{res.theta_min:.0f}, {res.theta_max:.0f}] runs/s)")


if __name__ == "__main__":
    main()
