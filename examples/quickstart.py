"""Quickstart: build a zoo model, train a few steps, generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt
from repro.serve import generate
from repro.train import TrainStepConfig, make_train_step


def main():
    print("available archs:", ", ".join(list_archs()))
    cfg = get_config("gemma2-9b").reduced()     # same family, CPU-sized
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                   TrainStepConfig(remat="none",
                                                   total_steps=30)))
    opt = init_opt(params)
    src = SyntheticLM(vocab=cfg.vocab, seed=0)
    for i in range(30):
        b = src.batch(step=i, shard=0, n_shards=1, batch=8, seq=64)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    prompt = jnp.asarray(b["tokens"][:2, :16])
    out = generate(model, params, {"tokens": prompt}, max_new=12)
    print("generated:", out.tolist()[0])


if __name__ == "__main__":
    main()
