"""COSMOS-TPU in action: plan train knobs for every arch on the 256-chip
pod, then replay an elastic event (lose 3 hosts) and re-plan — the
paper's invocation-frugality argument applied to XLA.

All pricing runs through the same ``Oracle``/``OracleLedger`` protocol as
the WAMI HLS exploration (examples/wami_dse.py): one shared ledger
accounts every priced knob point across all stages, and a re-plan of an
unchanged stage is a cache hit, not a new pricing.

    PYTHONPATH=src python examples/autoshard.py       # or pip install -e .
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config, list_archs
from repro.core.autotune import (HBM_BYTES_PER_CHIP, XLAOracle,
                                 choose_train_knobs)
from repro.core.oracle import OracleLedger
from repro.ft import replan


def main():
    shape = SHAPES[0]  # train_4k
    mesh = {"data": 16, "model": 16}
    ledger = OracleLedger(XLAOracle())     # one ledger for the whole fleet
    print(f"{'arch':24s} {'mb':>3s} {'remat':6s} {'accum':9s} "
          f"{'plan GB':>8s} fit")
    for arch in list_archs():
        cfg = get_config(arch)
        p = choose_train_knobs(cfg, shape, mesh, ledger=ledger)
        fit = "Y" if p.est_bytes <= HBM_BYTES_PER_CHIP else "N"
        print(f"{arch:24s} {p.microbatches:3d} {p.remat:6s} "
              f"{p.accum_dtype:9s} {p.est_bytes / 1e9:8.1f} {fit}")
    n_priced = ledger.total()
    print(f"-- {n_priced} priced invocations across "
          f"{len(ledger.invocations)} stages (ladder walk, batched) --")

    print("\n-- elastic event: 12 chips lost on the multi-pod mesh --")
    plan = replan((2, 16, 16), ("pod", "data", "model"), 512 - 12)
    print(f"new mesh {dict(zip(plan.axis_names, plan.new_shape))}, "
          f"usable {plan.usable_devices}, resharding "
          f"{'required' if plan.needs_resharding else 'NOT required'}: "
          f"{plan.note}")
    mesh2 = dict(zip(plan.axis_names, plan.new_shape))
    p2 = choose_train_knobs(get_config("gemma2-9b"), shape, mesh2,
                            ledger=ledger)
    print(f"gemma2-9b re-planned: mb={p2.microbatches} remat={p2.remat} "
          f"({p2.est_bytes / 1e9:.1f} GB/chip) — "
          f"{ledger.total() - n_priced} new pricings, one compile to remap")
    # planning the unchanged stage again costs nothing
    before = ledger.total()
    choose_train_knobs(get_config("gemma2-9b"), shape, mesh, ledger=ledger)
    print(f"unchanged-stage re-plan: {ledger.total() - before} new "
          f"invocations (cache)")


if __name__ == "__main__":
    main()
