"""Batched serving example: requests through the slot-based engine.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import run


def main():
    # hybrid arch through the same engine (API-uniform serving)
    run("zamba2-2.7b-smoke", requests=12, slots=4, prompt_len=24, max_new=12)
    run("qwen2-0.5b-smoke", requests=16, slots=8, prompt_len=32, max_new=16)


if __name__ == "__main__":
    main()
