"""End-to-end driver: train a ~110M-param qwen2-family LM for a few
hundred steps on the synthetic Markov stream, with async checkpoints and
watchdog — the assignment's (b) end-to-end example.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~90M params: qwen2 family at d=768, 12L, 4k vocab (vocab kept
    # small so the synthetic bigram table is learnable in O(100) steps)
    base = get_config("qwen2-0.5b")
    cfg = dataclasses.replace(
        base, name="qwen2-110m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=4096, dtype="float32",
        param_dtype="float32")
    import repro.configs.registry as reg
    reg.ARCHS[cfg.name] = cfg

    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                         "repro_train_lm")
    params, losses = run("qwen2-110m", steps=args.steps, batch=8, seq=128,
                         lr=6e-4, microbatches=1, remat="none",
                         ckpt_dir=ckpt, ckpt_every=100)
    drop = losses[0] - losses[-1]
    print(f"loss drop over {args.steps} steps: {drop:.3f} "
          f"({'OK' if drop > 0.3 else 'check hyperparameters'})")


if __name__ == "__main__":
    main()
