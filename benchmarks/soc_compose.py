"""Beyond-paper: SoC composition — a chip's worth of accelerators.

The layer above one accelerator's DSE (docs/soc.md): each cell takes a
committed two-app traffic mix (WAMI frames + fleet pipeline requests),
resolves both apps' system-level Pareto fronts through the registry
(WAMI on its PLM-shared front), and has
:class:`repro.core.soc.SoCComposer` pick replica counts + operating
points to maximize sustained mix throughput under the ``sys_medium``
chip budgets.  Per cell it writes the CSV report plus the
``*.composition.json`` sidecar that ``python -m repro.core.soc.verify``
independently re-proves (the CI ``soc-compose`` job), and the primary
mix cell writes ``artifacts/bench/BENCH_soc.json`` — the
sustained-throughput-per-area trajectory file.

Every run also gates the greedy allocator against the exhaustive
packer on a small gate budget: the gap must stay within the pinned
bound (currently 0.40% — packing granularity, see docs/soc.md), and
the composition itself must survive :func:`assert_composition_sound`.

    PYTHONPATH=src python -m benchmarks.run --cell \\
        soc/soc-analytical-wami60_fleet40
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

# fixed pseudo-cells (the "soc" app is the composition layer, not a
# registered App): one cell per committed traffic mix
SCENARIOS = {"pairs": (("soc", "analytical"),),
             "variants": ("wami60_fleet40", "wami90_fleet10")}

#: variant -> mix spec (parsed with the per-app DEFAULT_DEMANDS pricing)
MIXES: Dict[str, str] = {
    "wami60_fleet40": "wami=0.6,fleet=0.4",
    "wami90_fleet10": "wami=0.9,fleet=0.1",
}
PRIMARY = "wami60_fleet40"       # the cell that writes BENCH_soc.json
BUDGET_NAME = "sys_medium"

#: the greedy-vs-exhaustive gate: small enough for the exhaustive
#: packer, tight enough that replica packing granularity matters
GATE_BUDGET = dict(name="soc_gate", area_mm2=40.0, power_w=16.0,
                   bw_gbps=64.0)
GATE_MAX_GAP = 0.004             # pinned: greedy within 0.40% of optimal

_FRONT_CACHE: Dict[tuple, Dict[str, list]] = {}


def _fronts(composer) -> Dict[str, list]:
    """Within-process front cache — both mix cells share the same
    (app, backend, share_plm, delta) explorations."""
    key = tuple((d.app, d.backend, d.share_plm, d.delta)
                for d in composer.mix.demands)
    if key not in _FRONT_CACHE:
        _FRONT_CACHE[key] = composer.fronts()
    return _FRONT_CACHE[key]


def _compose(mix_name: str, budget, tracer=None, metrics=None):
    from repro.core.soc import SoCComposer, TrafficMix
    mix = TrafficMix.parse(MIXES[mix_name], name=mix_name)
    composer = SoCComposer(budget, mix, workers=8, tracer=tracer,
                           metrics=metrics)
    composer._fronts = _fronts(composer)
    return composer, composer.compose()


def run(report, cell) -> None:
    from repro.core.obs import LogicalClock, MetricsRegistry, Tracer
    from repro.core.soc import (SoCBudget, assert_composition_sound,
                                get_budget, greedy_composition,
                                optimal_composition)
    budget = get_budget(BUDGET_NAME)
    tracer = Tracer(LogicalClock())
    metrics = MetricsRegistry()
    t0 = time.time()
    composer, comp = _compose(cell.variant, budget, tracer=tracer,
                              metrics=metrics)
    wall = time.time() - t0
    fronts = composer.fronts()

    # the strict post-pass: the composition must survive independent
    # re-verification (pricing, budgets, throughput claim, front pin)
    assert_composition_sound(comp, fronts=fronts)

    # the greedy-vs-exhaustive gate on the small instance
    gate = SoCBudget(**GATE_BUDGET)
    g = greedy_composition(gate, comp.mix, fronts)
    o = optimal_composition(gate, comp.mix, fronts)
    gap = ((o.sustained_throughput - g.sustained_throughput)
           / o.sustained_throughput)
    assert gap <= GATE_MAX_GAP, (
        f"greedy fell {gap:.4%} short of the exhaustive packer "
        f"(pinned bound {GATE_MAX_GAP:.2%})")

    b = comp.budget
    lines = [f"# SoC composition — mix {comp.mix.name} on {b.name} "
             f"@{b.tech_nm}nm ({comp.method})",
             "app,share,point,replicas,theta_per_replica,capacity_rps,"
             "area_mm2,power_w,bw_gbps"]
    for a in comp.allocations:
        lines.append(f"{a.app},{a.share:.4f},{a.point.index},"
                     f"{a.replicas},{a.point.theta:.6g},"
                     f"{a.capacity:.6g},{a.area_mm2:.6g},"
                     f"{a.power_w:.6g},{a.bw_gbps:.6g}")
    lines.append(f"# sustained T={comp.sustained_throughput:.6g} req/s; "
                 f"totals: area {comp.area_mm2:.6g}/{b.area_mm2:g} mm2, "
                 f"power {comp.power_w:.6g}/{b.power_w:g} W, "
                 f"bw {comp.bw_gbps:.6g}/{b.bw_gbps:g} GB/s")
    lines.append(f"# throughput per area "
                 f"{comp.throughput_per_area:.6g} req/s/mm2")
    lines.append(f"# greedy-vs-exhaustive gate ({gate.name}: "
                 f"{gate.area_mm2:g} mm2, {gate.power_w:g} W, "
                 f"{gate.bw_gbps:g} GB/s): greedy "
                 f"T={g.sustained_throughput:.6g}, exhaustive "
                 f"T={o.sustained_throughput:.6g}, gap {gap * 100:.3f}% "
                 f"<= {GATE_MAX_GAP * 100:.2f}% pinned")
    moves = metrics.snapshot().get("soc.moves", 0)
    lines.append(f"# obs: {len(tracer.spans())} spans "
                 f"(soc.compose > soc.front/soc.allocate), "
                 f"{moves} allocator moves")
    lines.append("# verify: composition independently re-proved feasible "
                 "(python -m repro.core.soc.verify)")
    name = f"soc_compose_{cell.variant}"
    report.write(name, lines)
    report.write_json(name, comp.to_json(), kind="composition")

    if cell.variant == PRIMARY:
        _write_trajectory(report, budget, gate, g, o, gap)

    report.csv(name, wall * 1e6,
               f"T={comp.sustained_throughput:.4g}rps_tpa="
               f"{comp.throughput_per_area:.4g}_gap={gap * 100:.2f}pct")


def _write_trajectory(report, budget, gate, g, o, gap) -> None:
    """``artifacts/bench/BENCH_soc.json`` — sustained throughput per
    area across every committed mix (the ROADMAP trajectory file)."""
    mixes: Dict[str, dict] = {}
    for mix_name in sorted(MIXES):
        _, comp = _compose(mix_name, budget)
        mixes[mix_name] = {
            "sustained_throughput_rps": comp.sustained_throughput,
            "area_mm2": comp.area_mm2,
            "power_w": comp.power_w,
            "bw_gbps": comp.bw_gbps,
            "throughput_per_area_rps_per_mm2": comp.throughput_per_area,
            "replicas": {a.app: a.replicas for a in comp.allocations},
            "points": {a.app: a.point.index for a in comp.allocations},
            "method": comp.method,
        }
    doc = {"version": 1, "bench": "soc_compose",
           "generated_by": "python -m benchmarks.run --cell "
                           f"soc/soc-analytical-{PRIMARY}",
           "budget": budget.to_json(),
           "gate": {"budget": gate.to_json(),
                    "greedy_T": g.sustained_throughput,
                    "exhaustive_T": o.sustained_throughput,
                    "gap": gap, "max_gap": GATE_MAX_GAP},
           "mixes": mixes}
    path = os.path.join(report.out_dir, "BENCH_soc.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    import argparse
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", choices=sorted(MIXES), default=PRIMARY)
    args = ap.parse_args()
    from run import CellReport
    from scenarios import Cell
    run(CellReport(Cell("soc", "soc", "analytical", args.variant)),
        Cell("soc", "soc", "analytical", args.variant))
