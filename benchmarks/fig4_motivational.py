"""Fig. 4: the (ports x unrolls) design space of the Gradient component.

Reproduces the paper's motivational example: sweeping the PLM port count
moves both latency and area by integer factors; unrolling moves latency
within a port region with diminishing returns; the with-memory span
dwarfs the dual-port-only span.  Also prices the same knob pair on the
TPU side via the wami_gradient Pallas kernel's VMEM/grid model
(DESIGN.md §2's "ports -> banks -> VMEM tiles" analogy).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.apps.wami import wami_knob_space
from repro.core import InvocationRequest, OracleLedger, span
from repro.core.registry import build_tool
from repro.kernels.wami_gradient import grid_steps, vmem_bytes

# the Gradient component is WAMI's; both oracle families price it
SCENARIOS = {"apps": ("wami",), "backends": "*"}


def _gradient_rows(backend: str):
    """The priced (ports x unrolls) points of the Gradient component.

    Both oracles resolve through the registry (``build_tool("wami",
    backend)``).  ``analytical`` sweeps the full Table-1 knob space
    through the HLS model.  ``pallas`` replays the *measured* points of
    the checked-in recording — the subset the COSMOS drive actually
    paid for (exhaustively measuring the space is exactly what the
    paper's methodology avoids).
    """
    space = wami_knob_space("gradient")       # canonical Table-1 bounds
    tool = OracleLedger(build_tool("wami", backend), workers=8)
    if backend == "pallas":
        store = tool.tool.store           # the native-tile recording
        keys = sorted(k for k in store.entries if k[0] == "gradient")
        requests = [InvocationRequest("gradient", unrolls=u, ports=p)
                    for _, p, u in keys]
        unit = ("lam_ms", "area_bytes", 1e3)
    else:
        requests = [InvocationRequest("gradient", unrolls=unrolls,
                                      ports=ports)
                    for ports in space.ports()
                    for unrolls in range(max(1, ports),
                                         space.max_unrolls + 1)]
        unit = ("lam_ms", "area_mm2", 1e3)
    rows: List[Dict] = []
    for req, s in zip(requests, tool.evaluate_batch(requests)):
        if s.feasible:
            rows.append({"ports": req.ports, "unrolls": req.unrolls,
                         "lam_ms": s.lam * unit[2], "area": s.area})
    return rows, unit


def run(report, cell) -> None:
    backend = cell.backend
    t0 = time.time()
    rows, (lam_col, area_col, _) = _gradient_rows(backend)
    wall = time.time() - t0

    all_lam = [r["lam_ms"] for r in rows]
    all_area = [r["area"] for r in rows]
    dual = [r for r in rows if r["ports"] == 2]
    lam_span, area_span = span(all_lam), span(all_area)
    lam_dual = span([r["lam_ms"] for r in dual]) if dual else 1.0
    area_dual = span([r["area"] for r in dual]) if dual else 1.0

    lines = [f"# Fig. 4 — Gradient design space ({len(rows)} syntheses, "
             f"backend={backend})",
             f"ports,unrolls,{lam_col},{area_col}"]
    lines += [f"{r['ports']},{r['unrolls']},{r['lam_ms']:.4f},"
              f"{r['area']:.4f}" for r in rows]
    lines.append(f"# span with memory co-design: lambda {lam_span:.2f}x, "
                 f"area {area_span:.2f}x (paper: 7.9x / 3.7x)")
    lines.append(f"# span dual-port only:        lambda {lam_dual:.2f}x, "
                 f"area {area_dual:.2f}x (paper: 1.4x / 1.2x)")
    lines.append("# TPU analogue (wami_gradient kernel, 512x512 frame):")
    lines.append("# ports,unrolls,vmem_bytes_per_step,grid_steps")
    for ports in (1, 2, 4, 8):
        for unrolls in (8, 32):
            lines.append(f"# {ports},{unrolls},"
                         f"{vmem_bytes(512, 512, ports=ports, unrolls=unrolls)},"
                         f"{grid_steps(512, 512, ports=ports, unrolls=unrolls)}")
    name = ("fig4_motivational" if backend == "analytical"
            else f"fig4_motivational_{backend}")
    report.write(name, lines)
    csv_name = ("fig4_gradient_space" if backend == "analytical"
                else f"fig4_gradient_space_{backend}")
    report.csv(csv_name, wall * 1e6 / max(1, len(rows)),
               f"lam_span={lam_span:.2f}x_vs_dual={lam_dual:.2f}x")
