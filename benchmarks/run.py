# One bench module per paper table; one cell per app x backend x variant.
"""Benchmark harness: the registry-driven scenario-matrix runner.

    PYTHONPATH=src python -m benchmarks.run                # full matrix
    PYTHONPATH=src python -m benchmarks.run --list         # enumerate only
    PYTHONPATH=src python -m benchmarks.run --only fig10
    PYTHONPATH=src python -m benchmarks.run --app wami --backend pallas
    PYTHONPATH=src python -m benchmarks.run --cell fig10/wami-pallas-share_plm
    PYTHONPATH=src python -m benchmarks.run --emit-docs    # docs/matrix.md

The matrix is enumerated from each bench's ``SCENARIOS`` table expanded
against the App/Backend registry (benchmarks/scenarios.py): every
registered app x backend x variant cell appears exactly once, and cells
that cannot run are *reported as skipped with a reason*, never silently
absent.  Unknown ``--only``/``--app``/``--backend``/``--cell`` names
exit non-zero and list what IS registered (the registry's error style).

Each executed cell writes ``artifacts/bench/<bench>/<app>-<backend>
[-variant].csv`` plus a machine-readable ``artifacts/bench/matrix.json``
summary; stdout carries one ``name,us_per_call,derived`` summary row per
measurement (see docs/benchmarks.md for what ``derived`` means per
bench).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from . import scenarios as S
except ImportError:                      # standalone: python benchmarks/run.py
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import scenarios as S

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
DOCS_MD = os.path.join(os.path.dirname(__file__), "..", "docs", "matrix.md")


class Report:
    """Legacy flat report: ``write`` lands ``<out_dir>/<name>.csv``.
    The standalone bench ``__main__`` blocks still use it."""

    def __init__(self, out_dir: str = OUT_DIR):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.rows = []

    def _path(self, name: str) -> str:
        return os.path.join(self.out_dir, f"{name}.csv")

    def write(self, name: str, lines):
        path = self._path(name)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")

    def write_json(self, name: str, doc, *, kind: str = "plans") -> str:
        """Sidecar JSON artifact next to the cell's CSV (same basename,
        ``.<kind>.json`` extension) — e.g. the committed memory-plan
        records ``python -m repro.core.analysis.verify`` re-proves.
        Deterministic bytes: sorted keys, fixed indent."""
        base, _ = os.path.splitext(self._path(name))
        path = f"{base}.{kind}.json"
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def csv(self, name: str, us_per_call: float, derived: str):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        print(row, flush=True)


class CellReport(Report):
    """Per-cell report: every ``write`` routes to the cell's artifact
    path ``<out_dir>/<bench>/<app>-<backend>[-variant].csv`` (the
    ``name`` argument is kept for the legacy callers' benefit but does
    not pick the file)."""

    def __init__(self, cell: S.Cell, out_dir: str = OUT_DIR):
        super().__init__(out_dir)
        self.cell = cell

    def _path(self, name: str) -> str:
        return os.path.join(self.out_dir, self.cell.artifact)


_PLURAL = {"bench": "benches"}


def _unknown(kind: str, bad, valid) -> int:
    plural = _PLURAL.get(kind, kind + "s")
    print(f"unknown {kind} {sorted(bad)!r}; registered {plural}: "
          f"{sorted(valid)}", file=sys.stderr)
    return 2


def _split(values):
    out = []
    for v in values or ():
        out += [p for p in v.split(",") if p]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="registry-driven scenario-matrix bench runner")
    ap.add_argument("--list", action="store_true",
                    help="print the enumerated cell matrix (run/skip + "
                         "reason) without running anything")
    ap.add_argument("--only", action="append", default=None,
                    metavar="BENCH", help="run only these benches "
                    "(repeatable / comma-separated)")
    ap.add_argument("--app", action="append", default=None,
                    help="run only cells of these apps")
    ap.add_argument("--backend", action="append", default=None,
                    help="run only cells of these backends")
    ap.add_argument("--cell", action="append", default=None,
                    metavar="BENCH/APP-BACKEND[-VARIANT]",
                    help="run exactly these cells (repeatable)")
    ap.add_argument("--out-dir", default=OUT_DIR,
                    help="artifact root (default artifacts/bench)")
    ap.add_argument("--emit-docs", nargs="?", const=DOCS_MD, default=None,
                    metavar="PATH",
                    help="regenerate docs/matrix.md from the registry "
                         "and exit")
    args = ap.parse_args(argv)

    cells = S.enumerate_matrix()

    # -- filter validation: unknown names are an error, not a no-op ----
    only = _split(args.only)
    bad = [b for b in only if b not in S.BENCH_MODULES]
    if bad:
        return _unknown("bench", bad, S.BENCH_MODULES)
    apps_f = _split(args.app)
    bad = [a for a in apps_f if a not in {sc.cell.app for sc in cells}]
    if bad:
        return _unknown("app", bad, {sc.cell.app for sc in cells})
    backends_f = _split(args.backend)
    bad = [b for b in backends_f
           if b not in {sc.cell.backend for sc in cells}]
    if bad:
        return _unknown("backend", bad,
                        {sc.cell.backend for sc in cells})
    cells_f = _split(args.cell)
    ids = {sc.cell.id for sc in cells}
    bad = [c for c in cells_f if c not in ids]
    if bad:
        return _unknown("cell", bad, ids)

    if args.emit_docs:
        # docs describe the whole matrix; filters don't apply here
        text = S.render_matrix_md(cells)
        with open(args.emit_docs, "w") as f:
            f.write(text)
        print(f"emit-docs: wrote {os.path.relpath(args.emit_docs)} "
              f"({len(cells)} cells)")
        return 0

    def selected(sc: S.ScenarioCell) -> bool:
        c = sc.cell
        if only and c.bench not in only:
            return False
        if apps_f and c.app not in apps_f:
            return False
        if backends_f and c.backend not in backends_f:
            return False
        if cells_f and c.id not in cells_f:
            return False
        return True

    if args.list:
        subset = [sc for sc in cells if selected(sc)]
        print(S.render_list(subset))
        unexplained = [sc.cell.id for sc in subset if not sc.runnable
                       and not (sc.skip_reason or "").strip()]
        return 1 if unexplained else 0

    modules = S.bench_modules()
    out_dir = args.out_dir
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for sc in cells:
        entry = {"bench": sc.cell.bench, "app": sc.cell.app,
                 "backend": sc.cell.backend, "variant": sc.cell.variant,
                 "id": sc.cell.id, "reason": sc.skip_reason}
        if not selected(sc):
            entry["status"] = "filtered"
        elif not sc.runnable:
            entry["status"] = "skip"
            if cells_f and sc.cell.id in cells_f:
                # a cell the caller named explicitly must actually run
                failures += 1
                print(f"{sc.cell.id},ERROR,requested cell cannot run: "
                      f"{sc.skip_reason}", flush=True)
            else:
                print(f"# skip {sc.cell.id}: {sc.skip_reason}", flush=True)
        else:
            report = CellReport(sc.cell, out_dir)
            try:
                modules[sc.cell.bench].run(report, sc.cell)
                entry["status"] = "run"
                entry["artifact"] = sc.cell.artifact
                entry["summary"] = list(report.rows)
            except Exception as e:  # noqa: BLE001
                failures += 1
                entry["status"] = "error"
                entry["reason"] = f"{type(e).__name__}:{e}"
                print(f"{sc.cell.id},ERROR,{type(e).__name__}:{e}",
                      flush=True)
                traceback.print_exc()
        records.append(entry)

    counts = {}
    for entry in records:
        counts[entry["status"]] = counts.get(entry["status"], 0) + 1
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "matrix.json"), "w") as f:
        json.dump({"version": 1,
                   "generated_by": "python -m benchmarks.run",
                   "counts": counts, "cells": records},
                  f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# matrix: " + " ".join(f"{k}={v}"
                                   for k, v in sorted(counts.items())),
          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
