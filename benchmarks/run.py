# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run [--only fig10]

Detailed tables land in artifacts/bench/<name>.csv; the stdout CSV is the
summary line per bench (name, us_per_call, derived metric).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


class Report:
    def __init__(self):
        os.makedirs(OUT_DIR, exist_ok=True)
        self.rows = []

    def write(self, name: str, lines):
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w") as f:
            f.write("\n".join(lines) + "\n")

    def csv(self, name: str, us_per_call: float, derived: str):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        print(row, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", choices=["analytical", "pallas"],
                    default="analytical",
                    help="oracle backend for the benches that support it "
                         "(fig4, fig10, kernels, fleet — all resolved "
                         "through the core.registry); pallas replays the "
                         "checked-in measurement recordings")
    ap.add_argument("--share-plm", action="store_true",
                    help="memory-co-design variant for the benches that "
                         "support it (fig10): tile knob axis + shared-PLM "
                         "system cost via the core.plm planner")
    args = ap.parse_args()

    from . import (autoshard_llm, fig4_motivational, fig10_pareto,
                   fig11_invocations, fleet_dse, kernels_micro,
                   roofline_table, table1_characterization)
    benches = {
        "fig4": fig4_motivational,
        "table1": table1_characterization,
        "fig10": fig10_pareto,
        "fig11": fig11_invocations,
        "roofline": roofline_table,
        "kernels": kernels_micro,
        "autoshard": autoshard_llm,
        "fleet": fleet_dse,
    }
    report = Report()
    print("name,us_per_call,derived")
    failures = 0
    for key, mod in benches.items():
        if args.only and key != args.only:
            continue
        try:
            import inspect
            params = inspect.signature(mod.run).parameters
            kw = {}
            if "backend" in params:
                kw["backend"] = args.backend
            if "share_plm" in params and args.share_plm:
                kw["share_plm"] = True
            mod.run(report, **kw)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{key},ERROR,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
