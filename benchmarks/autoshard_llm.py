"""COSMOS-TPU planning (beyond-paper): knob ladders priced analytically.

For each train cell the planner walks the Algorithm-1-style knob ladder
(microbatches x remat) and prices HBM per device; the chosen rung is the
one the dry-run compiles (one XLA invocation instead of a ladder of
them — the paper's invocation-frugality argument on the XLA oracle).
Accuracy of the priced model vs compiled memory_analysis() is reported
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import time

from repro.configs import SHAPES, get_config, list_archs
from repro.core.autotune import (HBM_BYTES_PER_CHIP, choose_train_knobs,
                                 price_train_step)

MESH = {"data": 16, "model": 16}

# a fixed pseudo-cell: the planner walks the LLM config zoo through the
# analytical autotune pricing, not a registered App's TMG
SCENARIOS = {"pairs": (("zoo", "analytical"),)}


def run(report, cell) -> None:
    t0 = time.time()
    shape = SHAPES[0]           # train_4k
    lines = ["# COSMOS-TPU planner: train_4k knob choice per arch "
             "(256-chip pod, 16 GB budget)",
             "arch,microbatches,remat,accum,planned_gb,fits,ladder_rungs_priced"]
    n_fit = 0
    for arch in list_archs():
        cfg = get_config(arch)
        # price the whole ladder for visibility
        rungs = 0
        for mb in (1, 2, 4, 8, 16, 32, 64):
            if shape.global_batch // 16 < mb:
                break
            rungs += 1
        plan = choose_train_knobs(cfg, shape, MESH)
        fits = plan.est_bytes <= HBM_BYTES_PER_CHIP
        n_fit += fits
        lines.append(f"{arch},{plan.microbatches},{plan.remat},"
                     f"{plan.accum_dtype},{plan.est_bytes / 1e9:.1f},"
                     f"{'Y' if fits else 'N'},{rungs}")
    lines.append("# an exhaustive compile sweep would cost "
                 "(7 mb x 3 remat) = 21 compiles/arch; the planner "
                 "compiles 1 (21x fewer oracle invocations, the Fig. 11 "
                 "argument on XLA)")
    report.write("autoshard_llm", lines)
    report.csv("autoshard_planner", (time.time() - t0) * 1e6,
               f"fit={n_fit}/{len(list_archs())}_archs")
