"""COSMOS-TPU planning (beyond-paper): knob ladders priced analytically.

For each train cell the planner walks the Algorithm-1-style knob ladder
(microbatches x remat) and prices HBM per device; the chosen rung is the
one the dry-run compiles (one XLA invocation instead of a ladder of
them — the paper's invocation-frugality argument on the XLA oracle).
Accuracy of the priced model vs compiled memory_analysis() is reported
in EXPERIMENTS.md §Perf.

The second pseudo-cell (``service/soak``) is the multi-tenant DSE
service soak: N tenants over >= 2 apps x 2 backends driven concurrently
through :class:`repro.serve.DSEService` with ``workers > 1`` at both
the service and session level, gated on byte-equality of every
tenant's front against its isolated sequential run AND on the shared
ledger pricing strictly fewer real invocations than the tenants' sum.
It writes ``artifacts/bench/BENCH_serve.json`` — the repo's perf
trajectory file (queries/sec, coalescing hit rate, invocation counts
per PR).  ``DSE_SOAK_TENANTS=2`` shrinks it to the cheap two-tenant
load CI runs on every push (docs/service.md).
"""

from __future__ import annotations

import json
import os
import time

from repro.configs import SHAPES, get_config, list_archs
from repro.core.autotune import (HBM_BYTES_PER_CHIP, choose_train_knobs,
                                 price_train_step)

MESH = {"data": 16, "model": 16}

# fixed pseudo-cells: the zoo planner walks the LLM config zoo through
# the analytical autotune pricing (no registered App's TMG), the
# service soak drives registered apps through the DSE service, and the
# service trace commits the deterministic logical-clock trace artifact
SCENARIOS = {"pairs": (("zoo", "analytical"), ("service", "soak"),
                       ("service", "trace"))}


def _soak_queries(tenants):
    """The soak tenant mix, overlap-first: the first two tenants share
    one oracle pool (characterization is delta-independent, so the
    two-tenant CI soak already exercises coalescing + the shared
    cache); four tenants cover 2 apps x 2 backends (the ISSUE
    acceptance shape)."""
    from repro.core import DSEQuery
    from repro.core.registry import get_app, get_backend
    base = [
        DSEQuery(app="wami", backend="analytical", workers=2, tenant="t0"),
        DSEQuery(app="wami", backend="analytical", delta=0.5, tenant="t1"),
        DSEQuery(app="wami", backend="pallas", share_plm=True,
                 workers=2, tenant="t2"),
        DSEQuery(app="fleet", backend="analytical", tenant="t3"),
    ]
    picked, dropped = [], []
    for q in base[:max(2, tenants)]:
        reason = get_backend(q.backend).skip_reason(get_app(q.app))
        (dropped if reason else picked).append((q, reason))
    return [q for q, _ in picked], [(q, r) for q, r in dropped]


def _run_soak(report, cell) -> None:
    from repro.core.registry import build_query_session
    from repro.serve import DSEService

    tenants = int(os.environ.get("DSE_SOAK_TENANTS", "4"))
    queries, dropped = _soak_queries(tenants)

    # isolated sequential references: per-tenant front + attribution
    iso = {}
    for q in queries:
        s = build_query_session(q)
        iso[q.tenant] = (s.run(), dict(s.ledger.invocations))

    t0 = time.time()
    with DSEService(max_pending=len(queries), workers=3) as svc:
        handles = svc.submit_all(queries)
        results = {h.query.tenant: h.result(timeout=600) for h in handles}
        stats = svc.stats()
    wall_s = time.time() - t0

    lines = [f"# DSE-service soak: {len(queries)} concurrent tenants "
             f"vs isolated sequential runs",
             "tenant,app,backend,share_plm,delta,invocations,"
             "front_identical,attribution_identical"]
    for h in handles:
        q = h.query
        ref, ref_inv = iso[q.tenant]
        res = results[q.tenant]
        front_ok = (repr(res.planned) == repr(ref.planned)
                    and repr(res.mapped) == repr(ref.mapped))
        inv_ok = h.invocations() == ref_inv
        lines.append(f"{q.tenant},{q.app},{q.backend},{q.share_plm},"
                     f"{q.delta},{sum(ref_inv.values())},"
                     f"{'Y' if front_ok else 'N'},"
                     f"{'Y' if inv_ok else 'N'}")
        # the gates: concurrency must be invisible per tenant
        assert front_ok, (f"tenant {q.tenant} ({q.app}/{q.backend}): "
                          f"concurrent front differs from isolated run")
        assert inv_ok, (f"tenant {q.tenant}: ledger attribution differs "
                        f"from isolated run")
    for q, reason in dropped:
        lines.append(f"# dropped {q.tenant} ({q.app}/{q.backend}): {reason}")

    tenant_sum = sum(sum(inv.values()) for _, inv in iso.values())
    shared = stats["shared_invocations"]
    # ...while the shared ledger prices strictly fewer real calls
    assert shared < tenant_sum, (
        f"no cross-tenant dedup: shared ledger {shared} >= "
        f"tenant sum {tenant_sum}")
    hits = sum(p["hits"] for p in stats["pools"].values())
    joins = sum(p["joins"] for p in stats["pools"].values())
    hit_rate = (hits + joins) / tenant_sum if tenant_sum else 0.0
    lines.append(f"# shared ledger: {shared} real invocations for "
                 f"{tenant_sum} attributed ({tenant_sum - shared} saved; "
                 f"{hits} cache hits + {joins} in-flight joins)")
    report.write("dse_service_soak", lines)
    report.csv("dse_service_soak", wall_s * 1e6,
               f"tenants={len(queries)}_saved="
               f"{tenant_sum - shared}of{tenant_sum}")

    # the perf trajectory file (ROADMAP: track across PRs); version 2
    # adds the per-pool outcome partition and the service-level
    # queue-wait / latency histograms from the metrics registry
    metrics = stats["metrics"]
    path = os.path.join(report.out_dir, "BENCH_serve.json")
    doc = {"version": 2, "bench": "dse-service soak",
           "generated_by": "python -m benchmarks.run --cell "
                           "autoshard/service-soak",
           "tenants": len(queries),
           "queries_per_sec": round(len(queries) / wall_s, 3),
           "wall_s": round(wall_s, 3),
           "coalescing_hit_rate": round(hit_rate, 4),
           "cache_hits": hits,
           "inflight_joins": joins,
           "tenant_invocations": tenant_sum,
           "shared_invocations": shared,
           "saved_invocations": tenant_sum - shared,
           "queue_wait_s": metrics["service.queue_wait_s"],
           "latency_s": metrics["service.latency_s"],
           "pools": {slug: {"invocations": p["invocations"],
                            "hits": p["hits"], "joins": p["joins"],
                            "batches": p["batches"],
                            "tenants": p["tenants"],
                            "outcomes": p["outcomes"]}
                     for slug, p in sorted(stats["pools"].items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def _run_trace(report, cell) -> None:
    """The committed observability artifact: a two-tenant service run
    driven strictly sequentially under a :class:`LogicalClock`, so the
    Chrome ``trace_event`` export is byte-identical across runs and
    machines (the CI determinism gate ``cmp``s two fresh runs).

    A second service instance reuses the first one's persistent cache
    root so every outcome tag in the partition appears: ``fresh`` and
    ``cache_hit`` in pass 1, ``replay`` in pass 2 (``inflight_join``
    needs concurrent submitters and stays 0 here by construction —
    determinism requires the sequential drive; the soak cell covers
    joins).  Before exporting, the run re-proves the Fig. 11
    reconciliation invariants from the ISSUE acceptance gate.
    """
    import shutil
    import tempfile

    from repro.core import DSEQuery
    from repro.core.obs import (LogicalClock, MetricsRegistry, Tracer,
                                validate_chrome)
    from repro.serve import DSEService

    queries = [
        DSEQuery(app="wami", backend="analytical", tenant="alpha"),
        DSEQuery(app="wami", backend="analytical", delta=0.5, tenant="beta"),
    ]
    tracer = Tracer(clock=LogicalClock())
    cache_root = tempfile.mkdtemp(prefix="dse-trace-")
    ledgers = {}
    try:
        # pass 1 (cold cache): fresh + cache_hit outcomes.  flush_every=1
        # so pass 2 sees every entry on disk while svc stays open — its
        # worker threads stay alive, which keeps thread idents (and so
        # the tracer's tid assignment) from being reused by svc2.
        with DSEService(max_pending=4, workers=1, cache_root=cache_root,
                        flush_every=1, tracer=tracer,
                        metrics=MetricsRegistry()) as svc:
            for q in queries:
                h = svc.submit(q)
                h.result(timeout=600)       # sequential: determinism
                ledgers[q.tenant] = h.outcome_counts()
            stats1 = svc.stats()
            # pass 2 (warm persistent cache, new instance): replay
            with DSEService(max_pending=4, workers=1,
                            cache_root=cache_root, tracer=tracer,
                            metrics=MetricsRegistry()) as svc2:
                h = svc2.submit(DSEQuery(app="wami", backend="analytical",
                                         tenant="alpha2"))
                h.result(timeout=600)
                ledgers["alpha2"] = h.outcome_counts()
                stats2 = svc2.stats()

        # --- Fig. 11 reconciliation gates (ISSUE acceptance) ---------
        # per-tenant: the four outcomes partition all evaluated points,
        # and fresh+replay is exactly the ledger's real-invocation total
        point_counts = tracer.outcome_counts("oracle.point")
        tenant_total = {t: sum(c.values()) for t, c in ledgers.items()}
        agg = {}
        for counts in ledgers.values():
            for o, n in counts.items():
                agg[o] = agg.get(o, 0) + n
        assert {o: n for o, n in agg.items() if n} == point_counts, (
            f"ledger outcome counters {agg} != traced oracle.point "
            f"outcomes {point_counts}")
        assert agg.get("cache_hit", 0) > 0, "no cache_hit points"
        assert agg.get("inflight_join", 0) == 0, (
            "sequential drive cannot join flights")

        # shared level: every tenant-fresh point reaches the shared
        # oracle exactly once, and the shared fresh count is the real
        # tool-invocation total
        shared_counts = tracer.outcome_counts("shared.point")
        pool_outcomes = {}
        for stats in (stats1, stats2):
            for p in stats["pools"].values():
                for o, n in p["outcomes"].items():
                    pool_outcomes[o] = pool_outcomes.get(o, 0) + n
        pool_outcomes = {o: n for o, n in sorted(pool_outcomes.items()) if n}
        assert pool_outcomes == shared_counts, (
            f"pool outcome counters {pool_outcomes} != traced "
            f"shared.point outcomes {shared_counts}")
        # the tenant ledgers hold no persistent cache, so ``replay``
        # appears exactly where the restored entries live: the shared
        # pool cache that pass 2 rehydrated from disk
        assert shared_counts.get("replay", 0) > 0, (
            "pass 2 produced no replay points at the shared level")
        assert sum(shared_counts.values()) == agg["fresh"], (
            f"shared.point total {sum(shared_counts.values())} != "
            f"tenant fresh sum {agg['fresh']}")
        shared_real = (stats1["shared_invocations"]
                       + stats2["shared_invocations"])
        assert shared_counts.get("fresh", 0) == shared_real, (
            f"shared fresh {shared_counts.get('fresh', 0)} != shared "
            f"ledger total {shared_real}")

        doc = tracer.export_chrome()
        problems = validate_chrome(doc)
        assert not problems, f"invalid trace_event export: {problems[:5]}"
        report.write_json("service_trace", doc, kind="trace")

        lines = [f"# deterministic service trace: {len(ledgers)} queries, "
                 f"{len(doc['traceEvents'])} events (logical clock)",
                 "tenant,fresh,cache_hit,inflight_join,replay,total"]
        for tenant, counts in sorted(ledgers.items()):
            lines.append(f"{tenant},{counts.get('fresh', 0)},"
                         f"{counts.get('cache_hit', 0)},"
                         f"{counts.get('inflight_join', 0)},"
                         f"{counts.get('replay', 0)},{tenant_total[tenant]}")
        lines.append(f"# shared pool outcomes: {pool_outcomes} "
                     f"({shared_real} real tool invocations)")
        report.write("service_trace", lines)
        report.csv("service_trace", float(len(doc["traceEvents"])),
                   f"events_outcomes=f{agg.get('fresh', 0)}"
                   f"_c{agg.get('cache_hit', 0)}"
                   f"_r{shared_counts.get('replay', 0)}")
    finally:
        shutil.rmtree(cache_root, ignore_errors=True)


def run(report, cell) -> None:
    if cell.app == "service":
        if cell.backend == "trace":
            _run_trace(report, cell)
        else:
            _run_soak(report, cell)
        return
    _run_zoo(report, cell)




def _run_zoo(report, cell) -> None:
    t0 = time.time()
    shape = SHAPES[0]           # train_4k
    lines = ["# COSMOS-TPU planner: train_4k knob choice per arch "
             "(256-chip pod, 16 GB budget)",
             "arch,microbatches,remat,accum,planned_gb,fits,ladder_rungs_priced"]
    n_fit = 0
    for arch in list_archs():
        cfg = get_config(arch)
        # price the whole ladder for visibility
        rungs = 0
        for mb in (1, 2, 4, 8, 16, 32, 64):
            if shape.global_batch // 16 < mb:
                break
            rungs += 1
        plan = choose_train_knobs(cfg, shape, MESH)
        fits = plan.est_bytes <= HBM_BYTES_PER_CHIP
        n_fit += fits
        lines.append(f"{arch},{plan.microbatches},{plan.remat},"
                     f"{plan.accum_dtype},{plan.est_bytes / 1e9:.1f},"
                     f"{'Y' if fits else 'N'},{rungs}")
    lines.append("# an exhaustive compile sweep would cost "
                 "(7 mb x 3 remat) = 21 compiles/arch; the planner "
                 "compiles 1 (21x fewer oracle invocations, the Fig. 11 "
                 "argument on XLA)")
    report.write("autoshard_llm", lines)
    report.csv("autoshard_planner", (time.time() - t0) * 1e6,
               f"fit={n_fit}/{len(list_archs())}_archs")
