"""COSMOS-TPU planning (beyond-paper): knob ladders priced analytically.

For each train cell the planner walks the Algorithm-1-style knob ladder
(microbatches x remat) and prices HBM per device; the chosen rung is the
one the dry-run compiles (one XLA invocation instead of a ladder of
them — the paper's invocation-frugality argument on the XLA oracle).
Accuracy of the priced model vs compiled memory_analysis() is reported
in EXPERIMENTS.md §Perf.

The second pseudo-cell (``service/soak``) is the multi-tenant DSE
service soak: N tenants over >= 2 apps x 2 backends driven concurrently
through :class:`repro.serve.DSEService` with ``workers > 1`` at both
the service and session level, gated on byte-equality of every
tenant's front against its isolated sequential run AND on the shared
ledger pricing strictly fewer real invocations than the tenants' sum.
It writes ``artifacts/bench/BENCH_serve.json`` — the repo's perf
trajectory file (queries/sec, coalescing hit rate, invocation counts
per PR).  ``DSE_SOAK_TENANTS=2`` shrinks it to the cheap two-tenant
load CI runs on every push (docs/service.md).
"""

from __future__ import annotations

import json
import os
import time

from repro.configs import SHAPES, get_config, list_archs
from repro.core.autotune import (HBM_BYTES_PER_CHIP, choose_train_knobs,
                                 price_train_step)

MESH = {"data": 16, "model": 16}

# fixed pseudo-cells: the zoo planner walks the LLM config zoo through
# the analytical autotune pricing (no registered App's TMG), and the
# service soak drives registered apps through the DSE service
SCENARIOS = {"pairs": (("zoo", "analytical"), ("service", "soak"))}


def _soak_queries(tenants):
    """The soak tenant mix, overlap-first: the first two tenants share
    one oracle pool (characterization is delta-independent, so the
    two-tenant CI soak already exercises coalescing + the shared
    cache); four tenants cover 2 apps x 2 backends (the ISSUE
    acceptance shape)."""
    from repro.core import DSEQuery
    from repro.core.registry import get_app, get_backend
    base = [
        DSEQuery(app="wami", backend="analytical", workers=2, tenant="t0"),
        DSEQuery(app="wami", backend="analytical", delta=0.5, tenant="t1"),
        DSEQuery(app="wami", backend="pallas", share_plm=True,
                 workers=2, tenant="t2"),
        DSEQuery(app="fleet", backend="analytical", tenant="t3"),
    ]
    picked, dropped = [], []
    for q in base[:max(2, tenants)]:
        reason = get_backend(q.backend).skip_reason(get_app(q.app))
        (dropped if reason else picked).append((q, reason))
    return [q for q, _ in picked], [(q, r) for q, r in dropped]


def _run_soak(report, cell) -> None:
    from repro.core.registry import build_query_session
    from repro.serve import DSEService

    tenants = int(os.environ.get("DSE_SOAK_TENANTS", "4"))
    queries, dropped = _soak_queries(tenants)

    # isolated sequential references: per-tenant front + attribution
    iso = {}
    for q in queries:
        s = build_query_session(q)
        iso[q.tenant] = (s.run(), dict(s.ledger.invocations))

    t0 = time.time()
    with DSEService(max_pending=len(queries), workers=3) as svc:
        handles = svc.submit_all(queries)
        results = {h.query.tenant: h.result(timeout=600) for h in handles}
        stats = svc.stats()
    wall_s = time.time() - t0

    lines = [f"# DSE-service soak: {len(queries)} concurrent tenants "
             f"vs isolated sequential runs",
             "tenant,app,backend,share_plm,delta,invocations,"
             "front_identical,attribution_identical"]
    for h in handles:
        q = h.query
        ref, ref_inv = iso[q.tenant]
        res = results[q.tenant]
        front_ok = (repr(res.planned) == repr(ref.planned)
                    and repr(res.mapped) == repr(ref.mapped))
        inv_ok = h.invocations() == ref_inv
        lines.append(f"{q.tenant},{q.app},{q.backend},{q.share_plm},"
                     f"{q.delta},{sum(ref_inv.values())},"
                     f"{'Y' if front_ok else 'N'},"
                     f"{'Y' if inv_ok else 'N'}")
        # the gates: concurrency must be invisible per tenant
        assert front_ok, (f"tenant {q.tenant} ({q.app}/{q.backend}): "
                          f"concurrent front differs from isolated run")
        assert inv_ok, (f"tenant {q.tenant}: ledger attribution differs "
                        f"from isolated run")
    for q, reason in dropped:
        lines.append(f"# dropped {q.tenant} ({q.app}/{q.backend}): {reason}")

    tenant_sum = sum(sum(inv.values()) for _, inv in iso.values())
    shared = stats["shared_invocations"]
    # ...while the shared ledger prices strictly fewer real calls
    assert shared < tenant_sum, (
        f"no cross-tenant dedup: shared ledger {shared} >= "
        f"tenant sum {tenant_sum}")
    hits = sum(p["hits"] for p in stats["pools"].values())
    joins = sum(p["joins"] for p in stats["pools"].values())
    hit_rate = (hits + joins) / tenant_sum if tenant_sum else 0.0
    lines.append(f"# shared ledger: {shared} real invocations for "
                 f"{tenant_sum} attributed ({tenant_sum - shared} saved; "
                 f"{hits} cache hits + {joins} in-flight joins)")
    report.write("dse_service_soak", lines)
    report.csv("dse_service_soak", wall_s * 1e6,
               f"tenants={len(queries)}_saved="
               f"{tenant_sum - shared}of{tenant_sum}")

    # the perf trajectory file (ROADMAP: track across PRs)
    path = os.path.join(report.out_dir, "BENCH_serve.json")
    doc = {"version": 1, "bench": "dse-service soak",
           "generated_by": "python -m benchmarks.run --cell "
                           "autoshard/service-soak",
           "tenants": len(queries),
           "queries_per_sec": round(len(queries) / wall_s, 3),
           "wall_s": round(wall_s, 3),
           "coalescing_hit_rate": round(hit_rate, 4),
           "cache_hits": hits,
           "inflight_joins": joins,
           "tenant_invocations": tenant_sum,
           "shared_invocations": shared,
           "saved_invocations": tenant_sum - shared,
           "pools": {slug: {"invocations": p["invocations"],
                            "hits": p["hits"], "joins": p["joins"],
                            "batches": p["batches"],
                            "tenants": p["tenants"]}
                     for slug, p in sorted(stats["pools"].items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def run(report, cell) -> None:
    if cell.app == "service":
        _run_soak(report, cell)
        return
    _run_zoo(report, cell)


def _run_zoo(report, cell) -> None:
    t0 = time.time()
    shape = SHAPES[0]           # train_4k
    lines = ["# COSMOS-TPU planner: train_4k knob choice per arch "
             "(256-chip pod, 16 GB budget)",
             "arch,microbatches,remat,accum,planned_gb,fits,ladder_rungs_priced"]
    n_fit = 0
    for arch in list_archs():
        cfg = get_config(arch)
        # price the whole ladder for visibility
        rungs = 0
        for mb in (1, 2, 4, 8, 16, 32, 64):
            if shape.global_batch // 16 < mb:
                break
            rungs += 1
        plan = choose_train_knobs(cfg, shape, MESH)
        fits = plan.est_bytes <= HBM_BYTES_PER_CHIP
        n_fit += fits
        lines.append(f"{arch},{plan.microbatches},{plan.remat},"
                     f"{plan.accum_dtype},{plan.est_bytes / 1e9:.1f},"
                     f"{'Y' if fits else 'N'},{rungs}")
    lines.append("# an exhaustive compile sweep would cost "
                 "(7 mb x 3 remat) = 21 compiles/arch; the planner "
                 "compiles 1 (21x fewer oracle invocations, the Fig. 11 "
                 "argument on XLA)")
    report.write("autoshard_llm", lines)
    report.csv("autoshard_planner", (time.time() - t0) * 1e6,
               f"fit={n_fit}/{len(list_archs())}_archs")
