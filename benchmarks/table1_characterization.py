"""Table 1: per-component characterization — COSMOS vs No-Memory spans."""

from __future__ import annotations

import statistics
import time

from repro.apps.wami import wami_cosmos
from repro.apps.wami.pipeline import wami_cosmos_no_memory

# the COSMOS-vs-No-Memory span comparison is an analytical-model
# experiment (the No-Memory ablation has no measured counterpart)
SCENARIOS = {"apps": ("wami",), "backends": ("analytical",)}


def run(report, cell) -> None:
    t0 = time.time()
    full = wami_cosmos(delta=0.25)
    nomem = wami_cosmos_no_memory(delta=0.25)
    wall = time.time() - t0

    lines = ["# Table 1 — component characterization (COSMOS vs No Memory)",
             "component,reg,lam_span,area_span,nm_lam_span,nm_area_span"]
    ls_c, as_c, ls_n, as_n = [], [], [], []
    for name, c in full.characterizations.items():
        n = nomem.characterizations[name]
        lines.append(f"{name},{len(c.regions)},{c.lam_span:.2f},"
                     f"{c.area_span:.2f},{n.lam_span:.2f},{n.area_span:.2f}")
        ls_c.append(c.lam_span); as_c.append(c.area_span)
        ls_n.append(n.lam_span); as_n.append(n.area_span)
    avg = (statistics.mean(ls_c), statistics.mean(as_c),
           statistics.mean(ls_n), statistics.mean(as_n))
    lines.append(f"AVERAGE,-,{avg[0]:.2f},{avg[1]:.2f},{avg[2]:.2f},{avg[3]:.2f}")
    lines.append(f"# paper: 4.06x/2.58x (COSMOS) vs 1.73x/1.22x (No Memory)")
    report.write("table1_characterization", lines)
    report.csv("table1_spans", wall * 1e6,
               f"lam={avg[0]:.2f}x/{avg[2]:.2f}x_area={avg[1]:.2f}x/{avg[3]:.2f}x")
