"""Table 1: per-component characterization — COSMOS vs No-Memory spans."""

from __future__ import annotations

import json
import os
import statistics
import time

from repro.apps.wami import wami_cosmos
from repro.apps.wami.pipeline import wami_cosmos_no_memory

# the COSMOS-vs-No-Memory span comparison is an analytical-model
# experiment (the No-Memory ablation has no measured counterpart)
SCENARIOS = {"apps": ("wami",), "backends": ("analytical",)}


def _ledgered_run(reg, *, batch=False, guided=False):
    """One full wami analytical session through a metrics-instrumented
    ledger; returns (session, result, ledger, invoke-wall histogram)."""
    from repro.core import BatchPricer, OracleLedger, build_session, build_tool
    tool = build_tool("wami", "analytical")
    if batch or guided:
        tool = BatchPricer.wrap(tool)
    ledger = OracleLedger(tool, metrics=reg)
    sess = build_session("wami", "analytical", ledger=ledger, guided=guided)
    res = sess.run()
    hist = reg.snapshot()["oracle.invoke_wall_s"]
    return sess, res, ledger, hist


_RAW_PLANE_UNROLLS = 128


def _scalar_plane(tool):
    """Wall time for the scalar path to price the full (pow2 ports x
    unrolls) knob plane of every component, one call per point."""
    n = 0
    t0 = time.perf_counter()
    for name in tool.components:
        for ports in (1, 2, 4, 8):
            for unrolls in range(1, _RAW_PLANE_UNROLLS + 1):
                tool.synthesize(name, unrolls=unrolls, ports=ports)
                n += 1
    return time.perf_counter() - t0, n


def _batched_plane(tool, pricer_cls):
    """Wall time for the vectorized path to price the identical plane:
    one corner request per component forces the covering grid build."""
    pricer = pricer_cls(tool)
    t0 = time.perf_counter()
    for name in tool.components:
        pricer.synthesize(name, unrolls=_RAW_PLANE_UNROLLS, ports=8)
    return time.perf_counter() - t0, pricer.grid_points_priced


def _write_pricing(report) -> None:
    """BENCH_pricing.json v2 — the vectorized-pricing + frugality bench.

    Two subtrees (docs/benchmarks.md has the schema):

    * ``deterministic`` — ledger counts, grid accounting, and the
      front-equality proofs.  Byte-identical between any two runs on
      any host; the CI ``pricing-frugality`` job cmp's exactly this
      subtree (two-run gate + committed-artifact freshness).
    * ``timing`` — host-dependent throughput (points priced per second
      through the scalar and batched paths, raw-loop speedup, best of
      3).  CI gates these by floors (batched >= 10x scalar; guided
      frugality >= 14.6x the exhaustive spend), never by bytes.
    """
    from repro.apps.wami import wami_exhaustive
    from repro.core import BatchPricer, build_tool
    from repro.core.obs import MetricsRegistry

    scalar_s, scalar_res, scalar_led, scalar_hist = _ledgered_run(
        MetricsRegistry())
    batch_s, batch_res, batch_led, batch_hist = _ledgered_run(
        MetricsRegistry(), batch=True)
    guided_s, guided_res, guided_led, _ = _ledgered_run(
        MetricsRegistry(), guided=True)
    exhaustive = wami_exhaustive()

    def front(res):
        return repr(res.planned), repr(res.mapped)

    pricer = batch_led.tool               # the session's BatchPricer
    guided_stats = guided_s.guided or {}
    ratio = exhaustive.total_invocations / max(1, guided_led.total())
    deterministic = {
        "exhaustive": {"invocations": exhaustive.total_invocations},
        "unguided": {"points": scalar_led.total(),
                     "per_component": dict(sorted(
                         scalar_led.invocations.items())),
                     "outcomes": scalar_led.outcome_counts()},
        "batched": {"points": batch_led.total(),
                    "outcomes": batch_led.outcome_counts(),
                    "ledger_books_equal_scalar":
                        dict(batch_led.invocations)
                        == dict(scalar_led.invocations)
                        and dict(batch_led.failed)
                        == dict(scalar_led.failed),
                    "front_equal_scalar":
                        front(batch_res) == front(scalar_res),
                    "grid": {"builds": pricer.grid_builds,
                             "points_priced": pricer.grid_points_priced,
                             "lookups": pricer.lookups,
                             "fallbacks": pricer.fallbacks}},
        "guided": {"points": guided_led.total(),
                   "per_component": dict(sorted(
                       guided_led.invocations.items())),
                   "confirmed": sum(v["confirmed"]
                                    for v in guided_stats.values()),
                   "fell_back": sorted(n for n, v in guided_stats.items()
                                       if v["fell_back"]),
                   "grid_invocations": sum(v["grid_invocations"]
                                           for v in guided_stats.values()),
                   "front_equal_unguided":
                       front(guided_res) == front(scalar_res),
                   "reduction_vs_exhaustive_x": round(ratio, 2)},
    }

    # host-dependent throughput.  The headline (the CI >=10x floor)
    # prices the identical full knob plane both ways, best of 3 —
    # warm: each rep rebuilds its grids, while the pure-function noise
    # memo is process-wide by design, which is the steady state every
    # repeated session and the service's pool-level pricer run at.
    # Ledger-path numbers from the invoke-wall histograms ride along
    # for the session-shaped (cold, 141-point) view.
    tool = build_tool("wami", "analytical")
    _scalar_plane(tool), _batched_plane(tool, BatchPricer)   # warmup rep
    raw_scalar, raw_n = min(_scalar_plane(tool) for _ in range(3))
    raw_batch, _ = min(_batched_plane(tool, BatchPricer) for _ in range(3))
    scalar_pps = (scalar_led.total() / scalar_hist["sum"]
                  if scalar_hist["sum"] else None)
    batch_pps = (pricer.grid_points_priced / batch_hist["sum"]
                 if batch_hist["sum"] else None)
    timing = {
        "raw_plane_points": raw_n,
        "points_per_sec_scalar": round(raw_n / raw_scalar, 1),
        "points_per_sec_batched": round(raw_n / raw_batch, 1),
        "speedup_raw_plane_x": round(raw_scalar / raw_batch, 2),
        "ledger_path": {
            "points_per_sec_scalar": round(scalar_pps, 1)
                                     if scalar_pps else None,
            "points_per_sec_batched": round(batch_pps, 1)
                                      if batch_pps else None,
            "tool_wall_s_scalar": round(scalar_hist["sum"], 6),
            "tool_wall_s_batched": round(batch_hist["sum"], 6),
            "invoke_wall_hist": scalar_hist["buckets"],
        },
        "best_of": 3,
    }

    doc = {"version": 2, "bench": "vectorized-pricing+frugality",
           "generated_by": "python -m benchmarks.run --cell "
                           "table1/wami-analytical",
           "app": "wami", "backend": "analytical",
           "deterministic": deterministic, "timing": timing}
    path = os.path.join(report.out_dir, "BENCH_pricing.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    report.csv("oracle_pricing",
               scalar_hist["sum"] / max(1, scalar_led.total()) * 1e6,
               f"points={scalar_led.total()}_batched_x="
               f"{timing['speedup_raw_plane_x']}_frugality_x="
               f"{deterministic['guided']['reduction_vs_exhaustive_x']}")


def run(report, cell) -> None:
    t0 = time.time()
    full = wami_cosmos(delta=0.25)
    nomem = wami_cosmos_no_memory(delta=0.25)
    wall = time.time() - t0

    lines = ["# Table 1 — component characterization (COSMOS vs No Memory)",
             "component,reg,lam_span,area_span,nm_lam_span,nm_area_span"]
    ls_c, as_c, ls_n, as_n = [], [], [], []
    for name, c in full.characterizations.items():
        n = nomem.characterizations[name]
        lines.append(f"{name},{len(c.regions)},{c.lam_span:.2f},"
                     f"{c.area_span:.2f},{n.lam_span:.2f},{n.area_span:.2f}")
        ls_c.append(c.lam_span); as_c.append(c.area_span)
        ls_n.append(n.lam_span); as_n.append(n.area_span)
    avg = (statistics.mean(ls_c), statistics.mean(as_c),
           statistics.mean(ls_n), statistics.mean(as_n))
    lines.append(f"AVERAGE,-,{avg[0]:.2f},{avg[1]:.2f},{avg[2]:.2f},{avg[3]:.2f}")
    lines.append(f"# paper: 4.06x/2.58x (COSMOS) vs 1.73x/1.22x (No Memory)")
    report.write("table1_characterization", lines)
    report.csv("table1_spans", wall * 1e6,
               f"lam={avg[0]:.2f}x/{avg[2]:.2f}x_area={avg[1]:.2f}x/{avg[3]:.2f}x")
    _write_pricing(report)
