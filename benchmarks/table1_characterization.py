"""Table 1: per-component characterization — COSMOS vs No-Memory spans."""

from __future__ import annotations

import json
import os
import statistics
import time

from repro.apps.wami import wami_cosmos
from repro.apps.wami.pipeline import wami_cosmos_no_memory

# the COSMOS-vs-No-Memory span comparison is an analytical-model
# experiment (the No-Memory ablation has no measured counterpart)
SCENARIOS = {"apps": ("wami",), "backends": ("analytical",)}


def _write_pricing(report) -> None:
    """The points-priced-per-second trajectory file: a full wami
    analytical DSE through a metrics-instrumented ledger, pricing
    throughput from the ``oracle.invoke_wall_s`` histogram (real tool
    invocations only — cache hits are free and excluded by
    construction)."""
    from repro.core import OracleLedger, build_session, build_tool
    from repro.core.obs import MetricsRegistry

    reg = MetricsRegistry()
    ledger = OracleLedger(build_tool("wami", "analytical"), metrics=reg)
    sess = build_session("wami", "analytical", ledger=ledger)
    t0 = time.time()
    sess.run()
    wall = time.time() - t0

    hist = reg.snapshot()["oracle.invoke_wall_s"]
    outcomes = ledger.outcome_counts()
    points = ledger.total()
    doc = {"version": 1, "bench": "points-priced-per-second",
           "generated_by": "python -m benchmarks.run --cell "
                           "table1/wami-analytical",
           "app": "wami", "backend": "analytical",
           "points": points,
           "points_per_sec": round(points / hist["sum"], 1)
                             if hist["sum"] else None,
           "tool_wall_s": round(hist["sum"], 6),
           "session_wall_s": round(wall, 3),
           "outcomes": outcomes,
           "invoke_wall_hist": hist["buckets"],
           "per_component": dict(sorted(ledger.invocations.items()))}
    path = os.path.join(report.out_dir, "BENCH_pricing.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    report.csv("oracle_pricing", hist["sum"] / points * 1e6 if points else 0.0,
               f"points={points}_per_sec="
               f"{doc['points_per_sec']}")


def run(report, cell) -> None:
    t0 = time.time()
    full = wami_cosmos(delta=0.25)
    nomem = wami_cosmos_no_memory(delta=0.25)
    wall = time.time() - t0

    lines = ["# Table 1 — component characterization (COSMOS vs No Memory)",
             "component,reg,lam_span,area_span,nm_lam_span,nm_area_span"]
    ls_c, as_c, ls_n, as_n = [], [], [], []
    for name, c in full.characterizations.items():
        n = nomem.characterizations[name]
        lines.append(f"{name},{len(c.regions)},{c.lam_span:.2f},"
                     f"{c.area_span:.2f},{n.lam_span:.2f},{n.area_span:.2f}")
        ls_c.append(c.lam_span); as_c.append(c.area_span)
        ls_n.append(n.lam_span); as_n.append(n.area_span)
    avg = (statistics.mean(ls_c), statistics.mean(as_c),
           statistics.mean(ls_n), statistics.mean(as_n))
    lines.append(f"AVERAGE,-,{avg[0]:.2f},{avg[1]:.2f},{avg[2]:.2f},{avg[3]:.2f}")
    lines.append(f"# paper: 4.06x/2.58x (COSMOS) vs 1.73x/1.22x (No Memory)")
    report.write("table1_characterization", lines)
    report.csv("table1_spans", wall * 1e6,
               f"lam={avg[0]:.2f}x/{avg[2]:.2f}x_area={avg[1]:.2f}x/{avg[3]:.2f}x")
    _write_pricing(report)
