"""Fig. 10: compositional DSE Pareto curve — planned (LP) vs mapped."""

from __future__ import annotations

import statistics
import time

from repro.apps.wami import wami_cosmos


def run(report) -> None:
    t0 = time.time()
    res = wami_cosmos(delta=0.25, workers=8)     # batched == sequential
    wall = time.time() - t0

    lines = ["# Fig. 10 — WAMI system Pareto: planned vs mapped",
             "theta_planned_fps,cost_planned_mm2,theta_mapped_fps,"
             "cost_mapped_mm2,sigma_pct"]
    sigmas = []
    for m in res.mapped:
        lines.append(f"{m.theta_planned:.2f},{m.cost_planned:.3f},"
                     f"{m.theta_actual:.2f},{m.cost_actual:.3f},"
                     f"{m.sigma_mismatch * 100:.1f}")
        sigmas.append(m.sigma_mismatch * 100)
    lines.append(f"# theta range [{res.theta_min:.2f}, {res.theta_max:.2f}] "
                 f"frames/s, {len(res.mapped)} points, delta=0.25")
    lines.append(f"# sigma: median {statistics.median(sigmas):.1f}% "
                 f"max {max(sigmas):.1f}% (paper: most <10%, a few >10% "
                 f"where region gaps force the conservative fallback)")
    report.write("fig10_pareto", lines)
    report.csv("fig10_pareto", wall * 1e6,
               f"points={len(res.mapped)}_median_sigma={statistics.median(sigmas):.1f}pct")
