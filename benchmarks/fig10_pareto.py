"""Fig. 10: compositional DSE Pareto curve — planned (LP) vs mapped.

``--backend analytical`` (default) drives the simulated HLS tool;
``--backend pallas`` replays the measured PallasOracle recording
(deterministic, no TPU) so the same planned-vs-mapped sigma analysis
runs on real kernel timings.
"""

from __future__ import annotations

import statistics
import time

from repro.apps.wami import wami_cosmos


def run(report, backend: str = "analytical") -> None:
    t0 = time.time()
    if backend == "pallas":
        from repro.apps.wami.pallas import wami_pallas_session
        res = wami_pallas_session(0.25, workers=8).run()
        cost_unit = "vmem_bytes"
    else:
        res = wami_cosmos(delta=0.25, workers=8)   # batched == sequential
        cost_unit = "mm2"
    wall = time.time() - t0

    lines = [f"# Fig. 10 — WAMI system Pareto: planned vs mapped "
             f"(backend={backend})",
             f"theta_planned_fps,cost_planned_{cost_unit},"
             f"theta_mapped_fps,cost_mapped_{cost_unit},sigma_pct"]
    sigmas = []
    for m in res.mapped:
        lines.append(f"{m.theta_planned:.2f},{m.cost_planned:.3f},"
                     f"{m.theta_actual:.2f},{m.cost_actual:.3f},"
                     f"{m.sigma_mismatch * 100:.1f}")
        sigmas.append(m.sigma_mismatch * 100)
    lines.append(f"# theta range [{res.theta_min:.2f}, {res.theta_max:.2f}] "
                 f"frames/s, {len(res.mapped)} points, delta=0.25")
    lines.append(f"# sigma: median {statistics.median(sigmas):.1f}% "
                 f"max {max(sigmas):.1f}% (paper: most <10%, a few >10% "
                 f"where region gaps force the conservative fallback)")
    name = ("fig10_pareto" if backend == "analytical"
            else f"fig10_pareto_{backend}")
    report.write(name, lines)
    report.csv(name, wall * 1e6,
               f"points={len(res.mapped)}_median_sigma="
               f"{statistics.median(sigmas):.1f}pct")
