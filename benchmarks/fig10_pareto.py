"""Fig. 10: compositional DSE Pareto curve — planned (LP) vs mapped.

``--backend analytical`` (default) drives the simulated HLS tool;
``--backend pallas`` replays the measured PallasOracle recording
(deterministic, no TPU) so the same planned-vs-mapped sigma analysis
runs on real kernel timings.

``--share-plm`` runs the memory-co-design variant: the tile knob opens
as a third axis and the map phase prices the memory subsystem through
the system-level PLM planner (docs/memory.md).  The report then carries
both fronts — the planned shared-bank system cost and the paper's naive
per-component sum — and the shared front dominates or equals the naive
one at every throughput point by construction.

Standalone, as the CI determinism gate (two runs must be byte-identical):

    PYTHONPATH=src python benchmarks/fig10_pareto.py --smoke --share-plm
"""

from __future__ import annotations

import statistics
import sys
import time

# the WAMI system Pareto, on both oracle families; share_plm is the
# memory-co-design variant (tile axis + shared-PLM system cost),
# tiles the multi-recording routing drive (measured backends with
# >= 2 recordings on disk), workers1 the fan-out determinism gate —
# all cell axes, not global flags
SCENARIOS = {"apps": ("wami",), "backends": "*",
             "variants": ("", "share_plm", "tiles", "workers1")}


def cell_skip_reason(app, backend, variant):
    """Tighten the default check for the new variants: ``tiles``
    replays multiple recordings (measured backends with >= 2 tiles on
    disk only); ``workers1`` runs everywhere the base cell does."""
    try:
        from .scenarios import default_skip_reason
    except ImportError:                      # standalone bench path
        from scenarios import default_skip_reason
    base = "share_plm" if variant in ("share_plm", "tiles") else ""
    reason = default_skip_reason(app, backend, base)
    if reason:
        return reason
    if variant == "tiles":
        if not backend.measured:
            return (f"tiles variant routes multiple recordings; backend "
                    f"{backend.name!r} has no measured surface")
        tiles = backend.supported_tiles(app)
        if len(tiles) < 2:
            return (f"tiles variant needs >= 2 recordings on disk; app "
                    f"{app.name!r} has {sorted(tiles)}")
    return None


def _share_plm_result(backend: str, workers: int = 8):
    """Registry-resolved: ``build_session("wami", backend,
    share_plm=True)``.  The measured drive goes through the classic
    :func:`wami_plm_session` wrapper (same ``build_session`` call
    underneath) so its measured-tiles default stays in one place.
    ``verify_plans=True`` makes the map phase a strict gate: every
    emitted memory plan is independently re-proved race-free by
    ``repro.core.analysis.verify`` before it lands in the report."""
    if backend == "pallas":
        from repro.apps.wami.pallas import wami_plm_session
        return wami_plm_session(0.25, workers=workers,
                                verify_plans=True).run()
    from repro.core.registry import build_session
    return build_session("wami", backend, share_plm=True,
                         workers=workers, verify_plans=True).run()


def _plans_doc(res) -> dict:
    """The committed ``*.plans.json`` sidecar: every mapped point's
    memory plan plus the LP schedule it conditions on, in the format
    ``python -m repro.core.analysis.verify`` re-proves (the artifact is
    the cross-environment source of truth — the verifier never re-runs
    the session)."""
    from repro.core.plm.spec import memory_plan_to_json
    points = []
    for m in sorted(res.mapped, key=lambda m: m.theta_planned):
        if m.memory_plan is None:
            continue
        points.append({
            "theta_planned": m.theta_planned,
            "schedule": (m.schedule.to_json()
                         if m.schedule is not None else None),
            "plan": memory_plan_to_json(m.memory_plan),
        })
    return {"app": "wami", "points": points}


def _run_tiles(report, cell) -> None:
    """The multi-recording drive: the shared-PLM front with *every*
    checked-in recording routed through the :class:`MeasurementSet`
    (the classic share_plm cell replays only the native tile and prices
    the rest through the calibrated fallback)."""
    from repro.apps.wami.pallas import wami_plm_session
    from repro.core.registry import get_app, get_backend
    tiles = tuple(sorted(
        get_backend(cell.backend).supported_tiles(get_app("wami"))))[:2]
    t0 = time.time()
    res = wami_plm_session(0.25, measured_tiles=tiles, workers=8,
                           verify_plans=True).run()
    wall = time.time() - t0
    lines = [f"# Fig. 10 tiles variant — shared-PLM WAMI front, "
             f"multi-recording routing (backend={cell.backend}, "
             f"measured tiles {'+'.join(str(t) for t in tiles)})",
             "theta_mapped_fps,cost_mapped_bytes,cost_unshared"]
    for m in sorted(res.mapped, key=lambda m: (m.theta_actual,
                                               m.cost_actual)):
        lines.append(f"{m.theta_actual:.2f},{m.cost_actual:.3f},"
                     f"{m.cost_unshared:.3f}")
    lines.append(f"# {len(res.mapped)} points; recordings routed: "
                 + ",".join(str(t) for t in tiles)
                 + " (vs native-only in the share_plm cell)")
    report.write(f"fig10_pareto_{cell.backend}_tiles", lines)
    report.csv(f"fig10_pareto_{cell.backend}_tiles", wall * 1e6,
               f"points={len(res.mapped)}_tiles="
               + "+".join(str(t) for t in tiles))


def _run_workers1(report, cell) -> None:
    """The fan-out determinism gate as a matrix cell: the workers=1
    sequential drive must produce the same front — point for point,
    knob for knob — as the workers=8 batched drive."""
    from repro.core.registry import build_session
    backend = cell.backend
    cost_unit = "vmem_bytes" if backend == "pallas" else "mm2"
    t0 = time.time()
    front1 = build_session("wami", backend, workers=1).run().pareto()
    front8 = build_session("wami", backend, workers=8).run().pareto()
    wall = time.time() - t0
    sig1 = repr([(p.perf, p.cost, p.knobs) for p in front1])
    sig8 = repr([(p.perf, p.cost, p.knobs) for p in front8])
    assert sig1 == sig8, (f"workers=1 front differs from workers=8 "
                          f"fan-out on backend {backend!r}")
    lines = [f"# Fig. 10 workers1 variant — WAMI front under workers=1 "
             f"(backend={backend})",
             f"theta_fps,cost_{cost_unit}"]
    for p in front1:
        lines.append(f"{p.perf:.2f},{p.cost:.3f}")
    lines.append(f"# {len(front1)} points, byte-identical to the "
                 f"workers=8 batched drive (repr-compared, knobs "
                 f"included)")
    report.write(f"fig10_pareto_{backend}_workers1", lines)
    report.csv(f"fig10_pareto_{backend}_workers1", wall * 1e6,
               f"points={len(front1)}_deterministic=yes")


def run(report, cell) -> None:
    from repro.core.registry import build_session
    if cell.variant == "tiles":
        return _run_tiles(report, cell)
    if cell.variant == "workers1":
        return _run_workers1(report, cell)
    backend = cell.backend
    share_plm = cell.variant == "share_plm"
    t0 = time.time()
    if share_plm:
        res = _share_plm_result(backend)
        cost_unit = "bytes" if backend == "pallas" else "mm2"
    else:
        res = build_session("wami", backend, workers=8).run()
        cost_unit = "vmem_bytes" if backend == "pallas" else "mm2"
    wall = time.time() - t0

    suffix = "_share_plm" if share_plm else ""
    lines = [f"# Fig. 10 — WAMI system Pareto: planned vs mapped "
             f"(backend={backend}{', shared PLM' if share_plm else ''})",
             f"theta_planned_fps,cost_planned_{cost_unit},"
             f"theta_mapped_fps,cost_mapped_{cost_unit},sigma_pct"
             + (",cost_unshared" if share_plm else "")]
    sigmas = []
    for m in res.mapped:
        # under the planner, sigma keeps comparing like with like: the
        # LP plans per-component (unshared) costs, so mapping fidelity
        # is planned vs the naive sum; the sharing saving is its own
        # column, not folded into sigma
        sigma = (abs(m.cost_unshared - m.cost_planned) / m.cost_planned
                 if share_plm else m.sigma_mismatch)
        row = (f"{m.theta_planned:.2f},{m.cost_planned:.3f},"
               f"{m.theta_actual:.2f},{m.cost_actual:.3f},"
               f"{sigma * 100:.1f}")
        if share_plm:
            row += f",{m.cost_unshared:.3f}"
        lines.append(row)
        sigmas.append(sigma * 100)
    lines.append(f"# theta range [{res.theta_min:.2f}, {res.theta_max:.2f}] "
                 f"frames/s, {len(res.mapped)} points, delta=0.25")
    lines.append(f"# sigma: median {statistics.median(sigmas):.1f}% "
                 f"max {max(sigmas):.1f}% (paper: most <10%, a few >10% "
                 f"where region gaps force the conservative fallback)")
    if share_plm:
        saved = [m.cost_unshared - m.cost_actual for m in res.mapped]
        groups = sorted({g for m in res.mapped for g in m.plm_groups})
        lines.append(f"# shared-PLM savings vs per-component sum: "
                     f"median {statistics.median(saved):.3f} "
                     f"max {max(saved):.3f} {cost_unit}")
        lines.append(f"# shared groups: "
                     + "; ".join("+".join(g) for g in groups))
    name = ("fig10_pareto" if backend == "analytical"
            else f"fig10_pareto_{backend}") + suffix
    report.write(name, lines)
    if share_plm and hasattr(report, "write_json"):
        report.write_json(name, _plans_doc(res))
    report.csv(name, wall * 1e6,
               f"points={len(res.mapped)}_median_sigma="
               f"{statistics.median(sigmas):.1f}pct")


def smoke(backend: str = "pallas") -> int:
    """The memory-co-design gate: shared-PLM front must dominate or
    equal the naive per-component-sum front at every point, be strictly
    cheaper somewhere, and the printout must be byte-identical across
    runs (CI runs it twice and compares).  No wall-clock output."""
    res = _share_plm_result(backend)
    lines = [f"fig10-smoke backend={backend} share-plm "
             f"points={len(res.mapped)}"]
    ok_dom, ok_strict = True, False
    for m in sorted(res.mapped, key=lambda m: (m.theta_actual,
                                               m.cost_actual)):
        if m.cost_actual > m.cost_unshared + 1e-9:
            ok_dom = False
        if m.cost_actual < m.cost_unshared * (1.0 - 1e-12):
            ok_strict = True
        lines.append(f"theta={m.theta_actual:.6g} "
                     f"shared={m.cost_actual:.6g} "
                     f"unshared={m.cost_unshared:.6g} "
                     f"groups={';'.join('+'.join(g) for g in m.plm_groups)}")
    tile_axis = sorted(
        n for n, ch in res.characterizations.items()
        if len({dict(p.knobs).get("tile", 0) for p in ch.points} - {0}) >= 2)
    lines.append(f"tile-axis components ({len(tile_axis)}): "
                 + ",".join(tile_axis))
    print("\n".join(lines))
    if not ok_dom:
        print("fig10-smoke: FAIL — shared-PLM cost exceeds the naive sum",
              file=sys.stderr)
        return 1
    if not ok_strict:
        print("fig10-smoke: FAIL — sharing never strictly cheaper",
              file=sys.stderr)
        return 1
    if len(tile_axis) < 3:
        print("fig10-smoke: FAIL — tile axis on fewer than 3 components",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    import argparse
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="deterministic shared-vs-naive dominance gate")
    ap.add_argument("--share-plm", action="store_true",
                    help="run the memory-co-design variant")
    ap.add_argument("--backend", choices=["analytical", "pallas"],
                    default="pallas")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(args.backend))
    from run import Report          # harness report, standalone
    from scenarios import Cell
    run(Report(), Cell("fig10", "wami", args.backend,
                       "share_plm" if args.share_plm else ""))
