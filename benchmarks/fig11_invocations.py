"""Fig. 11: HLS-tool invocations — exhaustive vs COSMOS, per component."""

from __future__ import annotations

import time

from repro.apps.wami import wami_cosmos, wami_exhaustive


def run(report) -> None:
    t0 = time.time()
    cos = wami_cosmos(delta=0.25)
    exh = wami_exhaustive()
    wall = time.time() - t0

    lines = ["# Fig. 11 — invocations to the HLS tool",
             "component,exhaustive,cosmos,reduction"]
    reductions = []
    for name in exh.invocations:
        e = exh.invocations[name]
        c = cos.invocations.get(name, 0)
        r = e / max(1, c)
        reductions.append(r)
        lines.append(f"{name},{e},{c},{r:.1f}x")
    total_r = exh.total_invocations / cos.total_invocations
    lines.append(f"TOTAL,{exh.total_invocations},{cos.total_invocations},"
                 f"{total_r:.1f}x")
    lines.append(f"# paper: 6.7x average, up to 14.6x per component")
    lines.append(f"# ours: {total_r:.1f}x average, up to {max(reductions):.1f}x")
    lines.append(f"# exhaustive composition would need "
                 f"{exh.combinations():.2e} combinations (paper: >9e12)")
    report.write("fig11_invocations", lines)
    report.csv("fig11_invocations", wall * 1e6,
               f"avg={total_r:.1f}x_max={max(reductions):.1f}x")
