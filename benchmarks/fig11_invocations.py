"""Fig. 11: HLS-tool invocations — exhaustive vs COSMOS, per component.

Also runnable standalone as a CI smoke gate:

    PYTHONPATH=src python benchmarks/fig11_invocations.py --smoke

which runs a reduced WAMI exploration and exits non-zero unless COSMOS
still beats the exhaustive baseline on invocations (ratio > 1).
"""

from __future__ import annotations

import sys
import time

# the frugality count compares against the WAMI exhaustive baseline,
# which only the analytical model can afford to price in full
SCENARIOS = {"apps": ("wami",), "backends": ("analytical",)}


def run(report, cell) -> None:
    from repro.apps.wami import wami_exhaustive
    from repro.core.registry import build_session

    t0 = time.time()
    session = build_session("wami", "analytical", delta=0.25, workers=8)
    cos = session.run()
    exh = wami_exhaustive(workers=8)
    wall = time.time() - t0

    lines = ["# Fig. 11 — invocations to the HLS tool",
             "component,exhaustive,cosmos,reduction"]
    reductions = []
    for name in exh.invocations:
        e = exh.invocations[name]
        c = cos.invocations.get(name, 0)
        r = e / max(1, c)
        reductions.append(r)
        lines.append(f"{name},{e},{c},{r:.1f}x")
    total_r = exh.total_invocations / cos.total_invocations
    lines.append(f"TOTAL,{exh.total_invocations},{cos.total_invocations},"
                 f"{total_r:.1f}x")
    by_phase = session.ledger.records_by_phase()
    lines.append(f"# paper: 6.7x average, up to 14.6x per component")
    lines.append(f"# ours: {total_r:.1f}x average, up to {max(reductions):.1f}x")
    lines.append(f"# cosmos breakdown by phase: "
                 + ",".join(f"{k}={v}" for k, v in sorted(by_phase.items())))
    lines.append(f"# exhaustive composition would need "
                 f"{exh.combinations():.2e} combinations (paper: >9e12)")
    report.write("fig11_invocations", lines)
    report.csv("fig11_invocations", wall * 1e6,
               f"avg={total_r:.1f}x_max={max(reductions):.1f}x")


def smoke() -> int:
    """Fast invocation-frugality gate on a reduced WAMI knob space."""
    from repro.apps.wami import (MATRIX_INV_LATENCY_S, wami_hls_tool,
                                 wami_knob_spaces, wami_tmg)
    from repro.core import KnobSpace, cosmos_dse, exhaustive_dse

    spaces = {n: KnobSpace(clock_ns=s.clock_ns, max_ports=min(4, s.max_ports),
                           max_unrolls=min(8, s.max_unrolls))
              for n, s in wami_knob_spaces().items()}
    t0 = time.time()
    cos = cosmos_dse(wami_tmg(), wami_hls_tool(), spaces, delta=0.3,
                     fixed={"matrix_inv": MATRIX_INV_LATENCY_S}, workers=8)
    exh = exhaustive_dse(list(spaces), wami_hls_tool(), spaces, workers=8)
    ratio = exh.total_invocations / max(1, cos.total_invocations)
    print(f"fig11-smoke: exhaustive={exh.total_invocations} "
          f"cosmos={cos.total_invocations} ratio={ratio:.2f}x "
          f"({time.time() - t0:.1f}s)")
    if ratio <= 1.0:
        print("fig11-smoke: FAIL — COSMOS no longer beats exhaustive",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    import argparse
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run asserting the invocation ratio > 1")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())
    sys.path.insert(0, os.path.dirname(__file__))

    class _Report:
        def write(self, name, lines):
            print("\n".join(lines))

        def csv(self, name, us, derived):
            print(f"{name},{us:.1f},{derived}")

    from scenarios import Cell
    run(_Report(), Cell("fig11", "wami", "analytical"))
