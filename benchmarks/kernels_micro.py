"""Kernel micro-benchmarks — one cell per registered app x backend.

Both cells drive the app's registered ``parity_cases`` (the registry
is the work list: a new app's kernels join by registering):

  * ``analytical`` — the same cases timed down their XLA reference
    path (``use_pallas=False``).  CPU microseconds, reported only to
    catch regressions in the jnp fallback kernels.
  * ``pallas`` — every kernel runs through its Pallas path in
    interpret mode and is checked against its jnp oracle; the reported
    numbers are interpret-mode walls (structural, not TPU performance)
    plus the parity error.  ``--smoke`` shrinks the tile and exits
    non-zero on any parity failure — the CI gate that the measured
    backend's kernels still compute the right thing.

Standalone (all apps at once):

    PYTHONPATH=src python benchmarks/kernels_micro.py --smoke --backend pallas
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

# every registered app joins both cells through its parity cases: the
# pallas cell checks + times the kernels in interpret mode, the
# analytical cell times the same cases down their XLA reference path
SCENARIOS = {"apps": "*", "backends": ("analytical", "pallas")}


def cell_skip_reason(app, backend, variant):
    """Bench-specific capability: both kernels cells drive the app's
    registered parity cases (interpret mode needs no recordings, so the
    registry's recording-based pallas check would be too strict)."""
    if app.parity_cases is None:
        return (f"app {app.name!r} registers no parity cases "
                f"(nothing for the kernels bench to drive)")
    return None


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _max_err(a, b):
    fa = jnp.asarray(a, jnp.float32)
    fb = jnp.asarray(b, jnp.float32)
    denom = float(jnp.abs(fb).max()) or 1.0
    return float(jnp.abs(fa - fb).max()) / max(1.0, denom)


def _registry_parity_cases(tile: int, app: str | None = None):
    """(name, knobbed_fn, oracle_fn, args) from registered apps that
    expose parity cases (all of them, or just ``app``) — the registry
    is the work list, so a new app's kernels join the CI gate by
    registering, not by editing this file."""
    from repro.core.registry import list_apps
    cases = []
    for a in list_apps():
        if app is not None and a.name != app:
            continue
        if a.parity_cases is not None:
            cases += list(a.parity_cases(tile))
    return cases


def run_pallas(report, *, app: str | None = None, tile: int = 128,
               ports: int = 4, unrolls: int = 8,
               reps: int = 3, tol: float = 1e-4) -> int:
    """Interpret-mode drive of the registered Pallas kernels (every
    app's, or one app's cell) vs their jnp oracles.  Returns the number
    of parity failures."""
    lines = [f"# Pallas kernels ({app or 'all registered apps'}), "
             f"interpret mode, "
             f"tile={tile}, ports={ports}, unrolls={unrolls}",
             "kernel,us_per_call_interpret,max_rel_err"]
    failures = 0
    for name, fn, oracle, args in _registry_parity_cases(tile, app):
        got = fn(*args, ports=ports, unrolls=unrolls, use_pallas=True,
                 interpret=True)
        want = oracle(*args)
        errs = [_max_err(g, w) for g, w in
                zip(got if isinstance(got, tuple) else (got,),
                    want if isinstance(want, tuple) else (want,))]
        err = max(errs)
        if err > tol:
            failures += 1
        us = _time(fn, *args, reps=reps, ports=ports, unrolls=unrolls,
                   use_pallas=True, interpret=True)
        lines.append(f"{name},{us:.0f},{err:.2e}")
        report.csv(f"{name}_pallas", us,
                   f"parity={'OK' if err <= tol else 'FAIL'}_{err:.1e}")
    report.write("kernels_micro_pallas", lines)
    return failures


def run_reference(report, *, app: str, tile: int = 128, ports: int = 2,
                  unrolls: int = 4, reps: int = 5) -> None:
    """The analytical cell: every parity case the app registers, timed
    down its XLA reference path (``use_pallas=False``) — the regression
    canary for the jnp fallback kernels, registry-driven like the
    interpret-mode cell."""
    lines = [f"# {app} kernels, XLA reference path (use_pallas=False), "
             f"tile={tile}",
             "kernel,us_per_call_ref"]
    for name, fn, oracle, args in _registry_parity_cases(tile, app):
        us = _time(fn, *args, reps=reps, ports=ports, unrolls=unrolls,
                   use_pallas=False, interpret=False)
        lines.append(f"{name},{us:.0f}")
        report.csv(f"{name}_ref", us, "xla_reference")
    report.write(f"kernels_micro_{app}", lines)


def run(report, cell) -> None:
    if cell.backend == "pallas":
        failures = run_pallas(report, app=cell.app)
        if failures:
            raise RuntimeError(f"{failures} {cell.app} Pallas kernel(s) "
                               f"diverged from their jnp oracle")
        return
    run_reference(report, app=cell.app)


if __name__ == "__main__":
    import argparse
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["analytical", "pallas"],
                    default="analytical")
    ap.add_argument("--smoke", action="store_true",
                    help="small tile, 1 rep, non-zero exit on any parity "
                         "failure (CI gate)")
    args = ap.parse_args()

    class _Report:
        def write(self, name, lines):
            print("\n".join(lines))

        def csv(self, name, us, derived):
            print(f"{name},{us:.1f},{derived}")

    if args.backend == "pallas":
        tile, reps = (32, 1) if args.smoke else (128, 3)
        failures = run_pallas(_Report(), tile=tile, ports=2, unrolls=4,
                              reps=reps)
        if args.smoke and failures:
            print(f"kernels-micro-smoke: FAIL — {failures} kernel(s) "
                  f"diverged from the jnp oracle", file=sys.stderr)
            raise SystemExit(1)
        raise SystemExit(0)
    from repro.core.registry import list_apps
    for app in list_apps():
        if app.parity_cases is not None:
            run_reference(_Report(), app=app.name)
