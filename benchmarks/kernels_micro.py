"""Kernel micro-benchmarks (CPU XLA-reference wall time + model GFLOP/s).

NOTE: wall times here are CPU-backend reference-path timings — the TPU
kernels are validated in interpret mode and their performance is assessed
structurally (BlockSpec working sets vs VMEM, MXU-shaped matmuls) in
EXPERIMENTS.md §Roofline; CPU microseconds are reported only to catch
regressions in the XLA fallback paths.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import mha
from repro.kernels.ssd_scan import ssd
from repro.kernels.wami_gradient import gradient


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(report) -> None:
    key = jax.random.PRNGKey(0)
    lines = ["# kernel micro-benches (CPU XLA reference path)",
             "kernel,config,us_per_call,gflops_model"]

    B, S, H, K, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    us = _time(mha, q, k, v, use_pallas=False)
    fl = 4 * B * H * S * S * d / 2          # causal
    lines.append(f"flash_attention,B{B}xS{S}xH{H}d{d},{us:.0f},"
                 f"{fl / us / 1e3:.1f}")
    report.csv("flash_attention_ref", us, f"{fl / us / 1e3:.1f}GFLOPs")

    Bz, S2, H2, P, N = 1, 2048, 8, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bz, S2, H2, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bz, S2, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bz, S2, N)) * 0.3
    us = _time(lambda *a: ssd(*a, use_pallas=False), x, dt, A, Bm, Cm)
    fl = Bz * S2 * H2 * P * N * 6
    lines.append(f"ssd_scan,B{Bz}xS{S2}xH{H2}P{P}N{N},{us:.0f},"
                 f"{fl / us / 1e3:.1f}")
    report.csv("ssd_scan_ref", us, f"{fl / us / 1e3:.1f}GFLOPs")

    img = jax.random.normal(key, (512, 512))
    us = _time(lambda im: gradient(im, use_pallas=False), img)
    lines.append(f"wami_gradient,512x512,{us:.0f},"
                 f"{512 * 512 * 4 / us / 1e3:.1f}")
    report.csv("wami_gradient_ref", us, "stencil")
    report.write("kernels_micro", lines)
