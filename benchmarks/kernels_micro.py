"""Kernel micro-benchmarks.

Two backends (``--backend`` / the harness's ``--backend`` flag):

  * ``analytical`` (default) — CPU XLA-reference wall time + model
    GFLOP/s.  Wall times here are CPU-backend reference-path timings;
    CPU microseconds are reported only to catch regressions in the XLA
    fallback paths.
  * ``pallas`` — every WAMI stage kernel runs through its Pallas path
    in interpret mode and is checked against its jnp oracle; the
    reported numbers are interpret-mode walls (structural, not TPU
    performance) plus the parity error.  ``--smoke`` shrinks the tile
    and exits non-zero on any parity failure — the CI gate that the
    measured backend's kernels still compute the right thing.

Standalone:

    PYTHONPATH=src python benchmarks/kernels_micro.py --smoke --backend pallas
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _max_err(a, b):
    fa = jnp.asarray(a, jnp.float32)
    fb = jnp.asarray(b, jnp.float32)
    denom = float(jnp.abs(fb).max()) or 1.0
    return float(jnp.abs(fa - fb).max()) / max(1.0, denom)


def _registry_parity_cases(tile: int):
    """(name, knobbed_fn, oracle_fn, args) from EVERY registered app
    that exposes parity cases — the registry is the work list, so a new
    app's kernels join the CI gate by registering, not by editing this
    file."""
    from repro.core.registry import list_apps
    cases = []
    for app in list_apps():
        if app.parity_cases is not None:
            cases += list(app.parity_cases(tile))
    return cases


def run_pallas(report, *, tile: int = 128, ports: int = 4, unrolls: int = 8,
               reps: int = 3, tol: float = 1e-4) -> int:
    """Interpret-mode drive of every registered app's Pallas kernels vs
    their jnp oracles.  Returns the number of parity failures."""
    lines = [f"# Pallas kernels (all registered apps), interpret mode, "
             f"tile={tile}, ports={ports}, unrolls={unrolls}",
             "kernel,us_per_call_interpret,max_rel_err"]
    failures = 0
    for name, fn, oracle, args in _registry_parity_cases(tile):
        got = fn(*args, ports=ports, unrolls=unrolls, use_pallas=True,
                 interpret=True)
        want = oracle(*args)
        errs = [_max_err(g, w) for g, w in
                zip(got if isinstance(got, tuple) else (got,),
                    want if isinstance(want, tuple) else (want,))]
        err = max(errs)
        if err > tol:
            failures += 1
        us = _time(fn, *args, reps=reps, ports=ports, unrolls=unrolls,
                   use_pallas=True, interpret=True)
        lines.append(f"{name},{us:.0f},{err:.2e}")
        report.csv(f"{name}_pallas", us,
                   f"parity={'OK' if err <= tol else 'FAIL'}_{err:.1e}")
    report.write("kernels_micro_pallas", lines)
    return failures


def run(report, backend: str = "analytical") -> None:
    if backend == "pallas":
        failures = run_pallas(report)
        if failures:
            raise RuntimeError(f"{failures} WAMI Pallas kernel(s) diverged "
                               f"from their jnp oracle")
        return
    key = jax.random.PRNGKey(0)
    lines = ["# kernel micro-benches (CPU XLA reference path)",
             "kernel,config,us_per_call,gflops_model"]

    from repro.kernels.flash_attention import mha
    from repro.kernels.ssd_scan import ssd
    from repro.kernels.wami_gradient import gradient

    B, S, H, K, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    us = _time(mha, q, k, v, use_pallas=False)
    fl = 4 * B * H * S * S * d / 2          # causal
    lines.append(f"flash_attention,B{B}xS{S}xH{H}d{d},{us:.0f},"
                 f"{fl / us / 1e3:.1f}")
    report.csv("flash_attention_ref", us, f"{fl / us / 1e3:.1f}GFLOPs")

    Bz, S2, H2, P, N = 1, 2048, 8, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bz, S2, H2, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bz, S2, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bz, S2, N)) * 0.3
    us = _time(lambda *a: ssd(*a, use_pallas=False), x, dt, A, Bm, Cm)
    fl = Bz * S2 * H2 * P * N * 6
    lines.append(f"ssd_scan,B{Bz}xS{S2}xH{H2}P{P}N{N},{us:.0f},"
                 f"{fl / us / 1e3:.1f}")
    report.csv("ssd_scan_ref", us, f"{fl / us / 1e3:.1f}GFLOPs")

    img = jax.random.normal(key, (512, 512))
    us = _time(lambda im: gradient(im, use_pallas=False), img)
    lines.append(f"wami_gradient,512x512,{us:.0f},"
                 f"{512 * 512 * 4 / us / 1e3:.1f}")
    report.csv("wami_gradient_ref", us, "stencil")
    report.write("kernels_micro", lines)


if __name__ == "__main__":
    import argparse
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["analytical", "pallas"],
                    default="analytical")
    ap.add_argument("--smoke", action="store_true",
                    help="small tile, 1 rep, non-zero exit on any parity "
                         "failure (CI gate)")
    args = ap.parse_args()

    class _Report:
        def write(self, name, lines):
            print("\n".join(lines))

        def csv(self, name, us, derived):
            print(f"{name},{us:.1f},{derived}")

    if args.backend == "pallas":
        tile, reps = (32, 1) if args.smoke else (128, 3)
        failures = run_pallas(_Report(), tile=tile, ports=2, unrolls=4,
                              reps=reps)
        if args.smoke and failures:
            print(f"kernels-micro-smoke: FAIL — {failures} kernel(s) "
                  f"diverged from the jnp oracle", file=sys.stderr)
            raise SystemExit(1)
        raise SystemExit(0)
    run(_Report())
