"""Beyond-paper: COSMOS fleet allocation for a multi-stage ML system.

The full paper methodology (Algorithm 1 regions -> Eq. 2 LP -> phi
mapping) over the registered ``fleet`` app — a hybrid flash-attention +
SSD-scan pipeline (``get_app("fleet")``) — on either oracle family:

  * ``--backend analytical`` — :class:`XLATool` fleet shares: the LP
    allocates chips across the two stages to hit a target pipeline
    throughput at minimum total HBM claimed;
  * ``--backend pallas`` — the calibrated-measured backend: the same
    stages priced by replaying the checked-in interpret-mode kernel
    recording, with the XLA roofline *calibrated to those measurements*
    (core/calibrate.py) pricing everything the recording does not
    cover.

Standalone, as the CI gate:

    PYTHONPATH=src python benchmarks/fleet_dse.py --smoke
    PYTHONPATH=src python benchmarks/fleet_dse.py --smoke --backend pallas

which asserts (a) the COSMOS front matches the exhaustively composed
front at its extremes and stays within the paper's mapping bound
everywhere, and (b) COSMOS still beats the exhaustive baseline on
oracle invocations (reduction >= 1) with the Fig. 11 ledger counting
across both stages.  ``--record`` re-measures the kernel recording.
"""

from __future__ import annotations

import sys
import time

# the fleet allocation study, on both oracle families
SCENARIOS = {"apps": ("fleet",), "backends": "*"}


def _fleet_drive(backend: str, workers: int = 4):
    """(cosmos result, exhaustive result, app) through the registry."""
    from repro.core import compose_exhaustive, exhaustive_dse
    from repro.core.registry import build_session, build_tool, get_app

    app = get_app("fleet")
    tool = (build_tool("fleet", "pallas", missing="fallback")
            if backend == "pallas" else None)
    session = build_session("fleet", backend, tool=tool, workers=workers)
    res = session.run()
    ex_tool = (build_tool("fleet", "pallas", missing="fallback")
               if backend == "pallas" else build_tool("fleet", "analytical"))
    spaces = app.knob_spaces()
    ex = exhaustive_dse(list(spaces), ex_tool, spaces, workers=workers)
    front = compose_exhaustive(app.tmg(), ex.fronts, fixed=dict(app.fixed))
    return res, ex, front


def run(report, cell) -> None:
    backend = cell.backend
    t0 = time.time()
    res, ex, _front = _fleet_drive(backend)
    red = ex.total_invocations / max(1, res.total_invocations)
    wall = time.time() - t0

    unit = ("vmem_bytes", 1.0) if backend == "pallas" else ("hbm_TB", 1e12)
    lines = [f"# COSMOS fleet allocation (flash_attention + ssd_scan "
             f"pipeline, backend={backend})",
             f"theta_per_s,total_cost_{unit[0]},"
             f"flash_ports,flash_unrolls,ssd_ports,ssd_unrolls"]
    for m in res.mapped:
        knobs = {o.component: (o.synthesis.ports, o.synthesis.unrolls)
                 for o in m.outcomes}
        fa = knobs.get("flash_attention", (0, 0))
        ss = knobs.get("ssd_scan", (0, 0))
        lines.append(f"{m.theta_actual:.3f},{m.cost_actual / unit[1]:.3f},"
                     f"{fa[0]},{fa[1]},{ss[0]},{ss[1]}")
    lines.append(f"# invocation reduction vs exhaustive pricing: {red:.1f}x")
    name = ("fleet_dse" if backend == "analytical"
            else f"fleet_dse_{backend}")
    report.write(name, lines)
    report.csv(name, wall * 1e6,
               f"points={len(res.mapped)}_reduction={red:.1f}x")


def smoke(backend: str = "analytical") -> int:
    """The fleet gate: COSMOS front vs the exhaustively composed exact
    front + the Fig. 11 invocation-frugality check, per backend."""
    t0 = time.time()
    res, ex, front = _fleet_drive(backend, workers=8)
    ratio = ex.total_invocations / max(1, res.total_invocations)
    mapped = sorted(res.mapped, key=lambda m: m.theta_actual)
    print(f"fleet-smoke backend={backend}: cosmos={res.total_invocations} "
          f"exhaustive={ex.total_invocations} ratio={ratio:.2f}x "
          f"points={len(mapped)} exact_front={len(front)} "
          f"({time.time() - t0:.1f}s)")
    ok = True
    if not mapped or not front:
        print("fleet-smoke: FAIL — empty front", file=sys.stderr)
        return 1
    if backend == "analytical":
        # one pure model prices both drives: the extremes must coincide
        # with the exact composed front
        for got, want, label in ((mapped[0].theta_actual, front[0].perf,
                                  "min"),
                                 (mapped[-1].theta_actual, front[-1].perf,
                                  "max")):
            if abs(got - want) > 1e-6 * max(abs(want), 1e-12):
                print(f"fleet-smoke: FAIL — theta_{label} {got:.6g} != "
                      f"exhaustive {want:.6g}", file=sys.stderr)
                ok = False
    else:
        # the measured drive replays only the points its own walk
        # recorded, while the exhaustive sweep ALSO prices never-walked
        # points through the calibrated fallback — the exact extremes
        # need not coincide, but the COSMOS theta range must sit inside
        # the exhaustively-achievable one
        lo, hi = front[0].perf, front[-1].perf
        if not (lo <= mapped[0].theta_actual * (1 + 1e-9)
                and mapped[-1].theta_actual <= hi * (1 + 1e-9)):
            print(f"fleet-smoke: FAIL — cosmos theta range "
                  f"[{mapped[0].theta_actual:.6g}, "
                  f"{mapped[-1].theta_actual:.6g}] outside exhaustive "
                  f"[{lo:.6g}, {hi:.6g}]", file=sys.stderr)
            ok = False
    # every COSMOS Pareto point within a bounded factor of the cheapest
    # exhaustive point at >= its throughput.  The bound is 2.0 (not the
    # WAMI suite's 1.6): the XLA roofline plateaus in the unroll knob
    # wherever a stage is compute-bound, and the paper's conservative
    # phi resolves a plateau to the fastest (most-HBM) corner — the
    # sigma > 10% cases Fig. 10 reports, not a regression
    for p in res.pareto():
        cands = [q.cost for q in front if q.perf >= p.perf * (1 - 1e-9)]
        if cands and p.cost > min(cands) * 2.0:
            print(f"fleet-smoke: FAIL — point (theta={p.perf:.4g}, "
                  f"cost={p.cost:.4g}) is {p.cost / min(cands):.2f}x the "
                  f"exhaustive front", file=sys.stderr)
            ok = False
    if ratio <= 1.0:
        print("fleet-smoke: FAIL — COSMOS no longer beats exhaustive "
              "on invocations", file=sys.stderr)
        ok = False
    return 0 if ok else 1


def record() -> int:
    """Re-measure the fleet kernel recording (interpret mode) by driving
    the exact session the replay backend reproduces."""
    from repro.apps.fleet import fleet_pallas_oracle
    from repro.core.registry import build_session
    oracle = fleet_pallas_oracle("record")
    res = build_session("fleet", "pallas", tool=oracle, workers=1).run()
    saved = oracle.flush()
    print(f"fleet-record: {len(oracle.store)} measured points -> {saved} "
          f"({res.total_invocations} oracle invocations)")
    return 0


if __name__ == "__main__":
    import argparse
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="front-vs-exhaustive + invocation-frugality gate")
    ap.add_argument("--record", action="store_true",
                    help="re-measure the interpret-mode kernel recording")
    ap.add_argument("--backend", choices=["analytical", "pallas"],
                    default="analytical")
    args = ap.parse_args()
    if args.record:
        raise SystemExit(record())
    if args.smoke:
        raise SystemExit(smoke(args.backend))
    from run import Report          # harness report, standalone
    from scenarios import Cell
    run(Report(), Cell("fleet", "fleet", args.backend))
