"""Beyond-paper: COSMOS fleet allocation for a multi-stage ML system.

The full paper methodology (Algorithm 1 regions -> Eq. 2 LP -> phi
mapping) over the XLA-priced oracle: stages of an RLHF-style system
(actor = zamba2-2.7b, learner = gemma2-9b) get fleet shares (ports) and
inverse-microbatch (unrolls) knobs; the LP allocates chips to hit a
target pipeline throughput at minimum total HBM claimed.
"""

from __future__ import annotations

import time

from repro.configs import SHAPES, get_config
from repro.core import KnobSpace, cosmos_dse, exhaustive_dse, pipeline_tmg
from repro.core.xlatool import XLATool


def run(report) -> None:
    t0 = time.time()
    comps = {
        "actor_zamba2": (get_config("zamba2-2.7b"), SHAPES[0]),
        "learner_gemma2": (get_config("gemma2-9b"), SHAPES[0]),
    }
    tool = XLATool(comps)
    tmg = pipeline_tmg(list(comps), buffers=2)
    spaces = {n: KnobSpace(clock_ns=1.0, max_ports=5, max_unrolls=6)
              for n in comps}
    res = cosmos_dse(tmg, tool, spaces, delta=0.3, workers=4)
    ex = exhaustive_dse(list(comps), XLATool(comps), spaces, workers=4)
    red = ex.total_invocations / max(1, res.total_invocations)
    wall = time.time() - t0

    lines = ["# COSMOS fleet allocation (actor+learner pipeline)",
             "theta_steps_per_s,total_hbm_TB,actor_chips,learner_chips"]
    for m in res.mapped:
        chips = {o.component: int(o.synthesis.detail.get("chips", 0))
                 for o in m.outcomes}
        lines.append(f"{m.theta_actual:.3f},{m.cost_actual / 1e12:.2f},"
                     f"{chips.get('actor_zamba2', 0)},"
                     f"{chips.get('learner_gemma2', 0)}")
    lines.append(f"# invocation reduction vs exhaustive pricing: {red:.1f}x")
    report.write("fleet_dse", lines)
    report.csv("fleet_dse", wall * 1e6,
               f"points={len(res.mapped)}_reduction={red:.1f}x")
