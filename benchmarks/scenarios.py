"""The scenario matrix: every bench x app x backend x variant cell.

Each bench module declares a ``SCENARIOS`` table saying which axes it
spans::

    SCENARIOS = {"apps": ("wami",), "backends": "*",
                 "variants": ("", "share_plm")}       # fig10
    SCENARIOS = {"apps": "*", "backends": ("analytical", "pallas")}
    SCENARIOS = {"pairs": (("zoo", "dryrun"),)}       # fixed pseudo-cell

``"*"`` expands against the live registry (``list_apps`` /
``list_backends``), so a newly registered app joins every wildcard
bench without editing benchmarks/.  :func:`enumerate_matrix` expands
the tables into :class:`ScenarioCell`s; a cell that cannot run (backend
does not support the app, no recording on disk, no PLM planner for the
``share_plm`` variant, ...) is enumerated anyway with a non-empty
``skip_reason`` — "handle every scenario" is a checked invariant, not a
habit (tests/test_scenarios.py, the CI ``scenario-matrix`` job).

A bench may *replace* the default capability check by exporting
``cell_skip_reason(app: App, backend: Backend, variant: str)`` — e.g.
the kernels parity bench needs ``parity_cases``, not recordings, so
the registry's recording-based pallas check does not apply.  A hook
that only wants to tighten the default should call
:func:`default_skip_reason` itself first.

Cells whose app is not a registered :class:`~repro.core.registry.App`
(the ``zoo`` pseudo-app: the LLM config zoo under ``repro.configs``)
are fixed cells and run unconditionally.
"""

from __future__ import annotations

import importlib
import os
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

__all__ = ["BENCH_MODULES", "Cell", "ScenarioCell", "bench_modules",
           "enumerate_matrix", "default_skip_reason", "render_list",
           "render_matrix_md"]

#: bench key -> module, in canonical (paper-figure) order
BENCH_MODULES: Dict[str, str] = {
    "fig4": "fig4_motivational",
    "table1": "table1_characterization",
    "fig10": "fig10_pareto",
    "fig11": "fig11_invocations",
    "roofline": "roofline_table",
    "kernels": "kernels_micro",
    "autoshard": "autoshard_llm",
    "fleet": "fleet_dse",
    "soc": "soc_compose",
}


@dataclass(frozen=True, order=True)
class Cell:
    """One runnable scenario: (bench, app, backend, variant)."""

    bench: str
    app: str
    backend: str
    variant: str = ""

    @property
    def id(self) -> str:
        tail = f"-{self.variant}" if self.variant else ""
        return f"{self.bench}/{self.app}-{self.backend}{tail}"

    @property
    def artifact(self) -> str:
        """Artifact path relative to ``artifacts/bench/``."""
        tail = f"-{self.variant}" if self.variant else ""
        return os.path.join(self.bench,
                            f"{self.app}-{self.backend}{tail}.csv")


@dataclass(frozen=True)
class ScenarioCell:
    """An enumerated cell: runnable, or skipped with a reason."""

    cell: Cell
    skip_reason: Optional[str] = None

    @property
    def runnable(self) -> bool:
        return self.skip_reason is None


def bench_modules() -> Dict[str, Any]:
    """Import every bench module, keyed by bench name.  Works both as
    ``benchmarks.scenarios`` (package) and as a top-level ``scenarios``
    (the standalone ``python benchmarks/<bench>.py`` path)."""
    pkg = __name__.rsplit(".", 1)[0] if "." in __name__ else None
    out: Dict[str, Any] = {}
    for key, name in BENCH_MODULES.items():
        out[key] = (importlib.import_module(f".{name}", pkg) if pkg
                    else importlib.import_module(name))
    return out


def default_skip_reason(app: Any, backend: Any, variant: str
                        ) -> Optional[str]:
    """The registry-derived capability check benches get for free:
    backend support (``Backend.skip_reason``) plus per-variant needs."""
    reason = backend.skip_reason(app)
    if reason:
        return reason
    if variant == "share_plm" and app.plm_planner is None:
        return (f"app {app.name!r} registers no PLM planner "
                f"(share_plm variant needs one)")
    return None


def _expand_pairs(spec: Dict[str, Any], app_names: List[str],
                  backend_names: List[str]) -> List[Tuple[str, str]]:
    if "pairs" in spec:
        return [tuple(p) for p in spec["pairs"]]
    apps = (app_names if spec.get("apps") == "*"
            else list(spec.get("apps", ())))
    backends = (backend_names if spec.get("backends") == "*"
                else list(spec.get("backends", ())))
    return [(a, b) for a in apps for b in backends]


def enumerate_matrix(modules: Optional[Dict[str, Any]] = None
                     ) -> List[ScenarioCell]:
    """Expand every bench's ``SCENARIOS`` table against the registry.

    Deterministic: benches in ``BENCH_MODULES`` order, apps and
    backends sorted by name, variants in declared order.  Every
    declared cell appears exactly once — unsupported ones carry a
    non-empty ``skip_reason`` instead of being silently absent.
    """
    from repro.core.registry import list_apps, list_backends
    modules = modules if modules is not None else bench_modules()
    apps = {a.name: a for a in list_apps()}
    backends = {b.name: b for b in list_backends()}
    out: List[ScenarioCell] = []
    for bench, mod in modules.items():
        spec = getattr(mod, "SCENARIOS", None)
        if spec is None:
            raise RuntimeError(f"bench module {mod.__name__!r} declares "
                               f"no SCENARIOS table")
        hook = getattr(mod, "cell_skip_reason", None)
        pairs = _expand_pairs(spec, sorted(apps), sorted(backends))
        for app_name, backend_name in pairs:
            for variant in spec.get("variants", ("",)):
                reason = None
                if app_name in apps and backend_name in backends:
                    check = hook or default_skip_reason
                    reason = check(apps[app_name], backends[backend_name],
                                   variant)
                out.append(ScenarioCell(Cell(bench, app_name, backend_name,
                                             variant), reason))
    return out


def render_list(cells: List[ScenarioCell]) -> str:
    """The ``--list`` printout: one CSV row per cell plus a summary
    line.  Byte-stable across runs (tests/test_scenarios.py)."""
    lines = ["cell,status,reason"]
    unexplained = 0
    for sc in cells:
        status = "run" if sc.runnable else "skip"
        reason = sc.skip_reason or ""
        if status == "skip" and not reason.strip():
            unexplained += 1
        lines.append(f"{sc.cell.id},{status},{reason}")
    n_run = sum(sc.runnable for sc in cells)
    lines.append(f"# matrix: {len(cells)} cells, {n_run} runnable, "
                 f"{len(cells) - n_run} skipped, {unexplained} unexplained")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# docs generation (docs/matrix.md)
# ----------------------------------------------------------------------
def render_matrix_md(cells: Optional[List[ScenarioCell]] = None) -> str:
    """docs/matrix.md, generated from the registry — the support
    matrix, recording availability, and the full bench cell matrix.
    Deterministic (no timestamps, basenames only): CI regenerates the
    file and fails on any diff."""
    from repro.core.registry import list_apps, list_backends
    cells = cells if cells is not None else enumerate_matrix()
    apps = list_apps()
    backends = list_backends()

    L: List[str] = []
    L.append("# The scenario matrix")
    L.append("")
    L.append("> **GENERATED** from the registry by "
             "`python -m benchmarks.run --emit-docs` — do not edit by "
             "hand.  The CI `scenario-matrix` job regenerates this file "
             "and fails on any diff.")
    L.append("")
    L.append("Every registered app x backend (x variant) cell the bench "
             "harness enumerates, with the capability facts behind each "
             "run/skip decision.  How to read and run the benches: "
             "[benchmarks.md](benchmarks.md); registering an app or "
             "backend: [backends.md](backends.md).")

    L.append("")
    L.append("## Registered apps")
    L.append("")
    for app in apps:
        d = app.describe()
        L.append(f"### `{d['name']}`")
        L.append("")
        L.append(d["description"] + ".")
        L.append("")
        L.append(f"* components: {len(d['components'])} "
                 f"({', '.join('`%s`' % c for c in d['components'])})")
        fixed = (", ".join("`%s`" % f for f in d["fixed"])
                 if d["fixed"] else "none")
        L.append(f"* fixed (software) stages: {fixed}; delta "
                 f"{d['delta']}")
        L.append(f"* measured surface: "
                 f"{'yes' if d['measured'] else 'no'}"
                 + (f" (native tile {d['native_tile']})"
                    if d["measured"] else ""))
        L.append(f"* PLM planner: {'yes' if d['plm_planner'] else 'no'}"
                 + (f"; analytical tile axis {d['plm_tile_sizes']}, "
                    f"measured-drive axis {d['plm_tile_sizes_measured']}"
                    if d["plm_tile_sizes"] else ""))
        L.append(f"* parity cases: "
                 f"{'yes' if d['parity_cases'] else 'no'}")
        L.append("")

    L.append("## Apps x backends support matrix")
    L.append("")
    header = "| app | " + " | ".join(f"`{b.name}`" for b in backends) + " |"
    L.append(header)
    L.append("|---" * (len(backends) + 1) + "|")
    for app in apps:
        row = [f"`{app.name}`"]
        for b in backends:
            reason = b.skip_reason(app)
            if reason is not None:
                row.append(f"no — {reason}")
            else:
                tiles = b.supported_tiles(app)
                row.append("yes" + (f" (tiles {list(tiles)})"
                                    if tiles else ""))
        L.append("| " + " | ".join(row) + " |")

    L.append("")
    L.append("## Recordings on disk")
    L.append("")
    L.append("The `(tile, device_kind)` keys a measured backend can "
             "replay, per app — the `MeasurementSet` routing keys under "
             "`artifacts/measurements/` "
             "([backends.md](backends.md#multi-recording-routing-"
             "measurementset)).")
    L.append("")
    L.append("| app | tile | device_kind | points | file |")
    L.append("|---|---|---|---|---|")
    any_rec = False
    for app in apps:
        for tile, kind, name, points in app.recording_keys():
            any_rec = True
            L.append(f"| `{app.name}` | {tile} | {kind} | {points} | "
                     f"`{name}` |")
    if not any_rec:
        L.append("| — | — | — | — | — |")

    L.append("")
    L.append("## The bench cell matrix")
    L.append("")
    n_run = sum(sc.runnable for sc in cells)
    L.append(f"{len(cells)} cells, {n_run} runnable, "
             f"{len(cells) - n_run} skipped.  Run one with "
             f"`python -m benchmarks.run --cell <cell>`; the artifact "
             f"lands in `artifacts/bench/<bench>/<app>-<backend>"
             f"[-variant].csv`.")
    L.append("")
    L.append("| cell | status | skip reason |")
    L.append("|---|---|---|")
    for sc in cells:
        status = "run" if sc.runnable else "skip"
        L.append(f"| `{sc.cell.id}` | {status} | "
                 f"{sc.skip_reason or ''} |")
    L.append("")
    return "\n".join(L)
