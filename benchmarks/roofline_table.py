"""The 40-cell roofline table, derived from the dry-run artifacts.

compute  = HLO_FLOPs / (chips x 197 TF/s)
memory   = HLO_bytes / (chips x 819 GB/s)
collective = modeled collective bytes / (chips x 50 GB/s link)
MODEL_FLOPS = 6ND (dense) / 6 N_active D (MoE) for train;
              2ND per generated token for decode/prefill.
"""

from __future__ import annotations

import glob
import json
import os
import time

from repro.configs import get_config, get_shape

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# a fixed pseudo-cell: the table derives from the LLM config zoo's
# committed dry-run artifacts, not from a registered App x Backend pair
SCENARIOS = {"pairs": (("zoo", "dryrun"),)}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # one token per sequence


def run(report, cell) -> None:
    t0 = time.time()
    lines = ["# Roofline table (per device; v5e: 197TF bf16, 819GB/s HBM, "
             "50GB/s link)",
             "arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
             "bound,model_flops_ratio,hbm_gb,fits_16g"]
    n_cells = 0
    worst = ("", 0.0)
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if "__tuned" in f or "naive" in f:
            continue
        r = json.load(open(f))
        if r["status"] == "skip":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},SKIP,,,"
                         f"{r['skip_reason'][:60]},,,")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},ERROR,,,,,,")
            continue
        n_cells += 1
        ro = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["cost"]["flops_per_device"] * r["devices"]
        ratio = mf / hlo_total if hlo_total else 0.0
        mem = r["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]
               + mem["output_bytes"]) / 1e9
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{ro['t_compute_s'] * 1e3:.2f},{ro['t_memory_s'] * 1e3:.2f},"
            f"{ro['t_collective_s'] * 1e3:.2f},{ro['bound']},"
            f"{ratio:.2f},{hbm:.1f},{'Y' if hbm <= 16 else 'N'}")
        frac = min(ro["t_compute_s"], ro["t_memory_s"]) / max(
            ro["t_bound_s"], 1e-12)
        if ro["t_bound_s"] > worst[1]:
            worst = (f"{r['arch']}/{r['shape']}/{r['mesh']}", ro["t_bound_s"])
    report.write("roofline_table", lines)
    report.csv("roofline_table", (time.time() - t0) * 1e6,
               f"cells={n_cells}_slowest={worst[0]}")
