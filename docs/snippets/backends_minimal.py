"""docs/backends.md — a complete minimal Oracle backend.

A backend only implements ``synthesize`` + ``cdfg_facts``;
``OracleBatchMixin`` provides the batched ``Oracle`` surface, and the
``OracleLedger`` layers counting/caching on top.
"""

from repro.core import (CDFGFacts, InvocationRequest, OracleBatchMixin,
                        OracleLedger, Synthesis)


class TableBackend(OracleBatchMixin):
    """Prices knob points from a pre-computed table (e.g. a vendor
    characterization dump).  Pure by construction."""

    def __init__(self, table):
        # table: {(component, unrolls, ports): (lam_s, area)}
        self.table = dict(table)

    def synthesize(self, component, *, unrolls, ports, max_states=None):
        entry = self.table.get((component, unrolls, ports))
        if entry is None:
            # infeasible is a RESULT (counted by the ledger), never an
            # exception
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls, feasible=False)
        lam, area = entry
        states = unrolls // max(1, ports) + 1
        if max_states is not None and states > max_states:
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls,
                             states_per_iter=states, feasible=False)
        return Synthesis(lam=lam, area=area, ports=ports, unrolls=unrolls,
                         states_per_iter=states, feasible=True)

    def cdfg_facts(self, component, synth):
        # must be consistent with the states logic above: Algorithm 1
        # uses h(u, p) as the max_states cap for the upper-left walk
        return CDFGFacts(gamma_r=1, gamma_w=1, eta=1, trip=1024)


def main():
    table = {("stage", u, p): (1e-3 / u + 1e-4 * p, 100.0 * u + 10.0 * p)
             for u in (1, 2, 4, 8) for p in (1, 2, 4)}
    ledger = OracleLedger(TableBackend(table), workers=4)
    reqs = [InvocationRequest("stage", unrolls=u, ports=2)
            for u in (1, 2, 4, 8)]
    for req, synth in zip(reqs, ledger.evaluate_batch(reqs)):
        print(req.key, synth.lam, synth.area)
    print("invocations:", ledger.total("stage"))   # 4 — dedup is free


if __name__ == "__main__":
    main()
