"""docs/backends.md — drive the WAMI DSE on the measured backend.

Replay mode: deterministic, no TPU, prices come from the recording
checked in under artifacts/measurements/.
"""

from repro.apps.wami.pallas import wami_pallas_oracle, wami_pallas_session


def main():
    session = wami_pallas_session(delta=0.25, workers=8)   # replay mode
    result = session.run()                                 # no TPU needed
    print(f"{result.total_invocations} invocations, "
          f"theta in [{result.theta_min:.1f}, {result.theta_max:.1f}] fps")
    for point in result.pareto():
        print(f"  theta {point.perf:8.2f}  cost {point.cost:12.1f}")

    # explicit-oracle form, e.g. to re-record on new hardware:
    oracle = wami_pallas_oracle("record")
    session = wami_pallas_session(delta=0.25, oracle=oracle)
    session.run()
    print("recording written to", oracle.flush())


if __name__ == "__main__":
    main()
