"""docs/backends.md — fit the analytical backend to measured points."""

from repro.apps.wami import wami_hls_tool
from repro.apps.wami.pallas import wami_pallas_session
from repro.core import ExplorationSession, calibrate_to_records
from repro.core.calibrate import CalibratedTool


def main():
    session = wami_pallas_session(delta=0.25, workers=8)   # measured drive
    measured = session.run()

    hls_tool = wami_hls_tool()
    fit = calibrate_to_records(hls_tool, session.ledger.records)
    for name, scale in sorted(fit.scales.items()):
        print(f"{name:14s} lam x{scale:.3g} "
              f"(residual spread x{fit.lam_spread[name]:.2f})")

    calibrated = CalibratedTool(hls_tool, fit)   # lam scaled per component
    cal_session = ExplorationSession(session.tmg, calibrated,
                                     session.spaces, delta=0.25,
                                     fixed=session.fixed, workers=8)
    cal = cal_session.run()
    print(f"measured theta range   [{measured.theta_min:.1f}, "
          f"{measured.theta_max:.1f}] fps")
    print(f"calibrated-model range [{cal.theta_min:.1f}, "
          f"{cal.theta_max:.1f}] fps")


if __name__ == "__main__":
    main()
