#!/usr/bin/env python
"""Markdown link checker for the repo docs (CI docs job).

Verifies that every relative markdown link in README.md, DESIGN.md and
docs/ points at a file that exists.  External (http/mailto) links and
pure anchors are skipped; ``path#fragment`` checks only the path.

    python docs/check_links.py            # default file set
    python docs/check_links.py FILE...    # explicit files
"""

from __future__ import annotations

import glob
import os
import re
import sys

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_files() -> list:
    files = [os.path.join(_REPO, "README.md"),
             os.path.join(_REPO, "DESIGN.md"),
             os.path.join(_REPO, "ROADMAP.md")]
    files += sorted(glob.glob(os.path.join(_REPO, "docs", "**", "*.md"),
                              recursive=True))
    return [f for f in files if os.path.exists(f)]


def check(path: str) -> list:
    broken = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for target in _LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.join(base, rel)):
                    broken.append((path, lineno, target))
    return broken


def main(argv: list) -> int:
    files = argv or default_files()
    broken = []
    for path in files:
        broken += check(path)
    for path, lineno, target in broken:
        print(f"{os.path.relpath(path, _REPO)}:{lineno}: "
              f"broken link -> {target}", file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
