"""Synthetic token stream: deterministic, seekable, structure-bearing.

Not uniform noise — a tiny order-2 Markov chain over the vocabulary so a
~100M model trained for a few hundred steps shows a real loss drop (the
end-to-end example's acceptance check).  Deterministic and seekable by
(shard, step), which is what makes checkpoint/restart exact: a restarted
run consumes exactly the batches it would have seen.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "TokenBatch"]


@dataclass(frozen=True)
class TokenBatch:
    tokens: np.ndarray
    targets: np.ndarray
    mask: np.ndarray


class SyntheticLM:
    """Order-2 Markov token source with per-(shard, step) seekability."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        self.vocab = vocab
        self.seed = seed
        self.branching = min(branching, vocab)

    def _transition(self, a: np.ndarray, b: np.ndarray, rnd: np.ndarray
                    ) -> np.ndarray:
        """next = f(prev, r): each token has `branching` fixed successors
        (an order-1 chain a small model can actually learn in tens of
        steps — the loss-decrease acceptance check depends on it)."""
        h = (b * 10007 + (rnd % self.branching) * 257 + self.seed) % (2 ** 31)
        return ((b + (h % self.branching) * 2654435761 + 1) % self.vocab
                ).astype(np.int64)

    def batch(self, *, step: int, shard: int, n_shards: int,
              batch: int, seq: int) -> Dict[str, np.ndarray]:
        """Batch for a given (step, shard) — pure function of its args."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, n_shards]))
        B = batch
        toks = np.empty((B, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, B)
        toks[:, 1] = rng.integers(0, self.vocab, B)
        noise = rng.integers(0, 4, (B, seq + 1))
        for t in range(2, seq + 1):
            toks[:, t] = self._transition(toks[:, t - 2], toks[:, t - 1],
                                          noise[:, t])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, seq), np.float32),
        }
