"""Host data pipeline: sharded iteration + background prefetch.

Each host materializes only its shard of the global batch (shard =
``jax.process_index()`` in a real multi-host run; overridable for tests
and simulation).  A daemon thread keeps ``prefetch`` batches ready so
host data generation overlaps device compute — the standard input-
pipeline/step overlap.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from .synthetic import SyntheticLM

__all__ = ["DataPipeline"]


class DataPipeline:
    def __init__(self, source: SyntheticLM, *, global_batch: int, seq: int,
                 shard: int = 0, n_shards: int = 1, start_step: int = 0,
                 prefetch: int = 2,
                 augment: Optional[Callable[[Dict], Dict]] = None):
        assert global_batch % n_shards == 0
        self.source = source
        self.global_batch = global_batch
        self.local_batch = global_batch // n_shards
        self.seq = seq
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step
        self.augment = augment
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        b = self.source.batch(step=step, shard=self.shard,
                              n_shards=self.n_shards,
                              batch=self.local_batch, seq=self.seq)
        if self.augment:
            b = self.augment(b)
        return b

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def seek(self, step: int):
        """Restart the stream at ``step`` (checkpoint restore)."""
        self.close()
        self.__init__(self.source, global_batch=self.global_batch,
                      seq=self.seq, shard=self.shard, n_shards=self.n_shards,
                      start_step=step,
                      prefetch=self._q.maxsize, augment=self.augment)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
