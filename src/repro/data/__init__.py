"""Data pipeline: synthetic sources + sharded prefetching loader."""

from .pipeline import DataPipeline
from .synthetic import SyntheticLM

__all__ = ["SyntheticLM", "DataPipeline"]
