"""WAMI gradient as a Pallas kernel with COSMOS-knob-driven BlockSpecs.

This is the paper's port/unroll knob pair made physical on TPU
(DESIGN.md §2):

  * ``ports``   -> number of column banks: the W axis is split into
    ``ports`` lane-blocks processed by parallel grid columns — the
    multi-bank PLM that Mnemosyne would generate, here as VMEM tiles;
  * ``unrolls`` -> rows computed per grid step (``block_h``): the loop
    body replication, trading VMEM footprint for fewer grid iterations.

The halo problem (vertical neighbours across block boundaries) is solved
the TPU way: the ops wrapper materializes the four shifted views with
XLA slices and the kernel consumes aligned blocks — no shared-memory
halo exchange to port from the GPU idiom.

The COSMOS characterization of this kernel (ports x unrolls ->
VMEM bytes x grid steps) is exercised in benchmarks/fig4_motivational.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):   # jax < 0.5: old class name
    pltpu.CompilerParams = pltpu.TPUCompilerParams

__all__ = ["gradient_kernel", "vmem_bytes", "grid_steps"]


def _kernel(left_ref, right_ref, up_ref, down_ref, gx_ref, gy_ref):
    gx_ref[...] = (right_ref[...] - left_ref[...]) * 0.5
    gy_ref[...] = (down_ref[...] - up_ref[...]) * 0.5


def gradient_kernel(gray: jnp.ndarray, *, ports: int = 1, unrolls: int = 8,
                    interpret: bool = False):
    """Central-difference gradient.  gray: (H, W) with W % ports == 0 and
    H % unrolls == 0.  Returns (gx, gy)."""
    H, W = gray.shape
    assert W % ports == 0 and H % unrolls == 0
    bw = W // ports
    bh = unrolls
    p = jnp.pad(gray, 1, mode="edge")
    left = p[1:-1, :-2]
    right = p[1:-1, 2:]
    up = p[:-2, 1:-1]
    down = p[2:, 1:-1]

    spec = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
    gx, gy = pl.pallas_call(
        _kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((H, W), gray.dtype)] * 2,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(left, right, up, down)
    return gx, gy


def vmem_bytes(H: int, W: int, *, ports: int, unrolls: int,
               dtype_bytes: int = 4) -> int:
    """VMEM working set per grid step (4 in + 2 out blocks)."""
    return 6 * unrolls * (W // ports) * dtype_bytes


def grid_steps(H: int, W: int, *, ports: int, unrolls: int) -> int:
    """Sequential steps if one core walks the grid (latency model input)."""
    return (H // unrolls) * ports
