"""Jitted wrapper for the WAMI gradient kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import gradient_kernel, grid_steps, vmem_bytes
from .ref import gradient_ref

__all__ = ["gradient", "gradient_oracle", "vmem_bytes", "grid_steps"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def gradient(gray, *, ports=1, unrolls=8, use_pallas=True, interpret=False):
    if use_pallas:
        return gradient_kernel(gray, ports=ports, unrolls=unrolls,
                               interpret=interpret)
    return gradient_ref(gray)


def gradient_oracle(gray):
    return gradient_ref(gray)
