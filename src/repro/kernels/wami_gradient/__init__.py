from .kernel import gradient_kernel, grid_steps, vmem_bytes
from .ops import gradient, gradient_oracle
from .ref import gradient_ref

__all__ = ["gradient_kernel", "gradient", "gradient_oracle", "gradient_ref",
           "vmem_bytes", "grid_steps"]
