"""Oracle: the WAMI gradient component (same math as apps.wami)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gradient_ref"]


def gradient_ref(gray: jnp.ndarray):
    p = jnp.pad(gray, 1, mode="edge")
    gx = (p[1:-1, 2:] - p[1:-1, :-2]) * 0.5
    gy = (p[2:, 1:-1] - p[:-2, 1:-1]) * 0.5
    return gx, gy
