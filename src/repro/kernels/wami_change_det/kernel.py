"""WAMI change detection (per-pixel GMM, K=3) as a Pallas kernel.

The heaviest WAMI stage: every pixel carries a K=3 Gaussian-mixture
background state (mu, var, w) that is matched, updated, and renormalized
each frame.  Knob geometry per DESIGN.md §2 (``ports`` lane-banks x
``unrolls`` rows per grid step); the mixture state rides along as
(K, H, W) planes whose BlockSpec blocks the pixel axes and keeps the
K axis whole, so each grid step owns the full mixture for its tile.

The argmin/one-hot over K is unrolled by hand (K=3): first-index
tie-breaking matches ``jnp.argmin`` exactly, and the unrolled compares
stay elementwise on the VPU instead of forcing a cross-lane reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..wami_common import (grid_steps_model, knob_blocks, parallel_params,
                           tile_spec, vmem_bytes_model)

__all__ = ["change_detection_kernel", "vmem_bytes", "grid_steps"]

_K = 3
# gray + 3 state planes of K=3 in; mask + 3 state planes of K=3 out
_N_IN, _N_OUT = 1 + 3 * _K, 1 + 3 * _K


def _first_min_onehot(v0, v1, v2):
    """One-hot of argmin over three planes, first index wins ties."""
    b0 = (v0 <= v1) & (v0 <= v2)
    b1 = (~b0) & (v1 <= v2)
    b2 = ~(b0 | b1)
    return b0, b1, b2


def _kernel(g_ref, mu_ref, var_ref, w_ref,
            mask_ref, mu_o, var_o, w_o, *, lr, mahal, fg):
    x = g_ref[...][None]                               # (1, bh, bw)
    mu, var, w = mu_ref[...], var_ref[...], w_ref[...]  # (K, bh, bw)
    d2 = (x - mu) ** 2 / jnp.maximum(var, 1e-4)
    match = d2 < mahal
    any_match = match[0] | match[1] | match[2]
    inf = jnp.inf
    dm = jnp.where(match, d2, inf)
    b0, b1, b2 = _first_min_onehot(dm[0], dm[1], dm[2])
    onehot = (jnp.stack([b0, b1, b2]) & any_match[None]).astype(mu.dtype)

    mu_n = mu + onehot * lr * (x - mu)
    var_n = var + onehot * lr * ((x - mu) ** 2 - var)
    w_n = (1 - lr) * w + lr * onehot
    # no match: replace the weakest component with a fresh one at x
    k0, k1, k2 = _first_min_onehot(w[0], w[1], w[2])
    wh = (jnp.stack([k0, k1, k2]) & (~any_match)[None]).astype(mu.dtype)
    mu_n = mu_n * (1 - wh) + wh * x
    var_n = var_n * (1 - wh) + wh * 25.0
    w_n = w_n * (1 - wh) + wh * lr
    w_n = w_n / (w_n[0] + w_n[1] + w_n[2])[None]
    # foreground: matched component is low-weight, or no match at all
    matched_w = (onehot * w).sum(axis=0)
    mask = (~any_match) | (matched_w < (1.0 - fg))
    mask_ref[...] = mask.astype(mu.dtype)
    mu_o[...] = mu_n
    var_o[...] = var_n
    w_o[...] = w_n


def change_detection_kernel(gray: jnp.ndarray, mu: jnp.ndarray,
                            var: jnp.ndarray, w: jnp.ndarray, *,
                            ports: int = 1, unrolls: int = 8,
                            lr: float = 0.05, mahal_thresh: float = 6.25,
                            fg_thresh: float = 0.7,
                            interpret: bool = False):
    """gray: (H, W); mu/var/w: (H, W, K=3) mixture state.

    Returns (mask (H, W) in {0.0, 1.0}, mu', var', w') with state in the
    (H, W, K) layout of the reference.
    """
    H, W = gray.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    spec = tile_spec(bh, bw)
    spec_k = pl.BlockSpec((_K, bh, bw), lambda i, j: (0, i, j))
    planes = lambda a: jnp.moveaxis(a, -1, 0)          # (H,W,K) -> (K,H,W)
    mask, mu_n, var_n, w_n = pl.pallas_call(
        functools.partial(_kernel, lr=lr, mahal=mahal_thresh, fg=fg_thresh),
        grid=(H // bh, ports),
        in_specs=[spec, spec_k, spec_k, spec_k],
        out_specs=[spec, spec_k, spec_k, spec_k],
        out_shape=[jax.ShapeDtypeStruct((H, W), gray.dtype)]
        + [jax.ShapeDtypeStruct((_K, H, W), gray.dtype)] * 3,
        compiler_params=parallel_params(),
        interpret=interpret,
    )(gray, planes(mu), planes(var), planes(w))
    back = lambda a: jnp.moveaxis(a, 0, -1)
    return mask, back(mu_n), back(var_n), back(w_n)


vmem_bytes = functools.partial(vmem_bytes_model, n_in=_N_IN, n_out=_N_OUT)
grid_steps = grid_steps_model
