"""Oracle: per-pixel GMM background subtraction (same math as
apps.wami.components.change_detection)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["change_detection_ref"]

_K = 3


def change_detection_ref(gray, mu, var, w, *, lr=0.05, mahal_thresh=6.25,
                         fg_thresh=0.7):
    x = gray[..., None]
    d2 = (x - mu) ** 2 / jnp.maximum(var, 1e-4)
    match = d2 < mahal_thresh
    any_match = jnp.any(match, axis=-1)
    d2_masked = jnp.where(match, d2, jnp.inf)
    best = jnp.argmin(d2_masked, axis=-1)
    onehot = jax.nn.one_hot(best, _K, dtype=gray.dtype) * any_match[..., None]

    mu_n = mu + onehot * lr * (x - mu)
    var_n = var + onehot * lr * ((x - mu) ** 2 - var)
    w_n = (1 - lr) * w + lr * onehot
    weakest = jnp.argmin(w, axis=-1)
    wh = jax.nn.one_hot(weakest, _K, dtype=gray.dtype) * (~any_match)[..., None]
    mu_n = mu_n * (1 - wh) + wh * x
    var_n = var_n * (1 - wh) + wh * 25.0
    w_n = w_n * (1 - wh) + wh * lr
    w_n = w_n / jnp.sum(w_n, axis=-1, keepdims=True)
    matched_w = jnp.sum(onehot * w, axis=-1)
    mask = (~any_match) | (matched_w < (1.0 - fg_thresh))
    return mask, mu_n, var_n, w_n
