from .ops import (change_detection, change_detection_oracle, grid_steps,
                  vmem_bytes)

__all__ = ["change_detection", "change_detection_oracle",
           "vmem_bytes", "grid_steps"]
