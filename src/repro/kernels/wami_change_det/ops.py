"""Jitted wrapper for the WAMI change-detection kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import change_detection_kernel, grid_steps, vmem_bytes
from .ref import change_detection_ref

__all__ = ["change_detection", "change_detection_oracle",
           "vmem_bytes", "grid_steps"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def change_detection(gray, mu, var, w, *, ports=1, unrolls=8,
                     use_pallas=True, interpret=False):
    if use_pallas:
        mask, mu_n, var_n, w_n = change_detection_kernel(
            gray, mu, var, w, ports=ports, unrolls=unrolls,
            interpret=interpret)
        return mask.astype(bool), mu_n, var_n, w_n
    return change_detection_ref(gray, mu, var, w)


def change_detection_oracle(gray, mu, var, w):
    return change_detection_ref(gray, mu, var, w)
