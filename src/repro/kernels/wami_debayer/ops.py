"""Jitted wrapper for the WAMI debayer kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import debayer_kernel, grid_steps, vmem_bytes
from .ref import debayer_ref

__all__ = ["debayer", "debayer_oracle", "vmem_bytes", "grid_steps"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def debayer(bayer, *, ports=1, unrolls=8, use_pallas=True, interpret=False):
    if use_pallas:
        return debayer_kernel(bayer, ports=ports, unrolls=unrolls,
                              interpret=interpret)
    return debayer_ref(bayer)


def debayer_oracle(bayer):
    return debayer_ref(bayer)
