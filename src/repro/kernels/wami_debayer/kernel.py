"""WAMI debayer (bilinear RGGB demosaic) as a Pallas kernel.

COSMOS knobs follow the wami_gradient geometry (DESIGN.md §2): ``ports``
column lane-banks x ``unrolls`` rows per grid step.  Like the gradient,
the halo problem is solved the TPU way: the ops wrapper materializes the
nine shifted views (center + 8-neighbourhood) with XLA slices, and the
kernel consumes aligned blocks.  The RGGB parity pattern is recovered
in-kernel from the global pixel coordinates (``program_id`` x block
offsets + iota), so any block size works — blocks need not align to the
2x2 Bayer quad.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..wami_common import (grid_steps_model, knob_blocks, parallel_params,
                           tile_spec, vmem_bytes_model)

__all__ = ["debayer_kernel", "vmem_bytes", "grid_steps"]

_N_IN, _N_OUT = 9, 3


def _kernel(c_ref, n_ref, s_ref, w_ref, e_ref, nw_ref, ne_ref, sw_ref,
            se_ref, r_ref, g_ref, b_ref):
    bh, bw = c_ref.shape
    c = c_ref[...]
    cross = (n_ref[...] + s_ref[...] + w_ref[...] + e_ref[...]) * 0.25
    diag = (nw_ref[...] + ne_ref[...] + sw_ref[...] + se_ref[...]) * 0.25
    horiz = (w_ref[...] + e_ref[...]) * 0.5
    vert = (n_ref[...] + s_ref[...]) * 0.5

    # global pixel parity: the block at grid cell (i, j) starts at row
    # i*bh, column j*bw of the full frame
    yy = (jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 0)
          + pl.program_id(0) * bh)
    xx = (jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 1)
          + pl.program_id(1) * bw)
    even_y, even_x = (yy % 2) == 0, (xx % 2) == 0
    r_loc = even_y & even_x                  # (0,0)=R
    g1_loc = even_y & (~even_x)              # (0,1)=G
    g2_loc = (~even_y) & even_x              # (1,0)=G
    b_loc = (~even_y) & (~even_x)            # (1,1)=B

    r_ref[...] = jnp.where(r_loc, c, jnp.where(g1_loc, horiz,
                           jnp.where(g2_loc, vert, diag)))
    g_ref[...] = jnp.where(r_loc | b_loc, cross, c)
    b_ref[...] = jnp.where(b_loc, c, jnp.where(g2_loc, horiz,
                           jnp.where(g1_loc, vert, diag)))


def debayer_kernel(bayer: jnp.ndarray, *, ports: int = 1, unrolls: int = 8,
                   interpret: bool = False) -> jnp.ndarray:
    """bayer: (H, W) RGGB mosaic -> (H, W, 3) float32 RGB."""
    img = bayer.astype(jnp.float32)
    H, W = img.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    p = jnp.pad(img, 1, mode="reflect")
    views = (p[1:-1, 1:-1],                              # c
             p[:-2, 1:-1], p[2:, 1:-1],                  # n, s
             p[1:-1, :-2], p[1:-1, 2:],                  # w, e
             p[:-2, :-2], p[:-2, 2:],                    # nw, ne
             p[2:, :-2], p[2:, 2:])                      # sw, se
    spec = tile_spec(bh, bw)
    r, g, b = pl.pallas_call(
        _kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 9,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((H, W), jnp.float32)] * 3,
        compiler_params=parallel_params(),
        interpret=interpret,
    )(*views)
    return jnp.stack([r, g, b], axis=-1)


vmem_bytes = functools.partial(vmem_bytes_model, n_in=_N_IN, n_out=_N_OUT)
grid_steps = grid_steps_model
