"""Oracle: bilinear RGGB demosaic (same math as apps.wami.components)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["debayer_ref"]


def debayer_ref(bayer: jnp.ndarray) -> jnp.ndarray:
    img = bayer.astype(jnp.float32)
    H, W = img.shape
    p = jnp.pad(img, 1, mode="reflect")
    c = p[1:-1, 1:-1]
    n, s = p[:-2, 1:-1], p[2:, 1:-1]
    w, e = p[1:-1, :-2], p[1:-1, 2:]
    nw, ne = p[:-2, :-2], p[:-2, 2:]
    sw, se = p[2:, :-2], p[2:, 2:]
    cross = (n + s + w + e) * 0.25
    diag = (nw + ne + sw + se) * 0.25
    horiz = (w + e) * 0.5
    vert = (n + s) * 0.5

    yy, xx = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
    r_loc = (yy % 2 == 0) & (xx % 2 == 0)
    g1_loc = (yy % 2 == 0) & (xx % 2 == 1)
    g2_loc = (yy % 2 == 1) & (xx % 2 == 0)
    b_loc = (yy % 2 == 1) & (xx % 2 == 1)

    r = jnp.where(r_loc, c, jnp.where(g1_loc, horiz,
                                      jnp.where(g2_loc, vert, diag)))
    g = jnp.where(r_loc | b_loc, cross, c)
    b = jnp.where(b_loc, c, jnp.where(g2_loc, horiz,
                                      jnp.where(g1_loc, vert, diag)))
    return jnp.stack([r, g, b], axis=-1)
