from .ops import debayer, debayer_oracle, grid_steps, vmem_bytes

__all__ = ["debayer", "debayer_oracle", "vmem_bytes", "grid_steps"]
