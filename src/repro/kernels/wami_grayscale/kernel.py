"""WAMI grayscale (BT.601 luma) as a Pallas kernel with COSMOS knobs.

Pure elementwise stage: three input planes (R, G, B), one output plane.
``ports``/``unrolls`` follow the wami_gradient geometry (DESIGN.md §2):
column lane-banks x rows per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..wami_common import (grid_steps_model, knob_blocks, parallel_params,
                           tile_spec, vmem_bytes_model)

__all__ = ["grayscale_kernel", "vmem_bytes", "grid_steps"]

_N_IN, _N_OUT = 3, 1


def _kernel(r_ref, g_ref, b_ref, y_ref):
    y_ref[...] = (0.299 * r_ref[...] + 0.587 * g_ref[...]
                  + 0.114 * b_ref[...])


def grayscale_kernel(rgb: jnp.ndarray, *, ports: int = 1, unrolls: int = 8,
                     interpret: bool = False) -> jnp.ndarray:
    """rgb: (H, W, 3) with W % ports == 0 and H % unrolls == 0 -> (H, W)."""
    H, W, _ = rgb.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    spec = tile_spec(bh, bw)
    return pl.pallas_call(
        _kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 3,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((H, W), rgb.dtype),
        compiler_params=parallel_params(),
        interpret=interpret,
    )(rgb[..., 0], rgb[..., 1], rgb[..., 2])


vmem_bytes = functools.partial(vmem_bytes_model, n_in=_N_IN, n_out=_N_OUT)
grid_steps = grid_steps_model
