from .ops import grayscale, grayscale_oracle, grid_steps, vmem_bytes

__all__ = ["grayscale", "grayscale_oracle", "vmem_bytes", "grid_steps"]
