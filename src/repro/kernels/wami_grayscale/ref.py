"""Oracle: BT.601 luma (same math as apps.wami.components.grayscale)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["grayscale_ref"]


def grayscale_ref(rgb: jnp.ndarray) -> jnp.ndarray:
    return (0.299 * rgb[..., 0] + 0.587 * rgb[..., 1]
            + 0.114 * rgb[..., 2])
