"""Jitted wrapper for the WAMI grayscale kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import grayscale_kernel, grid_steps, vmem_bytes
from .ref import grayscale_ref

__all__ = ["grayscale", "grayscale_oracle", "vmem_bytes", "grid_steps"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def grayscale(rgb, *, ports=1, unrolls=8, use_pallas=True, interpret=False):
    if use_pallas:
        return grayscale_kernel(rgb, ports=ports, unrolls=unrolls,
                                interpret=interpret)
    return grayscale_ref(rgb)


def grayscale_oracle(rgb):
    return grayscale_ref(rgb)
