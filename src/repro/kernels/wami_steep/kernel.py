"""WAMI steepest-descent images + Gauss-Newton Hessian as Pallas kernels.

Two stages of the inverse-compositional Lucas-Kanade template side,
sharing the COSMOS knob geometry of DESIGN.md §2 (``ports`` column
lane-banks x ``unrolls`` rows per grid step):

  * ``steepest_descent_kernel`` — elementwise with global coordinates:
    sd = (gx*x, gx*y, gx, gy*x, gy*y, gy).  The affine-warp Jacobian
    coordinates are rebuilt in-kernel from ``program_id`` block offsets
    + iota, so no coordinate planes are streamed from HBM;
  * ``hessian_kernel`` — the reduction H = sum_x sd(x)^T sd(x): each
    grid step contracts its (6, bh*bw) block on the MXU and accumulates
    into a single (6, 6) output block shared by every step, which forces
    an ``arbitrary`` (sequential) grid walk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..wami_common import (arbitrary_params, grid_steps_model, knob_blocks,
                           parallel_params, tile_spec, vmem_bytes_model)

__all__ = ["steepest_descent_kernel", "hessian_kernel",
           "vmem_bytes", "grid_steps", "hessian_vmem_bytes"]

_N_IN, _N_OUT = 2, 6      # steepest descent: gx, gy -> 6 sd planes


def _sd_kernel(gx_ref, gy_ref, s0, s1, s2, s3, s4, s5):
    bh, bw = gx_ref.shape
    gx, gy = gx_ref[...], gy_ref[...]
    yy = (jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 0)
          + pl.program_id(0) * bh).astype(gx.dtype)
    xx = (jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 1)
          + pl.program_id(1) * bw).astype(gx.dtype)
    s0[...] = gx * xx
    s1[...] = gx * yy
    s2[...] = gx
    s3[...] = gy * xx
    s4[...] = gy * yy
    s5[...] = gy


def steepest_descent_kernel(gx: jnp.ndarray, gy: jnp.ndarray, *,
                            ports: int = 1, unrolls: int = 8,
                            interpret: bool = False) -> jnp.ndarray:
    """gx, gy: (H, W) image gradients -> sd images (H, W, 6)."""
    H, W = gx.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    spec = tile_spec(bh, bw)
    planes = pl.pallas_call(
        _sd_kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 2,
        out_specs=[spec] * 6,
        out_shape=[jax.ShapeDtypeStruct((H, W), gx.dtype)] * 6,
        compiler_params=parallel_params(),
        interpret=interpret,
    )(gx, gy)
    return jnp.stack(planes, axis=-1)


def _hessian_kernel(s0, s1, s2, s3, s4, s5, out_ref):
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = jnp.stack([s[...].reshape(-1)
                      for s in (s0, s1, s2, s3, s4, s5)])       # (6, bh*bw)
    out_ref[...] += jnp.dot(flat, flat.T,
                            preferred_element_type=out_ref.dtype)


def hessian_kernel(sd: jnp.ndarray, *, ports: int = 1, unrolls: int = 8,
                   interpret: bool = False) -> jnp.ndarray:
    """sd: (H, W, 6) steepest-descent images -> Hessian (6, 6)."""
    H, W, _ = sd.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    spec = tile_spec(bh, bw)
    return pl.pallas_call(
        _hessian_kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 6,
        out_specs=pl.BlockSpec((6, 6), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((6, 6), sd.dtype),
        compiler_params=arbitrary_params(),
        interpret=interpret,
    )(*(sd[..., k] for k in range(6)))


vmem_bytes = functools.partial(vmem_bytes_model, n_in=_N_IN, n_out=_N_OUT)
grid_steps = grid_steps_model


def hessian_vmem_bytes(H: int, W: int, *, ports: int, unrolls: int,
                       dtype_bytes: int = 4) -> int:
    """Six sd input blocks + the resident (6, 6) accumulator."""
    return (6 * unrolls * (W // ports) + 36) * dtype_bytes
