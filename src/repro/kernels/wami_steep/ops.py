"""Jitted wrappers for the WAMI steepest-descent / Hessian kernels."""

from __future__ import annotations

import functools

import jax

from .kernel import (grid_steps, hessian_kernel, hessian_vmem_bytes,
                     steepest_descent_kernel, vmem_bytes)
from .ref import hessian_ref, steepest_descent_ref

__all__ = ["steepest_descent", "steepest_descent_oracle",
           "hessian", "hessian_oracle",
           "vmem_bytes", "grid_steps", "hessian_vmem_bytes"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def steepest_descent(gx, gy, *, ports=1, unrolls=8, use_pallas=True,
                     interpret=False):
    if use_pallas:
        return steepest_descent_kernel(gx, gy, ports=ports, unrolls=unrolls,
                                       interpret=interpret)
    return steepest_descent_ref(gx, gy)


def steepest_descent_oracle(gx, gy):
    return steepest_descent_ref(gx, gy)


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def hessian(sd, *, ports=1, unrolls=8, use_pallas=True, interpret=False):
    if use_pallas:
        return hessian_kernel(sd, ports=ports, unrolls=unrolls,
                              interpret=interpret)
    return hessian_ref(sd)


def hessian_oracle(sd):
    return hessian_ref(sd)
