"""Oracles: LK steepest-descent images + Gauss-Newton Hessian
(same math as apps.wami.components)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["steepest_descent_ref", "hessian_ref"]


def steepest_descent_ref(gx: jnp.ndarray, gy: jnp.ndarray) -> jnp.ndarray:
    H, W = gx.shape
    yy, xx = jnp.meshgrid(jnp.arange(H, dtype=gx.dtype),
                          jnp.arange(W, dtype=gx.dtype), indexing="ij")
    return jnp.stack([gx * xx, gx * yy, gx, gy * xx, gy * yy, gy], axis=-1)


def hessian_ref(sd: jnp.ndarray) -> jnp.ndarray:
    flat = sd.reshape(-1, 6)
    return flat.T @ flat
