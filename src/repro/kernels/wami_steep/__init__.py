from .ops import (grid_steps, hessian, hessian_oracle, hessian_vmem_bytes,
                  steepest_descent, steepest_descent_oracle, vmem_bytes)

__all__ = ["steepest_descent", "steepest_descent_oracle",
           "hessian", "hessian_oracle",
           "vmem_bytes", "grid_steps", "hessian_vmem_bytes"]
