"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jitted wrapper with an XLA fallback) and <name>/ref.py
(pure-jnp oracle).  Validated with interpret=True on CPU; the dry-run
lowers the XLA path (DESIGN.md Section 6).

The wami_* kernels additionally expose the COSMOS knob pair (``ports``
-> lane-bank grid columns, ``unrolls`` -> rows per grid step; shared
plumbing in ``wami_common.py``) plus ``vmem_bytes``/``grid_steps`` cost
models — they are the measurable substrate of the ``PallasOracle``
backend (DESIGN.md Section 2, docs/backends.md).
"""
