"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jitted wrapper with an XLA fallback) and <name>/ref.py
(pure-jnp oracle).  Validated with interpret=True on CPU; the dry-run
lowers the XLA path (DESIGN.md Section 6).
"""
