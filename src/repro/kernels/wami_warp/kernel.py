"""WAMI affine warp (bilinear resample) as a Pallas kernel.

The gather is the part TPUs dislike: arbitrary per-pixel source
addresses do not map onto the VMEM tiling.  Following the wami_gradient
halo recipe (DESIGN.md §2), the ops wrapper performs the address
computation and the four neighbour gathers with XLA — where the
scatter/gather engine lives — and the Pallas kernel consumes six
aligned planes (i00, i01, i10, i11, fx, fy) and does the arithmetic
(the bilinear blend), knob-tiled into ``ports`` lane-banks x
``unrolls`` rows per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..wami_common import (grid_steps_model, knob_blocks, parallel_params,
                           tile_spec, vmem_bytes_model)

__all__ = ["warp_blend_kernel", "warp_gather", "vmem_bytes", "grid_steps"]

_N_IN, _N_OUT = 6, 1


def _kernel(i00_ref, i01_ref, i10_ref, i11_ref, fx_ref, fy_ref, out_ref):
    fx, fy = fx_ref[...], fy_ref[...]
    top = i00_ref[...] * (1 - fx) + i01_ref[...] * fx
    bot = i10_ref[...] * (1 - fx) + i11_ref[...] * fx
    out_ref[...] = top * (1 - fy) + bot * fy


def warp_gather(img: jnp.ndarray, p: jnp.ndarray):
    """XLA side: affine source addresses + 4-neighbour gathers.

    x' = (1+p1) x + p2 y + p3 ;  y' = p4 x + (1+p5) y + p6.
    Returns (i00, i01, i10, i11, fx, fy), each (H, W).
    """
    H, W = img.shape
    yy, xx = jnp.meshgrid(jnp.arange(H, dtype=img.dtype),
                          jnp.arange(W, dtype=img.dtype), indexing="ij")
    sx = (1.0 + p[0]) * xx + p[1] * yy + p[2]
    sy = p[3] * xx + (1.0 + p[4]) * yy + p[5]
    x0 = jnp.clip(jnp.floor(sx), 0, W - 2)
    y0 = jnp.clip(jnp.floor(sy), 0, H - 2)
    fx = jnp.clip(sx - x0, 0.0, 1.0)
    fy = jnp.clip(sy - y0, 0.0, 1.0)
    x0i, y0i = x0.astype(jnp.int32), y0.astype(jnp.int32)
    return (img[y0i, x0i], img[y0i, x0i + 1],
            img[y0i + 1, x0i], img[y0i + 1, x0i + 1], fx, fy)


def warp_blend_kernel(img: jnp.ndarray, p: jnp.ndarray, *, ports: int = 1,
                      unrolls: int = 8, interpret: bool = False
                      ) -> jnp.ndarray:
    """img: (H, W), p: affine params (6,) -> warped (H, W)."""
    H, W = img.shape
    bh, bw = knob_blocks(H, W, ports=ports, unrolls=unrolls)
    planes = warp_gather(img, p)
    spec = tile_spec(bh, bw)
    return pl.pallas_call(
        _kernel,
        grid=(H // bh, ports),
        in_specs=[spec] * 6,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((H, W), img.dtype),
        compiler_params=parallel_params(),
        interpret=interpret,
    )(*planes)


vmem_bytes = functools.partial(vmem_bytes_model, n_in=_N_IN, n_out=_N_OUT)
grid_steps = grid_steps_model
