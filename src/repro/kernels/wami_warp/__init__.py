from .ops import grid_steps, vmem_bytes, warp_affine, warp_affine_oracle

__all__ = ["warp_affine", "warp_affine_oracle", "vmem_bytes", "grid_steps"]
