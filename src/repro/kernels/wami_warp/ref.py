"""Oracle: bilinear affine warp (same math as apps.wami.components)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warp_affine_ref"]


def warp_affine_ref(img: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    H, W = img.shape
    yy, xx = jnp.meshgrid(jnp.arange(H, dtype=img.dtype),
                          jnp.arange(W, dtype=img.dtype), indexing="ij")
    sx = (1.0 + p[0]) * xx + p[1] * yy + p[2]
    sy = p[3] * xx + (1.0 + p[4]) * yy + p[5]
    x0 = jnp.clip(jnp.floor(sx), 0, W - 2)
    y0 = jnp.clip(jnp.floor(sy), 0, H - 2)
    fx = jnp.clip(sx - x0, 0.0, 1.0)
    fy = jnp.clip(sy - y0, 0.0, 1.0)
    x0i, y0i = x0.astype(jnp.int32), y0.astype(jnp.int32)
    i00 = img[y0i, x0i]
    i01 = img[y0i, x0i + 1]
    i10 = img[y0i + 1, x0i]
    i11 = img[y0i + 1, x0i + 1]
    top = i00 * (1 - fx) + i01 * fx
    bot = i10 * (1 - fx) + i11 * fx
    return top * (1 - fy) + bot * fy
