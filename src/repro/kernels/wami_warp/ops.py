"""Jitted wrapper for the WAMI warp kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import grid_steps, vmem_bytes, warp_blend_kernel
from .ref import warp_affine_ref

__all__ = ["warp_affine", "warp_affine_oracle", "vmem_bytes", "grid_steps"]


@functools.partial(jax.jit, static_argnames=("ports", "unrolls",
                                             "use_pallas", "interpret"))
def warp_affine(img, p, *, ports=1, unrolls=8, use_pallas=True,
                interpret=False):
    if use_pallas:
        return warp_blend_kernel(img, p, ports=ports, unrolls=unrolls,
                                 interpret=interpret)
    return warp_affine_ref(img, p)


def warp_affine_oracle(img, p):
    return warp_affine_ref(img, p)
