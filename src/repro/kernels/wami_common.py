"""Shared plumbing for the COSMOS-knob WAMI kernels (DESIGN.md §2).

Every WAMI stage kernel maps the paper's two knobs onto the same
BlockSpec/grid geometry, established by ``wami_gradient``:

  * ``ports``   -> number of column banks: the W axis splits into
    ``ports`` lane-blocks processed by parallel grid columns (the
    multi-bank PLM Mnemosyne would generate, as VMEM tiles);
  * ``unrolls`` -> rows computed per grid step (``block_h``): loop-body
    replication, trading VMEM footprint for fewer grid iterations.

This module holds the helpers those kernels share: the jax<0.5 compat
shim for ``pltpu.CompilerParams``, the knob -> (grid, BlockSpec)
translation, and the VMEM/grid cost models parameterized by the number
of input/output blocks a kernel touches per grid step.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):   # jax < 0.5: old class name
    pltpu.CompilerParams = pltpu.TPUCompilerParams

__all__ = ["pltpu", "knob_blocks", "tile_spec", "parallel_params",
           "arbitrary_params", "vmem_bytes_model", "grid_steps_model"]


def knob_blocks(H: int, W: int, *, ports: int, unrolls: int
                ) -> Tuple[int, int]:
    """(block_h, block_w) for a knob pair; asserts the divisibility the
    real grid requires (the PallasOracle reports non-divisible knob
    points as infeasible instead of asserting)."""
    assert W % ports == 0, f"W={W} not divisible by ports={ports}"
    assert H % unrolls == 0, f"H={H} not divisible by unrolls={unrolls}"
    return unrolls, W // ports


def tile_spec(bh: int, bw: int) -> pl.BlockSpec:
    """The canonical (rows, lane-bank) block: grid cell (i, j) covers
    rows [i*bh, (i+1)*bh) of bank j."""
    return pl.BlockSpec((bh, bw), lambda i, j: (i, j))


def parallel_params() -> "pltpu.CompilerParams":
    """Both grid axes independent (elementwise/stencil stages)."""
    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel"))


def arbitrary_params() -> "pltpu.CompilerParams":
    """Sequential grid walk — required when the kernel accumulates into
    an output block shared across grid steps (reductions)."""
    return pltpu.CompilerParams(
        dimension_semantics=("arbitrary", "arbitrary"))


def vmem_bytes_model(H: int, W: int, *, ports: int, unrolls: int,
                     n_in: int, n_out: int, dtype_bytes: int = 4) -> int:
    """VMEM working set per grid step: ``n_in`` input + ``n_out`` output
    blocks of (unrolls, W/ports) words each."""
    return (n_in + n_out) * unrolls * (W // ports) * dtype_bytes


def grid_steps_model(H: int, W: int, *, ports: int, unrolls: int) -> int:
    """Sequential steps if one core walks the grid (latency model input)."""
    return (H // unrolls) * ports
