"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD "state-space duality" insight: within a chunk
of Q tokens the recurrence is a (Q x Q) masked-decay attention — an MXU
matmul — and across chunks only the (P x N) state is carried.  The carry
lives in VMEM scratch across a SEQUENTIAL chunk grid dimension, so the
kernel streams x/dt/B/C chunk tiles HBM->VMEM exactly once and never
materializes the (S x S) dual form.

Grid: (Bz, H, n_chunks), last dimension "arbitrary" (sequential).
Block shapes: x (1,1,Q,P), dt (1,1,Q), B/C (1,Q,N) shared across heads,
outputs y (1,1,Q,P) and the final state (1,1,P,N) written on the last
chunk.  Q and N default to 128 (lane-width aligned); P is the head dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):   # jax < 0.5: old class name
    pltpu.CompilerParams = pltpu.TPUCompilerParams

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
            chunk: int, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0]                               # ()
    Bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)          # (Q, N)

    a = dt * A                                 # (Q,) log-decay steps
    cum = jnp.cumsum(a)                        # within-chunk cumulative

    # intra-chunk dual form: scores (Q, Q) = (C_i . B_j) * L_ij * dt_j
    s = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # mask before exp (masked diffs are positive and would overflow)
    L = jnp.exp(jnp.where(row >= col, diff, -1e30))
    w = s * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))     # (Q, P)

    # inter-chunk: y += C_i . (exp(cum_i) * h_in)
    h = h_scr[...]                                               # (P, N)
    y_inter = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())))  # (Q, P)
    y = y + y_inter * jnp.exp(cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h' = exp(sum a) * h + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    total = cum[-1]
    rem = jnp.exp(total - cum) * dt                              # (Q,)
    contrib = jax.lax.dot_general(x * rem[:, None], Bm,
                                  (((0,), (0,)), ((), ())))      # (P, N)
    h_scr[...] = jnp.exp(total) * h + contrib

    @pl.when(c_idx == n_chunks - 1)
    def _finish():
        hout_ref[0, 0] = h_scr[...]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: (Bz,S,H,P); dt: (Bz,S,H); A: (H,); B, C: (Bz,S,N).

    Returns (y (Bz,S,H,P), h_final (Bz,H,P,N)).  S % chunk == 0.
    """
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    xt = x.transpose(0, 2, 1, 3)               # (Bz,H,S,P)
    dtt = dt.transpose(0, 2, 1)                # (Bz,H,S)

    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_fin = pl.pallas_call(
        kern,
        grid=(Bz, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), B, C)
    return y.transpose(0, 2, 1, 3), h_fin
