"""Jitted wrapper for the SSD scan kernel (Pallas or jnp oracle)."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan
from .ref import ssd_ref

__all__ = ["ssd", "ssd_oracle"]


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd(x, dt, A, B, C, *, chunk=128, use_pallas=True, interpret=False):
    if use_pallas:
        return ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, B, C)


def ssd_oracle(x, dt, A, B, C):
    return ssd_ref(x, dt, A, B, C)
