"""Pure-jnp oracle for the SSD scan: the naive sequential recurrence.

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (B_t outer x_t)
    y_t = C_t . h_t

O(S) sequential — slow but unambiguous ground truth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ssd_ref"]


def ssd_ref(x, dt, A, B, C, h0=None):
    """x: (Bz, S, H, P); dt: (Bz, S, H); A: (H,); B, C: (Bz, S, N).

    Returns (y (Bz,S,H,P), h_final (Bz,H,P,N)).
    """
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bz, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A)[..., None, None]           # (Bz,H,1,1)
        contrib = (dtt[..., None, None]
                   * xt[..., :, None] * bt[:, None, None, :])  # (Bz,H,P,N)
        h = h * decay + contrib
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.transpose(1, 0, 2),
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h
