from .kernel import ssd_scan
from .ops import ssd, ssd_oracle
from .ref import ssd_ref

__all__ = ["ssd_scan", "ssd", "ssd_oracle", "ssd_ref"]
