"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation of the GPU flash-attention insight (DESIGN.md §2):
the streaming-softmax tiling is kept, but blocks are sized for VMEM and
the MXU — (block_q x d) and (block_kv x d) tiles with d and block sizes
multiples of 128 so both matmuls hit the 128x128 systolic array, and the
running (m, l, acc) state lives in VMEM scratch across the sequential
KV grid dimension (no shared-memory/warp semantics to port).

Grid: (B, H, Sq/block_q, Skv/block_kv) with the LAST dimension sequential
("arbitrary") — each (b, h, iq) walks its KV blocks in order,
accumulating into scratch, and writes the normalized output tile on the
final block.  GQA is expressed in the k/v BlockSpec index maps (head h
reads KV head h // group), so no KV duplication ever materializes.

Supports: causal masking, sliding windows (gemma2 local layers),
attention soft-capping, and a q_offset for decode alignment.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):   # jax < 0.5: old class name
    pltpu.CompilerParams = pltpu.TPUCompilerParams

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            q_offset: int, block_q: int, block_kv: int, n_kv: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bkv)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kv_pos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    dist = q_pos - kv_pos
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= dist >= 0
    if window and window > 0:
        ok &= dist < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: p would be exp(NEG_INF - NEG_INF) = 1; zero them
    p = jnp.where(ok, p, 0.0)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, d); k, v: (B, K, Skv, d).  Returns (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0, "GQA requires H % K == 0"
    G = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, nkv = Sq // block_q, Skv // block_kv
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv, n_kv=nkv)

    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
