"""Jitted public wrapper for the flash-attention kernel.

Accepts the model-layout tensors (B, S, H, hd) and dispatches to the
Pallas kernel (TPU) or the jnp oracle (any backend).  ``interpret=True``
runs the kernel body in Python on CPU — how the tests validate it here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref

__all__ = ["mha", "mha_ref"]


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_offset", "block_q",
                                             "block_kv", "use_pallas",
                                             "interpret"))
def mha(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
        block_q=128, block_kv=128, use_pallas=True, interpret=False):
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) -> (B, Sq, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        o = flash_attention(qt, kt, vt, causal=causal, window=window,
                            softcap=softcap, q_offset=q_offset,
                            block_q=block_q, block_kv=block_kv,
                            interpret=interpret)
    else:
        o = attention_ref(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, q_offset=q_offset)
    return o.transpose(0, 2, 1, 3)


def mha_ref(q, k, v, **kw):
    kw.pop("use_pallas", None)
    kw.pop("interpret", None)
    kw.pop("block_q", None)
    kw.pop("block_kv", None)
    return mha(q, k, v, use_pallas=False, **kw)
