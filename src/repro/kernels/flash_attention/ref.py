"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (Sq, Skv) score matrix — O(S^2) memory, fine for
test sizes, numerically the ground truth the kernel must match.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, q_offset: int = 0) -> jnp.ndarray:
    """q: (B, H, Sq, d); k, v: (B, K, Skv, d) with H % K == 0.

    ``q_offset``: absolute position of q[0] (decode: Skv - Sq).
    """
    B, H, Sq, d = q.shape
    K = k.shape[1]
    G = H // K
    qf = q.astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, K, G, Sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(k.shape[2])
    dist = q_pos[:, None] - kv_pos[None, :]
    ok = jnp.ones_like(dist, dtype=bool)
    if causal:
        ok &= dist >= 0
    if window and window > 0:
        ok &= dist < window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, d).astype(q.dtype)
