from .kernel import flash_attention
from .ops import mha, mha_ref
from .ref import attention_ref

__all__ = ["flash_attention", "mha", "mha_ref", "attention_ref"]
