"""The metrics registry: counters, gauges, fixed-bucket histograms.

Before this module every layer kept its own bare-int counters —
``OracleLedger.invocations``, ``PersistentOracleCache.hits``, per-pool
``SharedOracle`` tallies, ``DSEService``'s queue stats — each with its
own locking discipline (and, in places, none).  The registry unifies
them behind one *pull* interface:

    reg = MetricsRegistry()
    reg.counter("oracle.points.fresh").inc()
    reg.histogram("service.latency_s").observe(wall)
    reg.snapshot()        # -> one deterministic JSON-able dict

Every instrument is internally locked, so incrementing from a worker
thread and snapshotting from the service thread is always consistent;
the classes that historically exposed bare ints now keep those names as
properties over registry counters (lock-consistent by construction).

Instruments are create-on-first-use and name-unique: asking for the
same name with a different type (or different histogram buckets) is a
programming error and raises.  ``DSEService.stats()`` embeds the
snapshot; the soak bench persists the latency/queue-wait histograms
into ``artifacts/bench/BENCH_serve.json``.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LATENCY_BUCKETS_S",
]

#: default fixed buckets for latency histograms, in seconds (upper
#: bounds; observations above the last edge land in "+Inf")
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """A monotonically increasing count (lock-protected)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> int:
        with self._lock:
            self._value += n
            return self._value

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """A point-in-time value (queue depth, running queries)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, delta: float) -> float:
        with self._lock:
            self._value += delta
            return self._value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket distribution: cumulative-style bucket counts plus
    ``count``/``sum`` (enough for rates and coarse percentiles without
    keeping observations)."""

    __slots__ = ("name", "buckets", "_counts", "_count", "_sum", "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name!r}: bucket edges must be "
                             f"non-empty, unique, and ascending: {buckets}")
        self.name = name
        self.buckets = edges
        self._counts = [0] * (len(edges) + 1)      # +1 = overflow (+Inf)
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = len(self.buckets)
        for j, edge in enumerate(self.buckets):
            if value <= edge:
                i = j
                break
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
        out: Dict[str, Any] = {"count": count, "sum": round(total, 6)}
        buckets: Dict[str, int] = {}
        for edge, n in zip(self.buckets, counts):
            buckets[f"le_{edge:g}"] = n
        buckets["le_inf"] = counts[-1]
        out["buckets"] = buckets
        return out


class MetricsRegistry:
    """Name -> instrument, create-on-first-use, one snapshot call.

    A name is permanently bound to its first-requested type (and, for
    histograms, bucket edges): a mismatch raises rather than silently
    splitting a metric in two.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, "
                    f"requested as {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS_S
                  ) -> Histogram:
        hist = self._get(name, Histogram, lambda: Histogram(name, buckets))
        if hist.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"buckets {hist.buckets}")
        return hist

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._instruments))

    def snapshot(self) -> Dict[str, Any]:
        """Every instrument's current value, sorted by name — the pull
        interface ``DSEService.stats()`` (and the benches) read."""
        with self._lock:
            items = sorted(self._instruments.items())
        return {name: inst.snapshot() for name, inst in items}
