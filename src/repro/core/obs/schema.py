"""Trace-artifact schema: what a committed trace file must look like.

The ``autoshard/service-trace`` bench cell commits a deterministic
Chrome ``trace_event`` artifact; this module is the schema CI
re-validates it against (the ``observability`` job), with no external
JSON-schema dependency — the schema is a declarative table below and
the validator walks it.

Two formats are covered:

  * **Chrome trace document** (``*.trace.json``) — ``validate_chrome``:
    top-level ``traceEvents`` list; every event needs ``name``/``cat``/
    ``ph``/``pid``/``tid``/``ts``(+``dur`` for ``ph="X"``); ``ph`` is
    ``X`` (complete span) or ``i`` (instant); every ``oracle.point`` /
    ``shared.point`` event must carry an ``args.outcome`` drawn from
    the four-way partition ``fresh | cache_hit | inflight_join |
    replay``.
  * **span JSONL** (:meth:`Tracer.export_jsonl` output) —
    ``validate_jsonl``: one object per line with ``id``/``name``/
    ``tid``/``start``/``end``/``status``/``attrs``; ``parent`` ids must
    resolve to an earlier span (ids are allocated in start order).

CLI::

    python -m repro.core.obs.schema artifacts/bench/autoshard/*.trace.json

exits 1 listing every violation, 0 when all files validate.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional, Sequence

from .trace import OUTCOMES

__all__ = ["OUTCOMES", "validate_chrome", "validate_jsonl",
           "validate_file", "main"]

#: span names whose events must carry an outcome tag
_POINT_SPANS = ("oracle.point", "shared.point")

#: required event fields -> allowed types (the declarative schema)
_EVENT_FIELDS: Dict[str, tuple] = {
    "name": (str,),
    "cat": (str,),
    "ph": (str,),
    "pid": (int,),
    "tid": (int,),
    "ts": (int, float),
    "args": (dict,),
}

_SPAN_FIELDS: Dict[str, tuple] = {
    "id": (int,),
    "name": (str,),
    "tid": (int,),
    "start": (int, float),
    "end": (int, float),
    "status": (str,),
    "attrs": (dict,),
}


def _check_fields(obj: Dict[str, Any], fields: Dict[str, tuple],
                  where: str, errors: List[str]) -> bool:
    ok = True
    for key, types in fields.items():
        if key not in obj:
            errors.append(f"{where}: missing required field {key!r}")
            ok = False
        elif not isinstance(obj[key], types) or isinstance(obj[key], bool):
            errors.append(f"{where}: field {key!r} has type "
                          f"{type(obj[key]).__name__}, want "
                          f"{'/'.join(t.__name__ for t in types)}")
            ok = False
    return ok


def validate_chrome(doc: Any) -> List[str]:
    """Violations in a Chrome ``trace_event`` document (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"document: want a JSON object, got {type(doc).__name__}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["document: missing or non-list 'traceEvents'"]
    if not events:
        errors.append("document: empty 'traceEvents' (nothing was traced)")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: want an object")
            continue
        if not _check_fields(ev, _EVENT_FIELDS, where, errors):
            continue
        ph = ev["ph"]
        if ph not in ("X", "i"):
            errors.append(f"{where}: unknown phase {ph!r} (want 'X' or 'i')")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                    or dur < 0:
                errors.append(f"{where}: complete event needs a "
                              f"non-negative 'dur', got {dur!r}")
        if ev["ts"] < 0:
            errors.append(f"{where}: negative ts {ev['ts']!r}")
        if ev["name"].split(".", 1)[0] != ev["cat"]:
            errors.append(f"{where}: cat {ev['cat']!r} is not the first "
                          f"segment of name {ev['name']!r}")
        if ev["name"] in _POINT_SPANS:
            outcome = ev["args"].get("outcome")
            if outcome not in OUTCOMES:
                errors.append(
                    f"{where}: {ev['name']} event needs args.outcome in "
                    f"{list(OUTCOMES)}, got {outcome!r}")
    return errors


def validate_jsonl(text: str) -> List[str]:
    """Violations in a span-JSONL export (empty = valid)."""
    errors: List[str] = []
    seen: set = set()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        errors.append("jsonl: no spans")
    for i, line in enumerate(lines):
        where = f"line {i + 1}"
        try:
            span = json.loads(line)
        except ValueError as e:
            errors.append(f"{where}: invalid JSON: {e}")
            continue
        if not isinstance(span, dict):
            errors.append(f"{where}: want an object")
            continue
        if not _check_fields(span, _SPAN_FIELDS, where, errors):
            continue
        if span["status"] not in ("ok", "error"):
            errors.append(f"{where}: unknown status {span['status']!r}")
        if span["end"] < span["start"]:
            errors.append(f"{where}: end {span['end']} before start "
                          f"{span['start']}")
        parent = span.get("parent")
        if parent is not None and parent not in seen:
            errors.append(f"{where}: parent {parent} does not name an "
                          f"earlier span")
        if span["name"] in _POINT_SPANS and \
                span["attrs"].get("outcome") not in OUTCOMES:
            errors.append(f"{where}: {span['name']} span needs "
                          f"attrs.outcome in {list(OUTCOMES)}, got "
                          f"{span['attrs'].get('outcome')!r}")
        seen.add(span["id"])
    return errors


def validate_file(path: str) -> List[str]:
    """Dispatch on extension: ``*.jsonl`` as span lines, anything else
    as a Chrome trace document."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        return [f"unreadable: {e}"]
    if path.endswith(".jsonl"):
        return validate_jsonl(text)
    try:
        doc = json.loads(text)
    except ValueError as e:
        return [f"invalid JSON: {e}"]
    return validate_chrome(doc)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.obs.schema",
        description="validate trace artifacts (Chrome trace_event JSON "
                    "or span JSONL) against the documented schema")
    ap.add_argument("paths", nargs="+", help="trace files to validate")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        errors = validate_file(path)
        if errors:
            bad += 1
            print(f"FAIL {path}: {len(errors)} violation(s)",
                  file=sys.stderr)
            for e in errors[:50]:
                print(f"  {e}", file=sys.stderr)
        else:
            print(f"ok   {path}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
