"""Span-based tracing for the DSE engine and service.

COSMOS's headline number is invocation *frugality* (Fig. 11), and the
multi-tenant service's is *coalescing* — both are claims about where
tool invocations came from and why some never happened.  This module
makes every such event a first-class, exportable record:

  * :class:`Span` — one timed, attributed unit of work with
    parent/child nesting (``session.characterize`` >
    ``session.component`` > ``oracle.point``);
  * :class:`Tracer` — the collector: ``tracer.span(name, **attrs)`` is
    a context manager, ``tracer.begin``/``Span.finish`` cover
    lifecycles that cross function boundaries (a service query from
    submit to completion), and ``tracer.instant`` records
    zero-duration marks (progress ticks);
  * two clocks — :class:`WallClock` for real runs and
    :class:`LogicalClock`, a deterministic tick counter, so CI can
    commit trace artifacts that are *byte-stable* across machines and
    runs;
  * two exporters — newline-JSON (:meth:`Tracer.export_jsonl`) for
    grep/jq pipelines, and the Chrome ``trace_event`` format
    (:meth:`Tracer.export_chrome`) so a full ``service-soak`` run opens
    directly in Perfetto / ``chrome://tracing``.

Tracing is opt-in and cheap when off: the module-level
:data:`NULL_TRACER` satisfies the same surface with reused no-op
objects, so instrumented hot paths (every oracle point) cost one method
call when no one is listening.  The span taxonomy and both export
formats are documented in docs/observability.md; the trace-artifact
schema CI validates lives in :mod:`repro.core.obs.schema`.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Protocol

__all__ = [
    "Clock",
    "WallClock",
    "LogicalClock",
    "OUTCOMES",
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
]

#: the per-point oracle outcome partition (docs/observability.md):
#: every evaluated knob point gets exactly one of these
OUTCOMES = ("fresh", "cache_hit", "inflight_join", "replay")


# ----------------------------------------------------------------------
# Clocks
# ----------------------------------------------------------------------
class Clock(Protocol):
    """Timestamps for spans.  ``now`` must be monotonic."""

    def now(self) -> float: ...


class WallClock:
    """Real elapsed time (``time.monotonic``) — what live runs use."""

    def now(self) -> float:
        return time.monotonic()


class LogicalClock:
    """A deterministic clock: every ``now()`` is the next integer tick.

    Two identical sequential runs observe identical tick sequences, so
    exported traces are byte-identical — the property the CI
    determinism gate (and the committed trace artifact) relies on.
    Thread-safe: concurrent runs still get *unique, ordered* ticks,
    they just stop being reproducible when the interleaving is racy.
    """

    def __init__(self, start: int = 0):
        self._t = int(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            self._t += 1
            return float(self._t)


# ----------------------------------------------------------------------
# Spans
# ----------------------------------------------------------------------
class Span:
    """One unit of traced work: ``[start, end)`` + attributes.

    Use as a context manager (the common case), or finish explicitly
    via :meth:`finish` for lifecycles that cross function boundaries.
    An exception leaving the ``with`` body is recorded on the span
    (``status="error"``, ``error=<repr>``) and re-raised.
    """

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "tid",
                 "start", "end", "attrs", "status", "error", "_stacked")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], tid: int, start: float,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.tid = tid
        self.start = start
        self.end: Optional[float] = None
        self.attrs = attrs
        self.status = "ok"
        self.error: Optional[str] = None
        self._stacked = False

    def set(self, key: str, value: Any) -> None:
        """Attach/overwrite one attribute (JSON-able values only)."""
        self.attrs[key] = value

    def finish(self, error: Optional[BaseException] = None) -> None:
        if self.end is not None:      # idempotent
            return
        if error is not None:
            self.status = "error"
            self.error = f"{type(error).__name__}: {error}"
        self._tracer._finish(self)

    # -- context manager ----------------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self._stacked = True
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._stacked:
            self._tracer._pop(self)
            self._stacked = False
        self.finish(exc)
        return False                   # never swallow

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "id": self.span_id, "name": self.name, "tid": self.tid,
            "start": self.start, "end": self.end, "status": self.status,
            "attrs": dict(self.attrs),
        }
        if self.parent_id is not None:
            out["parent"] = self.parent_id
        if self.error is not None:
            out["error"] = self.error
        return out


class _NullSpan:
    """The no-op span: every mutator is a cheap pass.  One shared
    instance serves the whole process."""

    __slots__ = ()
    name = ""
    span_id = 0
    parent_id = None
    attrs: Dict[str, Any] = {}

    def set(self, key: str, value: Any) -> None:
        pass

    def finish(self, error: Optional[BaseException] = None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


# ----------------------------------------------------------------------
# The tracer
# ----------------------------------------------------------------------
class Tracer:
    """Collects spans; exports newline-JSON and Chrome ``trace_event``.

    Parenting is implicit within a thread (a context-managed span
    becomes the parent of spans opened inside it, on the same thread)
    and explicit across threads (``parent=``): phase spans hand
    themselves to their fan-out workers.  Thread lanes (``tid``) are
    small ints assigned in order of each thread's first span — under a
    sequential drive every run assigns the same lanes, which keeps
    logical-clock exports byte-stable.
    """

    def __init__(self, clock: Optional[Clock] = None):
        self.clock = clock or WallClock()
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._next_id = 1
        self._tids: Dict[int, int] = {}
        self._local = threading.local()

    # -- span lifecycle ------------------------------------------------
    def span(self, name: str, *, parent: Optional[Span] = None,
             **attrs: Any) -> Span:
        """Open a span.  Use as ``with tracer.span(...) as sp:`` —
        entering pushes it onto this thread's parent stack."""
        return self.begin(name, parent=parent, **attrs)

    def begin(self, name: str, *, parent: Optional[Span] = None,
              **attrs: Any) -> Span:
        """Open a span without touching the parent stack (for
        lifecycles finished elsewhere via :meth:`Span.finish`)."""
        ident = threading.get_ident()
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
            tid = self._tids.setdefault(ident, len(self._tids))
        if parent is None:
            stack = getattr(self._local, "stack", None)
            if stack:
                parent = stack[-1]
        parent_id = None if parent is None else parent.span_id
        return Span(self, name, span_id, parent_id, tid,
                    self.clock.now(), dict(attrs))

    def instant(self, name: str, *, parent: Optional[Span] = None,
                **attrs: Any) -> None:
        """Record a zero-duration mark (progress ticks, rejections)."""
        sp = self.begin(name, parent=parent, **attrs)
        sp.end = sp.start
        with self._lock:
            self._spans.append(sp)

    # internal: stack + completion
    def _push(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(span)

    def _pop(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()

    def _finish(self, span: Span) -> None:
        span.end = self.clock.now()
        with self._lock:
            self._spans.append(span)

    def current(self) -> Optional[Span]:
        """This thread's innermost open span (None outside any)."""
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    # -- reading back --------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Finished spans in start order (optionally filtered by name)."""
        with self._lock:
            out = sorted(self._spans, key=lambda s: s.span_id)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def outcome_counts(self, name: str = "oracle.point",
                       by: str = "outcome") -> Dict[str, int]:
        """Histogram of one attribute over spans of ``name`` — the
        Fig. 11 reconciliation helper (fresh/cache_hit/... counts)."""
        out: Dict[str, int] = {}
        for s in self.spans(name):
            key = str(s.attrs.get(by, "?"))
            out[key] = out.get(key, 0) + 1
        return out

    # -- exporters -----------------------------------------------------
    def export_jsonl(self) -> str:
        """One JSON object per line, spans in start order.  Keys are
        sorted, so identical span streams give identical bytes."""
        return "\n".join(json.dumps(s.to_json(), sort_keys=True)
                         for s in self.spans()) + "\n"

    def export_chrome(self, *, time_unit_us: float = 1.0) -> Dict[str, Any]:
        """The Chrome ``trace_event`` document (JSON-able dict).

        Complete spans become ``ph="X"`` events, instants ``ph="i"``;
        ``ts``/``dur`` are microseconds (wall clocks report seconds, so
        they pass ``time_unit_us=1e6``; the logical clock's ticks map
        1:1).  Load the written file in Perfetto / ``chrome://tracing``.
        """
        events: List[Dict[str, Any]] = []
        for s in self.spans():
            cat = s.name.split(".", 1)[0]
            args = {k: s.attrs[k] for k in sorted(s.attrs)}
            if s.parent_id is not None:
                args["parent"] = s.parent_id
            if s.error is not None:
                args["error"] = s.error
            ev: Dict[str, Any] = {
                "name": s.name, "cat": cat, "pid": 1, "tid": s.tid,
                "ts": round(s.start * time_unit_us, 3), "args": args,
            }
            end = s.end if s.end is not None else s.start
            if end == s.start:
                ev["ph"] = "i"
                ev["s"] = "t"          # thread-scoped instant
            else:
                ev["ph"] = "X"
                ev["dur"] = round((end - s.start) * time_unit_us, 3)
            events.append(ev)
        return {"displayTimeUnit": "ms", "traceEvents": events}


class NullTracer(Tracer):
    """The disabled tracer: same surface, no recording, near-zero cost.

    The single module-level :data:`NULL_TRACER` is what every
    instrumented layer defaults to — ``tracer or NULL_TRACER`` — so
    un-traced runs never allocate spans."""

    def __init__(self):             # no clock, no lock, no storage
        pass

    def span(self, name: str, *, parent: Optional[Span] = None,
             **attrs: Any) -> _NullSpan:        # type: ignore[override]
        return _NULL_SPAN

    begin = span                                 # type: ignore[assignment]

    def instant(self, name: str, *, parent: Optional[Span] = None,
                **attrs: Any) -> None:
        pass

    def current(self) -> None:
        return None

    def spans(self, name: Optional[str] = None) -> List[Span]:
        return []

    def outcome_counts(self, name: str = "oracle.point",
                       by: str = "outcome") -> Dict[str, int]:
        return {}

    def export_jsonl(self) -> str:
        return "\n"

    def export_chrome(self, *, time_unit_us: float = 1.0) -> Dict[str, Any]:
        return {"displayTimeUnit": "ms", "traceEvents": []}


NULL_TRACER = NullTracer()
