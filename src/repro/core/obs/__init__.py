"""Unified observability for the DSE engine and service.

Two small, dependency-free primitives — a span tracer and a metrics
registry — threaded through the whole stack (docs/observability.md):

  * :mod:`repro.core.obs.trace` — ``Tracer.span(name, **attrs)``
    context-manager spans with parent/child nesting, an injectable
    clock (:class:`WallClock` live, :class:`LogicalClock` for
    byte-stable CI artifacts), newline-JSON and Chrome ``trace_event``
    exporters (Perfetto-openable);
  * :mod:`repro.core.obs.metrics` — :class:`MetricsRegistry` with
    lock-consistent counters, gauges, and fixed-bucket latency
    histograms behind one ``snapshot()`` pull interface;
  * :mod:`repro.core.obs.schema` — the trace-artifact schema CI
    validates committed traces against
    (``python -m repro.core.obs.schema``).

Instrumented layers: :class:`~repro.core.session.ExplorationSession`
phases, the oracle stack (:class:`~repro.core.oracle.OracleLedger` /
:class:`~repro.core.oracle.SharedOracle` — every evaluated point
carries an ``outcome`` tag from the four-way partition
``fresh | cache_hit | inflight_join | replay``),
:meth:`~repro.core.plm.planner.PLMPlanner.plan_point` (certificate
tier chosen), and the :class:`~repro.serve.dse_service.DSEService`
query lifecycle (submit -> queued -> dispatched -> done).
"""

from .metrics import (Counter, Gauge, Histogram, LATENCY_BUCKETS_S,
                      MetricsRegistry)
from .trace import (Clock, LogicalClock, NULL_TRACER, NullTracer, OUTCOMES,
                    Span, Tracer, WallClock)

__all__ = [
    "Clock",
    "WallClock",
    "LogicalClock",
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LATENCY_BUCKETS_S",
    "OUTCOMES",
    "validate_chrome",
    "validate_jsonl",
]

# schema is also a `python -m` entry point: importing it eagerly here
# would double-import it under runpy (same rule as core.analysis)
_SCHEMA_LAZY = {"validate_chrome", "validate_jsonl"}


def __getattr__(name):
    if name in _SCHEMA_LAZY:
        from . import schema
        return getattr(schema, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
