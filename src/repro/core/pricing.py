"""Whole-grid knob pricing: one array dispatch per component, bit-exact.

The analytical backends (`HLSTool`'s list scheduler, `XLATool`'s
roofline) price one ``(component, unrolls, ports, tile)`` point per
call, so a full Algorithm-1 sweep is thousands of scalar dispatches.
:class:`BatchPricer` re-expresses both pricing models as array programs
over the *entire* ``(ports, unrolls)`` plane of a ``(component, tile)``
pair — one vectorized evaluation, memoized, after which every scalar
request is an O(1) table lookup.

The contract is **bit-exactness**, not approximation: a `BatchPricer`
wrapped around a tool returns `Synthesis` objects equal field-for-field
(lam, area, states, feasibility mask, detail dict — and therefore the
same Fig. 11 ledger counts) to what the scalar path returns.  Two rules
make that possible:

* elementwise IEEE-754 ops (`+ - * /`, `np.ceil`, `np.maximum`) are
  correctly rounded in numpy, so mirroring the scalar code's operation
  *order* reproduces its floats exactly;
* transcendentals are NOT safe — numpy's SIMD `log2`/`power` kernels
  may differ from libm by 1 ulp — so ``x ** 0.90`` and
  ``log2(states+1)`` are computed through python's `math` on the (few)
  unique values and broadcast back through a lookup table, and the
  md5 noise hash runs in a python loop with precomputed key prefixes.

`BatchPricer` implements the batched ``Oracle`` protocol (via
:class:`~repro.core.oracle.OracleBatchMixin`), so it drops underneath an
``OracleLedger``/``SharedOracle`` with zero result-visible change; any
request outside a grid's extent (non-power-of-two ports for HLS, a
``tile=`` knob for XLA, unknown components) falls through to the
wrapped tool verbatim.  Grid builds are traced as ``pricing.batch``
spans tagged with the grid size.
"""

from __future__ import annotations

import functools
import hashlib
import math
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .hlsim import (_AREA_CTRL_STATE, _AREA_PER_FU, _AREA_PER_REG,
                    _DMA_WORDS_PER_CYCLE, _FU_SHARING_EXP, HLSTool)
from .knobs import Synthesis
from .memgen import PLMSpec
from .oracle import OracleBatchMixin
from .xlatool import _HBM_BW, _ICI_BW, _PEAK, MAX_UNROLL, XLATool

__all__ = ["BatchPricer"]

_TWO_64 = float(1 << 64)             # md5 digest -> uniform [0,1)


@functools.lru_cache(maxsize=1 << 16)
def _noise_col(head: str, tail: str, ports: Tuple[int, ...]
               ) -> Tuple[float, ...]:
    """The scalar path's ``_hash01`` draws for one unroll count over a
    port ladder, memoized.

    Each draw is a pure function of the key string — independent of
    the tool's noise *scale* (which only thresholds it) — so one
    process-wide cache serves every grid, rebuild, and pricer; repeat
    builds skip the md5 entirely."""
    return tuple(
        int.from_bytes(hashlib.md5((head + str(p) + tail).encode())
                       .digest()[:8], "big") / _TWO_64
        for p in ports)


# ----------------------------------------------------------------------
# HLS grid: the list-scheduler economics over a (ports, unrolls) plane
# ----------------------------------------------------------------------
class _HLSGrid:
    """All scalar-path outputs for one ``(component, tile)`` pair.

    ``cycles`` is stored instead of lam so any ``clock_ns`` reprices at
    lookup time with the scalar path's exact expression.
    """

    def __init__(self, tool: HLSTool, component: str, tile: int,
                 max_ports: int, max_unrolls: int):
        spec, tile_key = tool.grid_inputs(component, tile)
        # max_ports is a power of two (the adapter guarantees it), so the
        # ladder indexes by bit_length in lookup()
        ports = [1 << k for k in range(max(1, max_ports).bit_length())
                 if (1 << k) <= max_ports]
        unrolls = list(range(1, max_unrolls + 1))
        self.component, self.tile = component, tile
        self.ports, self.max_unrolls = tuple(ports), max_unrolls
        P, U = len(ports), len(unrolls)
        self.size = P * U
        ln = spec.loop
        p_arr = np.asarray(ports, dtype=np.int64)[:, None]
        u_arr = np.asarray(unrolls, dtype=np.int64)[None, :]
        pf = p_arr.astype(np.float64)
        # -- states: Eq. (1) memory serialization + dependence residue --
        if ln.gamma_r:
            rd = np.ceil((ln.gamma_r * u_arr) / pf).astype(np.int64)
        else:
            rd = np.zeros((P, U), dtype=np.int64)
        if ln.gamma_w:
            wr = np.broadcast_to(
                np.ceil(ln.gamma_w / pf).astype(np.int64), (P, U)).copy()
        else:
            wr = np.zeros((P, U), dtype=np.int64)
        mem = rd + wr
        comp = np.maximum(1, ln.dep_depth - np.maximum(0, mem - 1))
        states = np.maximum(1, mem + comp - 1)
        # -- heuristic perturbation: the md5 hash must match the scalar
        # path bit-for-bit, so it stays a python loop (key prefixes and
        # per-unroll constants hoisted, draws memoized in _noise_r)
        if tool.noise > 0:
            sd, nm = repr(tool.seed), repr(spec.name)
            tail = f", {tile_key})" if tile_key else ")"
            extra = np.zeros((P, U), dtype=np.int64)
            for j, u in enumerate(unrolls):
                p_extra = tool.noise * (0.08 + 0.012 * u)
                mod = max(1, u // 4 + 1)
                col = extra[:, j]
                rs = _noise_col(f"({sd}, {nm}, {u}, ", tail, self.ports)
                for i, r in enumerate(rs):
                    if r < p_extra:
                        col[i] = 1 + int(r * 7919) % mod
            states = states + extra
        self.states = states
        # -- latency (in cycles; lam = cycles * clock_ns * 1e-9) --------
        groups = np.ceil(ln.trip / u_arr.astype(np.float64)).astype(np.int64)
        cyc_load = math.ceil(spec.words_in / _DMA_WORDS_PER_CYCLE)
        cyc_store = math.ceil(spec.words_out / _DMA_WORDS_PER_CYCLE)
        self.cycles = (cyc_load + (groups * states + ln.dep_depth)
                       + cyc_store + 12) * spec.outer_repeats
        # -- area: transcendentals through python math (see module doc) -
        fus = np.asarray([(ln.arith_ops * u) ** _FU_SHARING_EXP
                          for u in unrolls])
        uniq, inv = np.unique(states, return_inverse=True)
        log2_lut = np.asarray([math.log2(s + 1.0) for s in uniq.tolist()])
        ctrl = states.astype(np.float64) * log2_lut[inv].reshape(states.shape)
        regs = (ln.live_values * u_arr).astype(np.float64)
        self.area_logic = (_AREA_PER_FU * fus[None, :] + _AREA_PER_REG * regs
                           + _AREA_CTRL_STATE * ctrl)
        plm_area = np.empty((P, 1))
        banks = np.empty((P, 1))
        for i, p in enumerate(ports):
            plm = tool.memgen.generate(PLMSpec(
                words=spec.plm_size(), word_bits=spec.word_bits, ports=p))
            plm_area[i, 0] = plm.area
            banks[i, 0] = plm.banks
        self.plm_area, self.banks = plm_area, banks
        self.area_total = self.area_logic + plm_area
        self.plm_words = float(spec.plm_size())
        self.word_bits = float(spec.word_bits)

    def covers(self, ports: int, unrolls: int) -> bool:
        return ports <= self.ports[-1] and unrolls <= self.max_unrolls

    def lookup(self, unrolls: int, ports: int,
               max_states: Optional[int], clock_ns: float,
               tile: int) -> Synthesis:
        i = ports.bit_length() - 1
        j = unrolls - 1
        states = int(self.states[i, j])
        if max_states is not None and states > max_states:
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls,
                             states_per_iter=states, feasible=False,
                             tile=tile)
        lam = int(self.cycles[i, j]) * clock_ns * 1e-9
        return Synthesis(
            lam=lam, area=float(self.area_total[i, j]), ports=ports,
            unrolls=unrolls, states_per_iter=states, feasible=True,
            detail={"area_logic": float(self.area_logic[i, j]),
                    "area_plm": float(self.plm_area[i, 0]),
                    "banks": float(self.banks[i, 0]),
                    "plm_words": self.plm_words,
                    "word_bits": self.word_bits},
            tile=tile)


# ----------------------------------------------------------------------
# XLA grid: the roofline + HBM-footprint model over the same plane
# ----------------------------------------------------------------------
class _XLAGrid:
    """All scalar-path outputs of ``XLATool.synthesize`` for one stage.

    The mesh/footprint branches (family, long-context kv cap, loss
    chunking) are per-component *constants*, so the whole plane reduces
    to elementwise arithmetic on ``(ports, unrolls)`` axes — the only
    care needed is mirroring ``price_train_step``'s operation order.
    """

    def __init__(self, tool: XLATool, component: str,
                 max_ports: int, max_unrolls: int):
        cfg, shape = tool.components[component]
        tp = tool.tp
        B, S = shape.global_batch, shape.seq_len
        d, L = cfg.d_model, cfg.n_layers
        N = cfg.param_count()
        n_act = cfg.active_param_count()
        ports = list(range(1, max_ports + 1))
        unrolls = list(range(1, max_unrolls + 1))
        self.component = component
        self.max_ports, self.max_unrolls = max_ports, max_unrolls
        P, U = len(ports), len(unrolls)
        self.size = P * U
        chips_list = [tool.mesh_for(p)[0] for p in ports]
        dp_list = [tool.mesh_for(p)[1]["data"] for p in ports]
        chips = np.asarray(chips_list, dtype=np.int64)[:, None]
        dp = np.asarray(dp_list, dtype=np.int64)[:, None]
        mb = np.asarray([1 << max(0, MAX_UNROLL - u) for u in unrolls],
                        dtype=np.int64)[None, :]
        self.chips, self.mb = chips, mb
        self.div_ok = np.asarray(
            [(B % dpv == 0) or (dpv % B == 0) for dpv in dp_list])[:, None]
        # -- price_train_step(remat="full", accum="float32") -----------
        b_loc = (np.maximum(1, B // dp).astype(np.float64)
                 / mb.astype(np.float64))
        tpdp = np.asarray([tp * dpv for dpv in dp_list],
                          dtype=np.int64)[:, None]
        params: Any = 2.0 * N / tp
        grads: Any = 4.0 * N / tp
        opt = 8.0 * N / tpdp
        if cfg.family == "moe":
            params = 2.0 * N / tpdp + 2.0 * cfg.vocab * d / tp
            grads = grads / dp
            opt = 8.0 * N / tpdp
        resid = L * b_loc * S * d * 2.0
        H = max(cfg.n_heads, 1)
        heads_tp = H / tp if H % tp == 0 else 1.0
        if cfg.family in ("ssm", "hybrid"):
            Q = cfg.ssm_chunk
            n_ch = max(1, S // Q)
            hd_heads = cfg.ssm_heads()
            trans = (b_loc * Q * Q * hd_heads * 4.0
                     + 4 * b_loc * S * cfg.d_inner() * 4.0 / tp) * 1.5
            trans = trans + n_ch * b_loc * Q * Q * hd_heads * 4.0 / 4
        else:
            kvc = 1024 if S >= 16384 else S
            trans = (b_loc * (H / max(heads_tp, 1)) ** 0
                     * heads_tp * S * kvc * 4.0)
            trans = trans + (3 * b_loc * S * max(cfg.d_ff, cfg.expert_ff())
                             * 2.0 / tp)
        if cfg.family == "moe":
            cap = b_loc * S * cfg.top_k * cfg.capacity_factor
            trans = trans + (3 * cap * d * 2.0 / tp
                             + cap * cfg.expert_ff() * 2.0 / tp)
        chunk = 512 if cfg.vocab >= 65536 else S
        loss = 2 * b_loc * chunk * cfg.vocab * 4.0 / tp
        total = params + grads + opt + 2.2 * (resid + trans + loss)
        est = total.astype(np.int64)            # int(total): truncates
        self.est = est
        self.fits = est <= tool.hbm_budget
        # -- roofline lambda -------------------------------------------
        tokens = B * S
        flops_dev = 8.0 * n_act * tokens / chips.astype(np.float64)
        t_comp = flops_dev / _PEAK
        w_dev = 2.0 * n_act / tp
        bytes_dev = (3.0 * w_dev * mb.astype(np.float64)
                     + 4.0 * resid + 3.0 * opt + 2.0 * trans)
        t_mem = bytes_dev / _HBM_BW
        b_loc2 = (np.maximum(1.0, B / dp.astype(np.float64))
                  / mb.astype(np.float64))
        act = b_loc2 * S * d * 2.0
        layers = max(L, 1)
        coll = (2 * layers * mb * 3 * act * 2 * (tp - 1) / max(tp, 1)
                + 4.0 * n_act / tp * 2 * (dp.astype(np.float64) - 1)
                / np.maximum(dp.astype(np.float64), 1))
        t_coll = coll / _ICI_BW
        self.lam = np.maximum(
            np.maximum(np.broadcast_to(t_comp, (P, U)), t_mem), t_coll)
        self.area = est.astype(np.float64) * chips.astype(np.float64)

    def covers(self, ports: int, unrolls: int) -> bool:
        return ports <= self.max_ports and unrolls <= self.max_unrolls

    def lookup(self, unrolls: int, ports: int) -> Synthesis:
        i, j = ports - 1, unrolls - 1
        if not bool(self.div_ok[i, 0]):
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls, feasible=False)
        states = int(self.mb[0, j])
        if not bool(self.fits[i, j]):
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls,
                             states_per_iter=states, feasible=False)
        est = int(self.est[i, j])
        return Synthesis(
            lam=float(self.lam[i, j]), area=float(self.area[i, j]),
            ports=ports, unrolls=unrolls, states_per_iter=states,
            feasible=True,
            detail={"chips": float(int(self.chips[i, 0])),
                    "microbatches": float(states),
                    "gb_per_chip": est / 1e9})


# ----------------------------------------------------------------------
# the Oracle-protocol adapter
# ----------------------------------------------------------------------
class BatchPricer(OracleBatchMixin):
    """Whole-grid pricing adapter around an analytical tool.

    Drop-in for the wrapped tool everywhere a ``SynthesisTool`` or
    batched ``Oracle`` is accepted: ``synthesize`` answers from the
    memoized grid (building it on first touch, growing it by doubling
    when a request lands outside the current extent), and every other
    attribute (``cdfg_facts``, ``components``, ``plm_requirement``,
    ``grid_inputs``, ...) delegates to the tool.  Use
    :meth:`BatchPricer.wrap` to wrap opportunistically — non-analytical
    tools pass through unchanged.
    """

    #: grids at least this large are built on first touch, so the
    #: common knob spaces (wami: 8 ports x 16 unrolls) need one build
    _MIN_PORTS_HLS, _MIN_UNROLLS_HLS = 8, 16
    _MIN_PORTS_XLA, _MIN_UNROLLS_XLA = 4, 8

    def __init__(self, tool: Any):
        if isinstance(tool, BatchPricer):
            tool = tool._tool
        if not self._grid_exact(tool):
            raise TypeError(
                f"BatchPricer supports the pristine analytical backends "
                f"(HLSTool, XLATool); got {type(tool).__name__}. Use "
                f"BatchPricer.wrap() to pass other tools through.")
        self._mode = "hls" if isinstance(tool, HLSTool) else "xla"
        self._tool = tool
        self._grids: Dict[Tuple[str, int], Any] = {}
        self._lock = threading.Lock()
        # observability counters (read by tests and the pricing bench)
        self.grid_builds = 0
        self.grid_points_priced = 0
        self.lookups = 0
        self.fallbacks = 0

    @staticmethod
    def _grid_exact(tool: Any) -> bool:
        """True when the grid program provably mirrors ``tool``: an
        analytical backend whose ``synthesize`` is the pristine base
        implementation.  Subclasses that override ``synthesize`` (fault
        injection, gating, counting wrappers in tests) carry semantics
        the grid cannot reproduce and must price scalar."""
        for base in (HLSTool, XLATool):
            if isinstance(tool, base):
                return type(tool).synthesize is base.synthesize
        return False

    @classmethod
    def wrap(cls, tool: Any) -> Any:
        """Wrap ``tool`` when its pricing model has a grid program;
        return it unchanged otherwise (measured backends price by
        executing kernels, subclassed analytical tools carry override
        semantics — nothing to vectorize in either case)."""
        if isinstance(tool, cls):
            return tool
        if cls._grid_exact(tool):
            return cls(tool)
        return tool

    @property
    def tool(self) -> Any:
        """The wrapped scalar tool."""
        return self._tool

    def __getattr__(self, name: str) -> Any:
        # delegate everything the adapter does not override; guard via
        # __dict__ so a half-constructed instance cannot recurse
        try:
            tool = self.__dict__["_tool"]
        except KeyError:
            raise AttributeError(name) from None
        return getattr(tool, name)

    # -- grid management ----------------------------------------------
    def _grid_key(self, component: str, unrolls: Any, ports: Any,
                  kw: Dict[str, Any]) -> Optional[Tuple[str, int]]:
        """The memo key when the request is grid-priceable, else None
        (the request falls through to the scalar tool verbatim)."""
        if not isinstance(unrolls, int) or not isinstance(ports, int):
            return None
        if unrolls < 1 or ports < 1:
            return None
        if component not in self._tool.components:
            return None                       # KeyError stays scalar-raised
        if self._mode == "hls":
            if not set(kw) <= {"tile", "clock_ns"}:
                return None
            tile = kw.get("tile", 0)
            if not isinstance(tile, int):
                return None
            if ports & (ports - 1):
                return None                   # non-pow2 port ladder
            return (component, tile)
        if kw:                                # XLATool has no tile/clock
            return None
        return (component, 0)

    def _grid_for(self, key: Tuple[str, int], ports: int,
                  unrolls: int) -> Any:
        with self._lock:
            grid = self._grids.get(key)
            if grid is not None and grid.covers(ports, unrolls):
                return grid
            component, tile = key
            if self._mode == "hls":
                pmax = max(self._MIN_PORTS_HLS, ports,
                           grid.ports[-1] * 2 if grid else 0)
                umax = max(self._MIN_UNROLLS_HLS, unrolls,
                           grid.max_unrolls * 2 if grid else 0)
                with self.tracer.span("pricing.batch", component=component,
                                      tile=tile, ports=pmax, unrolls=umax,
                                      n=0) as sp:
                    grid = _HLSGrid(self._tool, component, tile, pmax, umax)
                    sp.set("n", grid.size)
            else:
                pmax = max(self._MIN_PORTS_XLA, ports,
                           grid.max_ports * 2 if grid else 0)
                umax = max(self._MIN_UNROLLS_XLA, unrolls,
                           grid.max_unrolls * 2 if grid else 0)
                with self.tracer.span("pricing.batch", component=component,
                                      tile=tile, ports=pmax, unrolls=umax,
                                      n=0) as sp:
                    grid = _XLAGrid(self._tool, component, pmax, umax)
                    sp.set("n", grid.size)
            self._grids[key] = grid
            self.grid_builds += 1
            self.grid_points_priced += grid.size
            return grid

    # -- SynthesisTool protocol ---------------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   **kw: Any) -> Synthesis:
        key = self._grid_key(component, unrolls, ports, kw)
        if key is None:
            self.fallbacks += 1
            return self._tool.synthesize(component, unrolls=unrolls,
                                         ports=ports, max_states=max_states,
                                         **kw)
        grid = self._grid_for(key, ports, unrolls)
        self.lookups += 1
        if self._mode == "hls":
            return grid.lookup(unrolls, ports, max_states,
                               kw.get("clock_ns", 1.0), kw.get("tile", 0))
        return grid.lookup(unrolls, ports)
