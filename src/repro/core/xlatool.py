"""XLATool: the COSMOS SynthesisTool over the TPU cost oracle.

Closes the loop between the paper's engine (characterize -> LP -> map)
and the TPU fleet: a *component* is one stage of a multi-model ML system
(actor/learner fleets, draft/target serving, teacher/student pipelines),
and the knobs map onto the paper's exactly:

    ports   -> the stage's FLEET SHARE: chips = 64 * 2^(ports-1)
               (pow-2, the paper's port rule) — resource replication:
               more chips => lower effective latency, more total HBM
               claimed (the paper's area);
    unrolls -> inverse microbatching: microbatches = 2^(max-unrolls),
               so higher unrolls => fewer weight re-reads => faster but
               more HBM per chip — the Amdahl-shaped lambda(u) the
               mapping function phi assumes.

One "synthesis" prices the configuration with the calibrated analytic
model from ``core.autotune`` (validated against ``memory_analysis()`` in
§Perf): lambda = roofline step time, alpha = total HBM bytes claimed
across the stage's chips.  ``repro.launch.dryrun --auto`` is the single
confirming compile per mapped point — the paper's invocation-frugality
discipline applied to XLA.  The system-level LP then allocates fleet
shares across stages to hit a target pipeline throughput at minimum
total HBM.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

from ..configs.base import ModelConfig, ShapeSpec
from .autotune import HBM_BYTES_PER_CHIP, price_train_step
from .knobs import CDFGFacts, Synthesis
from .oracle import OracleBatchMixin

__all__ = ["XLATool", "BASE_CHIPS", "MAX_UNROLL"]

BASE_CHIPS = 64          # ports=1 fleet share
MAX_UNROLL = 6           # unrolls=6 -> microbatches=1
_PEAK = 197e12
_HBM_BW = 819e9
_ICI_BW = 50e9


class XLATool(OracleBatchMixin):
    """SynthesisTool whose components are (ModelConfig, ShapeSpec) stages.

    Adapts directly to the batched ``Oracle`` protocol via
    :class:`~repro.core.oracle.OracleBatchMixin` — pricing is pure, so
    independent fleet-share/microbatch points fan out concurrently.
    """

    def __init__(self, components: Dict[str, tuple], *, tp: int = 16,
                 hbm_budget: int = HBM_BYTES_PER_CHIP):
        self.components = dict(components)
        self.tp = tp
        self.hbm_budget = hbm_budget

    def _chips(self, ports: int) -> int:
        return BASE_CHIPS * (1 << max(0, ports - 1))

    def _microbatches(self, unrolls: int) -> int:
        return 1 << max(0, MAX_UNROLL - unrolls)

    def mesh_for(self, ports: int) -> "tuple[int, Dict[str, int]]":
        """``(chips, mesh_shape)`` for a fleet share — the knob-to-mesh
        rule in one place, shared by ``synthesize`` and the whole-grid
        pricer (:mod:`repro.core.pricing`)."""
        chips = self._chips(ports)
        return chips, {"data": max(1, chips // self.tp), "model": self.tp}

    def _lambda(self, cfg: ModelConfig, shape: ShapeSpec, chips: int,
                mesh: Dict[str, int], microbatches: int, plan) -> float:
        """Roofline step time (s) for this stage at this fleet share."""
        tp, dp = mesh["model"], mesh["data"]
        tokens = shape.global_batch * shape.seq_len
        n_act = cfg.active_param_count()
        flops_dev = 8.0 * n_act * tokens / chips      # 6ND + remat re-fwd
        t_comp = flops_dev / _PEAK
        w_dev = 2.0 * n_act / tp
        bytes_dev = (3.0 * w_dev * microbatches       # weight re-reads
                     + 4.0 * plan.breakdown["residuals"]
                     + 3.0 * plan.breakdown["opt"]
                     + 2.0 * plan.breakdown["transient"])
        t_mem = bytes_dev / _HBM_BW
        b_loc = max(1.0, shape.global_batch / dp) / microbatches
        act = b_loc * shape.seq_len * cfg.d_model * 2.0
        layers = max(cfg.n_layers, 1)
        coll = (2 * layers * microbatches * 3 * act * 2 * (tp - 1) / max(tp, 1)
                + 4.0 * n_act / tp * 2 * (dp - 1) / max(dp, 1))
        t_coll = coll / _ICI_BW
        return max(t_comp, t_mem, t_coll)

    # ------------------------------------------------------------------
    # SynthesisTool protocol
    # ------------------------------------------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None) -> Synthesis:
        cfg, shape = self.components[component]
        chips, mesh = self.mesh_for(ports)
        microbatches = self._microbatches(unrolls)
        if shape.global_batch % mesh["data"] != 0 and \
                mesh["data"] % shape.global_batch != 0:
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls, feasible=False)
        plan = price_train_step(cfg, shape, mesh, microbatches=microbatches,
                                remat="full")
        lam = self._lambda(cfg, shape, chips, mesh, microbatches, plan)
        area = float(plan.est_bytes) * chips          # total HBM claimed
        states = microbatches
        # lambda-constraint analogue: a configuration whose per-chip HBM
        # exceeds the physical budget fails synthesis (cannot be built),
        # exactly like a schedule that does not fit max_states.
        feasible = plan.est_bytes <= self.hbm_budget
        if not feasible:
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls,
                             states_per_iter=states, feasible=False)
        return Synthesis(lam=lam, area=area, ports=ports, unrolls=unrolls,
                         states_per_iter=states, feasible=True,
                         detail={"chips": float(chips),
                                 "microbatches": float(microbatches),
                                 "gb_per_chip": plan.est_bytes / 1e9})

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        cfg, shape = self.components[component]
        return CDFGFacts(gamma_r=1, gamma_w=1,
                         eta=max(1, synth.states_per_iter),
                         trip=shape.global_batch, has_plm_access=False)
