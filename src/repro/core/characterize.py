"""Component characterization — Algorithm 1 of the paper (Section 5).

For each port count (powers of two up to ``max_ports``) the algorithm
synthesizes the two corners of a design-space region:

  lower-right (lam_max, alpha_min): unrolls = ports (line 3) — every PLM
      port is exploited, the point is not redundant;
  upper-left (lam_min, alpha_max): the largest unroll count, walking down
      from ``max_unrolls``, whose synthesis satisfies the
      lambda-constraint h_ports(unrolls) of Eq. (1) (lines 4-7).

The PLM for the region's port count is generated and its area added to
both corners (lines 8-10 — our HLSTool folds this in, see hlsim.py).

Eq. (1)'s gamma_r / gamma_w / eta are extracted from the CDFG of the
lower-right synthesis, exactly as in the paper.  For loops without PLM
accesses Eq. (1) is inapplicable (Section 5), and the optional
neighbourhood search is used for the upper-left corner instead.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .knobs import CDFGFacts, KnobSpace, Region, Synthesis
from .oracle import OracleLedger
from .pareto import DesignPoint, pareto_front_min_min, span

__all__ = ["CharacterizationResult", "characterize_component", "spans"]


@dataclass
class CharacterizationResult:
    component: str
    regions: List[Region]
    points: List[DesignPoint]           # every feasible synthesized point
    invocations: int
    failed: int

    @property
    def lam_span(self) -> float:
        return span([p.perf for p in self.points])

    @property
    def area_span(self) -> float:
        return span([p.cost for p in self.points])


def _point(component: str, s: Synthesis) -> DesignPoint:
    knobs = [("ports", s.ports), ("unrolls", s.unrolls)]
    if s.tile:
        # the third knob axis; only labelled when the space declared it,
        # so two-knob characterizations stay byte-identical to the seed
        knobs.append(("tile", s.tile))
    return DesignPoint(perf=s.lam, cost=s.area, knobs=tuple(knobs),
                       meta=(("states", float(s.states_per_iter)),))


def characterize_component(tool: OracleLedger, component: str,
                           space: KnobSpace, *,
                           neighbourhood: int = 2,
                           prune_dominated_regions: bool = True
                           ) -> CharacterizationResult:
    """Run Algorithm 1 for one component.

    ``prune_dominated_regions`` drops regions whose fast corner is no
    faster than an already-found region (Section 7.2: 'multiple ports can
    incur in additional area for no latency gains' — such components
    report fewer regions in Table 1).  The syntheses spent discovering
    this are still counted, as in Fig. 11.
    """
    before = tool.total(component)
    failed_before = tool.failed.get(component, 0)
    regions: List[Region] = []
    points: List[DesignPoint] = []

    for tile in space.tiles():
        # the no-latency-gain pruning is an argument about one port
        # ladder (Section 7.2); it resets per tile — regions at a
        # smaller tile are cheaper-but-slower and stay Pareto-relevant
        # even when a larger tile is faster everywhere, and the kept
        # set must not depend on tile_sizes ordering
        best_lam_min = float("inf")
        for ports in space.ports():
            # ---- lower-right corner: unrolls = ports (line 3) ---------
            lr = tool.synthesize(component, unrolls=max(1, ports),
                                 ports=ports, tile=tile)
            if not lr.feasible:
                continue
            facts = tool.cdfg_facts(component, lr)
            lam_max, area_min = lr.lam, lr.area
            mu_min = max(1, ports)

            # ---- upper-left corner (lines 4-7) -------------------------
            ul: Optional[Synthesis] = None
            mu_max = mu_min
            if facts.has_plm_access:
                for unrolls in range(space.max_unrolls, max(1, ports), -1):
                    cap = facts.h(unrolls, ports)   # Eq. (1) upper bound
                    cand = tool.synthesize(component, unrolls=unrolls,
                                           ports=ports, max_states=cap,
                                           tile=tile)
                    if cand.feasible:
                        ul, mu_max = cand, unrolls
                        break
            else:
                # Optional neighbourhood search (Section 5, last
                # paragraph): synthesize around max_unrolls and keep a
                # local Pareto point.
                cands: List[Synthesis] = []
                lo = max(max(1, ports) + 1, space.max_unrolls - neighbourhood)
                for unrolls in range(space.max_unrolls, lo - 1, -1):
                    cand = tool.synthesize(component, unrolls=unrolls,
                                           ports=ports, tile=tile)
                    if cand.feasible:
                        cands.append(cand)
                if cands:
                    ul = min(cands, key=lambda s: (s.lam, s.area))
                    mu_max = ul.unrolls

            if ul is None:
                ul, mu_max = lr, mu_min  # degenerate single-point region

            region = Region(ports=ports,
                            lam_max=lam_max, area_min=area_min,
                            lam_min=ul.lam, area_max=ul.area,
                            mu_min=mu_min, mu_max=mu_max, facts=facts,
                            tile=tile)

            improves = region.lam_min < best_lam_min * (1.0 - 1e-9)
            if improves or not prune_dominated_regions or not regions:
                regions.append(region)
                best_lam_min = min(best_lam_min, region.lam_min)
                points.append(_point(component, lr))
                if ul is not lr:
                    points.append(_point(component, ul))

    invocations = tool.total(component) - before
    # per-run delta, like `invocations`: a pre-warmed ledger (restored
    # cache, repeated characterization) must not double-count failures
    failed = tool.failed.get(component, 0) - failed_before
    return CharacterizationResult(component=component, regions=regions,
                                  points=points, invocations=invocations,
                                  failed=failed)


def spans(results: Dict[str, CharacterizationResult]) -> Dict[str, Dict[str, float]]:
    """Table 1 rows: per-component region count and lambda/alpha spans."""
    out: Dict[str, Dict[str, float]] = {}
    for name, res in results.items():
        out[name] = {
            "regions": float(len(res.regions)),
            "lam_span": res.lam_span,
            "area_span": res.area_span,
            "invocations": float(res.invocations),
        }
    return out
