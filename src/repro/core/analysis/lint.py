"""Repo-wide static lint: registry, kernel specs, knob spaces.

``python -m repro.core.analysis.lint`` checks every registered app
*without compiling or timing a single kernel* — everything here is
derivable from the registry records, the knob-space declarations, the
kernel specs' closed-form cost models, and the committed measurement
JSON.  Each finding carries a stable rule ID (the table lives in
docs/analysis.md):

========  ==============================================================
REG001    app factory (tmg / knob_spaces / analytical) raises
REG002    ``parity_cases`` unresolvable or malformed
REG003    declared recording missing on disk
REG004    measurement JSON invalid (version / key / value schema)
REG005    tile capability metadata inconsistent (default/native tiles)
REG006    TMG transition without a knob space (and not fixed)
SPEC001   kernel spec names a component the TMG does not have
SPEC002   no divisible (ports, unrolls) point in the knob space
SPEC003   no knob point fits the double-buffered VMEM budget
SPEC004   static cost model broken (non-positive vmem/grid numbers)
KNOB001   empty knob axis (no power-of-two port in [min, max])
KNOB002   duplicate values on an axis (tile axis walked twice)
KNOB003   non-positive tile size
OBS001    an ``evaluate_batch`` implementation does not report per-point
          outcomes to the tracer (no ``tracer``-rooted ``.span`` call
          anywhere in the class — see docs/observability.md)
SOC001    a committed ``*.composition.json`` artifact lacks budget or
          traffic-mix provenance (the independent re-checker
          ``python -m repro.core.soc.verify`` needs both — docs/soc.md)
========  ==============================================================

Exit status: 0 when every check passes, 1 otherwise (one line per
finding).  The CI ``static-analysis`` job runs this over the checked-in
registry on every push.
"""

from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

__all__ = ["LintFinding", "lint_app", "lint_all", "main"]


@dataclass(frozen=True)
class LintFinding:
    """One violated lint rule."""

    rule: str
    app: str
    subject: str          # component / tile / axis the finding is about
    detail: str

    def __str__(self) -> str:
        return f"{self.rule} {self.app}/{self.subject}: {self.detail}"


def _call(factory: Callable[..., Any], what: str, app_name: str,
          findings: List[LintFinding], rule: str = "REG001") -> Any:
    try:
        return factory()
    except Exception as e:            # noqa: BLE001 — lint reports, never dies
        findings.append(LintFinding(rule, app_name, what,
                                    f"factory raised {type(e).__name__}: {e}"))
        return None


# ----------------------------------------------------------------------
# registry consistency
# ----------------------------------------------------------------------
def _lint_registry(app, findings: List[LintFinding]) -> None:
    tmg = _call(app.tmg, "tmg", app.name, findings)
    spaces = _call(app.knob_spaces, "knob_spaces", app.name, findings)
    _call(app.analytical, "analytical", app.name, findings)

    if tmg is not None and spaces is not None:
        names = {t.name for t in tmg.transitions}
        for n in sorted((names - set(app.fixed)) - set(spaces)):
            findings.append(LintFinding(
                "REG006", app.name, n,
                "TMG transition has no knob space and no fixed latency"))
        for n in sorted(set(app.fixed) - names):
            findings.append(LintFinding(
                "REG006", app.name, n,
                "fixed latency for a transition the TMG does not have"))

    if app.parity_cases is not None:
        try:
            cases = app.parity_cases()
        except Exception as e:        # noqa: BLE001
            findings.append(LintFinding(
                "REG002", app.name, "parity_cases",
                f"factory raised {type(e).__name__}: {e}"))
            cases = None
        if cases is not None:
            if not cases:
                findings.append(LintFinding("REG002", app.name,
                                            "parity_cases", "empty case list"))
            for i, case in enumerate(cases or ()):
                ok = (isinstance(case, (tuple, list)) and len(case) == 4
                      and isinstance(case[0], str) and callable(case[1])
                      and callable(case[2])
                      and isinstance(case[3], (tuple, list)))
                if not ok:
                    findings.append(LintFinding(
                        "REG002", app.name, f"parity_cases[{i}]",
                        "expected (name, fn, oracle_fn, args) with "
                        "callable fn/oracle"))

    # recordings: declared tiles resolve to valid JSON stores on disk
    if app.measurement_path is not None:
        for tile in app.recorded_tiles:
            path = app.measurement_path(tile)
            if not os.path.exists(path):
                findings.append(LintFinding(
                    "REG003", app.name, f"tile={tile}",
                    f"declared recording missing: {path}"))
                continue
            _lint_measurement_json(app.name, tile, path, findings)
        for tile in app.default_tiles:
            if tile not in app.recorded_tiles:
                findings.append(LintFinding(
                    "REG005", app.name, f"tile={tile}",
                    "default tile is not a declared recorded tile"))
        if app.kernel_specs is not None and app.recorded_tiles and \
                app.native_tile not in app.recorded_tiles:
            findings.append(LintFinding(
                "REG005", app.name, f"tile={app.native_tile}",
                "native tile has no declared recording"))


def _lint_measurement_json(app_name: str, tile: int, path: str,
                           findings: List[LintFinding]) -> None:
    """REG004: the committed store must parse under the documented
    schema — version 1, ``comp:pN:uM`` keys, positive float walls."""
    subject = f"tile={tile}"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        findings.append(LintFinding("REG004", app_name, subject,
                                    f"unreadable JSON {path}: {e}"))
        return
    if doc.get("version") != 1:
        findings.append(LintFinding(
            "REG004", app_name, subject,
            f"unknown store version {doc.get('version')!r} in {path}"))
        return
    entries = doc.get("entries")
    if not isinstance(entries, dict) or not entries:
        findings.append(LintFinding(
            "REG004", app_name, subject,
            f"empty or non-dict 'entries' in {path}"))
        return
    for key, wall in entries.items():
        parts = key.rsplit(":", 2)
        bad_key = (len(parts) != 3 or not parts[1].startswith("p")
                   or not parts[2].startswith("u")
                   or not parts[1][1:].isdigit()
                   or not parts[2][1:].isdigit())
        if bad_key:
            findings.append(LintFinding(
                "REG004", app_name, subject,
                f"malformed entry key {key!r} (want 'comp:pN:uM')"))
        elif not isinstance(wall, (int, float)) or not wall > 0:
            findings.append(LintFinding(
                "REG004", app_name, subject,
                f"non-positive wall {wall!r} for entry {key!r}"))


# ----------------------------------------------------------------------
# kernel-spec static feasibility
# ----------------------------------------------------------------------
def _lint_kernel_specs(app, findings: List[LintFinding]) -> None:
    if app.kernel_specs is None:
        return
    from ..pallas_oracle import _VMEM_BUDGET
    try:
        specs = app.kernel_specs(app.native_tile)
    except Exception as e:            # noqa: BLE001
        findings.append(LintFinding(
            "SPEC001", app.name, "kernel_specs",
            f"factory raised {type(e).__name__}: {e}"))
        return
    tmg = _call(app.tmg, "tmg", app.name, findings)
    spaces = _call(app.knob_spaces, "knob_spaces", app.name, findings)
    if tmg is None or spaces is None:
        return
    names = {t.name for t in tmg.transitions}
    for comp in sorted(set(specs) - names):
        findings.append(LintFinding(
            "SPEC001", app.name, comp,
            "kernel spec for a component the TMG does not have"))
    for comp in sorted(set(specs) & names):
        spec, space = specs[comp], spaces.get(comp)
        if space is None:
            continue                  # REG006 already reported it
        feasible = False
        fits_vmem = False
        for ports in space.ports():
            for unrolls in range(1, space.max_unrolls + 1):
                if not spec.divisible(ports, unrolls):
                    continue
                feasible = True
                H, W = spec.shape
                try:
                    step = spec.vmem_bytes(H, W, ports=ports,
                                           unrolls=unrolls)
                    grid = spec.grid_steps(H, W, ports=ports,
                                           unrolls=unrolls)
                except Exception as e:    # noqa: BLE001
                    findings.append(LintFinding(
                        "SPEC004", app.name, comp,
                        f"cost model raised at (p={ports}, u={unrolls}): "
                        f"{type(e).__name__}: {e}"))
                    continue
                if step <= 0 or grid <= 0:
                    findings.append(LintFinding(
                        "SPEC004", app.name, comp,
                        f"non-positive cost model output at "
                        f"(p={ports}, u={unrolls}): vmem={step}, "
                        f"grid={grid}"))
                    continue
                if 2 * step <= _VMEM_BUDGET:
                    fits_vmem = True
        if not feasible:
            findings.append(LintFinding(
                "SPEC002", app.name, comp,
                f"no (ports, unrolls) point in the knob space divides "
                f"shape {spec.shape}"))
        elif not fits_vmem:
            findings.append(LintFinding(
                "SPEC003", app.name, comp,
                f"no divisible knob point fits the double-buffered "
                f"VMEM budget ({_VMEM_BUDGET} bytes)"))


# ----------------------------------------------------------------------
# knob-space sanity
# ----------------------------------------------------------------------
def _lint_knob_spaces(app, findings: List[LintFinding]) -> None:
    spaces = _call(app.knob_spaces, "knob_spaces", app.name, findings)
    if spaces is None:
        return
    for comp in sorted(spaces):
        space = spaces[comp]
        if not space.ports():
            findings.append(LintFinding(
                "KNOB001", app.name, comp,
                f"no power-of-two port count in "
                f"[{space.min_ports}, {space.max_ports}]"))
        tiles = tuple(space.tile_sizes)
        if len(set(tiles)) != len(tiles):
            findings.append(LintFinding(
                "KNOB002", app.name, comp,
                f"duplicate tile sizes {list(tiles)} — the axis would "
                f"be characterized twice"))
        for t in tiles:
            if t <= 0:
                findings.append(LintFinding(
                    "KNOB003", app.name, comp,
                    f"non-positive tile size {t}"))


# ----------------------------------------------------------------------
# observability: oracles must be traceable
# ----------------------------------------------------------------------
#: the modules whose classes implement ``Oracle.evaluate_batch`` — every
#: such class must thread its points through the tracer so the per-point
#: outcome partition (docs/observability.md) stays reconstructible
_OBS_ORACLE_MODULES = ("repro.core.oracle", "repro.core.autotune")


def _mentions_tracer(node) -> bool:
    import ast
    if isinstance(node, ast.Attribute):
        return "tracer" in node.attr.lower() or _mentions_tracer(node.value)
    if isinstance(node, ast.Name):
        return "tracer" in node.id.lower()
    if isinstance(node, ast.Call):
        return _mentions_tracer(node.func)
    return False


def _lint_observability(findings: List[LintFinding]) -> None:
    """OBS001: structurally verify that every class defining
    ``evaluate_batch`` in the oracle modules reports its work to the
    tracer — some ``<tracer>.span(...)`` (or ``.instant``/``.begin``)
    call must appear in the class body, where ``<tracer>`` is an
    expression rooted in a name containing "tracer" (``self.tracer``,
    ``self._tracer()``, a ``tracer`` local)."""
    import ast
    import importlib
    for modname in _OBS_ORACLE_MODULES:
        try:
            mod = importlib.import_module(modname)
            with open(mod.__file__) as f:
                tree = ast.parse(f.read(), filename=mod.__file__)
        except Exception as e:        # noqa: BLE001 — lint reports, never dies
            findings.append(LintFinding(
                "OBS001", "repo", modname,
                f"could not parse module: {type(e).__name__}: {e}"))
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            # Protocol classes declare the signature, they don't do work
            protocol = any(isinstance(b, ast.Name) and b.id == "Protocol"
                           for b in node.bases)
            if protocol:
                continue
            defines = any(isinstance(b, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                          and b.name == "evaluate_batch"
                          for b in node.body)
            if not defines:
                continue
            traced = any(
                isinstance(n, ast.Attribute)
                and n.attr in ("span", "instant", "begin")
                and _mentions_tracer(n.value)
                for n in ast.walk(node))
            if not traced:
                findings.append(LintFinding(
                    "OBS001", "repo", f"{modname}.{node.name}",
                    "evaluate_batch implementation never reports to the "
                    "tracer (expected a tracer-rooted .span/.instant "
                    "call somewhere in the class)"))


# ----------------------------------------------------------------------
# SoC composition artifacts: provenance must be embedded
# ----------------------------------------------------------------------
#: the keys a composition's budget / mix provenance blocks must carry
#: for ``python -m repro.core.soc.verify`` to re-prove it standalone
_SOC_BUDGET_KEYS = ("name", "area_mm2", "power_w", "bw_gbps", "tech_nm")
_SOC_MIX_KEYS = ("name", "demands")


def _lint_soc_artifacts(findings: List[LintFinding],
                        root: str = "artifacts/bench") -> None:
    """SOC001: every committed ``*.composition.json`` must embed the
    budget and traffic-mix provenance it was composed under — the
    artifact is the cross-environment source of truth, so a composition
    whose envelopes or demands live only in the process that wrote it
    cannot be independently re-proved."""
    import glob
    pattern = os.path.join(root, "**", "*.composition.json")
    for path in sorted(glob.glob(pattern, recursive=True)):
        subject = os.path.relpath(path, root)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            findings.append(LintFinding(
                "SOC001", "repo", subject,
                f"unreadable composition JSON: {e}"))
            continue
        budget = doc.get("budget")
        if not isinstance(budget, dict):
            findings.append(LintFinding(
                "SOC001", "repo", subject,
                "no 'budget' provenance block (dict expected)"))
        else:
            for key in _SOC_BUDGET_KEYS:
                if key not in budget:
                    findings.append(LintFinding(
                        "SOC001", "repo", subject,
                        f"budget provenance lacks {key!r}"))
        mix = doc.get("mix")
        if not isinstance(mix, dict):
            findings.append(LintFinding(
                "SOC001", "repo", subject,
                "no 'mix' provenance block (dict expected)"))
        else:
            for key in _SOC_MIX_KEYS:
                if key not in mix:
                    findings.append(LintFinding(
                        "SOC001", "repo", subject,
                        f"mix provenance lacks {key!r}"))
            if not mix.get("demands"):
                findings.append(LintFinding(
                    "SOC001", "repo", subject,
                    "mix provenance has no demands — a composition of "
                    "nothing proves nothing"))


# ----------------------------------------------------------------------
# driver
# ----------------------------------------------------------------------
def lint_app(app) -> List[LintFinding]:
    """All findings for one registered app (empty = clean)."""
    findings: List[LintFinding] = []
    _lint_registry(app, findings)
    _lint_kernel_specs(app, findings)
    _lint_knob_spaces(app, findings)
    return findings


def lint_all(apps=None) -> List[LintFinding]:
    """Lint ``apps`` (default: every registered app), deterministically
    ordered by (app, rule, subject)."""
    if apps is None:
        from ..registry import list_apps
        apps = list_apps()
    findings: List[LintFinding] = []
    for app in apps:
        findings.extend(lint_app(app))
    _lint_observability(findings)     # repo-level, app-independent
    _lint_soc_artifacts(findings)     # repo-level, artifact provenance
    return sorted(findings, key=lambda f: (f.app, f.rule, f.subject,
                                           f.detail))


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.analysis.lint",
        description="static lint over the registry, kernel specs, and "
                    "knob spaces (no kernel is compiled)")
    ap.add_argument("--app", action="append", default=None,
                    help="lint only this app (repeatable; default: all)")
    args = ap.parse_args(argv)
    from ..registry import get_app, list_apps
    apps = ([get_app(a) for a in args.app] if args.app else list_apps())
    findings = lint_all(apps)
    for f in findings:
        print(f, file=sys.stderr)
    checked = ", ".join(a.name for a in apps)
    if findings:
        print(f"lint: {len(findings)} finding(s) across [{checked}]",
              file=sys.stderr)
        return 1
    print(f"lint ok: [{checked}] — registry, kernel specs, and knob "
          f"spaces are statically clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
