"""Busy-interval analysis of LP schedules → non-concurrency certificates.

The Eq. (2) LP (:mod:`repro.core.planning`) solves for transition
initiation times sigma and firing delays tau at a target throughput
theta.  Under the resulting periodic schedule, firing k of transition i
occupies the busy interval

    [sigma_i + k/theta,  sigma_i + tau_i + k/theta)

so on the circle of circumference ``period = 1/theta`` transition i is
busy exactly on ``[sigma_i mod period, sigma_i + tau_i mod period)``.
Every TMG here carries a one-token self place per transition, which
forces ``tau_i <= period`` — a busy interval wraps the circle at most
once, and two transitions execute concurrently at some instant iff
their circular intervals overlap.

Pairs whose intervals are disjoint (with a conservative tolerance:
touching counts as overlap) are certified non-concurrent *under that
schedule*.  These are strictly weaker guarantees than the structural
one-token-cycle certificates of :mod:`repro.core.plm.compat` — they
hold only while the system runs the tagged schedule — and strictly
richer: on WAMI they certify dozens of pairs beyond the six-component
LK clique (see tests/test_analysis.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Optional, Tuple

from ..planning import Schedule
from ..plm.compat import CompatSource, exclusive_pairs
from ..tmg import TMG

__all__ = [
    "BusyInterval",
    "ScheduleCertificate",
    "busy_intervals",
    "intervals_overlap",
    "schedule_exclusive_pairs",
    "compat_source_for",
]

Pair = FrozenSet[str]

# relative tolerance (fraction of the period) below which two intervals
# are treated as touching — i.e. NOT certified disjoint.  Conservative:
# widening it can only drop certificates, never admit a race.
_REL_TOL = 1e-9


@dataclass(frozen=True)
class BusyInterval:
    """One transition's busy window on the schedule circle.

    ``start`` is normalized into ``[0, period)``; ``length`` is the
    planned firing delay tau (``length <= period`` for any schedule of a
    TMG with one-token self places).
    """

    name: str
    start: float
    length: float

    @property
    def end(self) -> float:
        return self.start + self.length


def busy_intervals(schedule: Schedule) -> Dict[str, BusyInterval]:
    """Every transition's busy interval, starts normalized mod period."""
    period = schedule.period
    out: Dict[str, BusyInterval] = {}
    for name, sigma in schedule.sigma.items():
        out[name] = BusyInterval(name=name, start=sigma % period,
                                 length=float(schedule.tau[name]))
    return out


def intervals_overlap(a: BusyInterval, b: BusyInterval, period: float,
                      tol: Optional[float] = None) -> bool:
    """Do the two circular intervals intersect (within tolerance)?

    Checked by unrolling b one period to each side: with both lengths
    <= period, an intersection on the circle implies a linear
    intersection at one of the three shifts.  ``tol`` > 0 makes the
    test conservative — intervals closer than ``tol`` count as
    overlapping, so a certificate always has real slack behind it.
    """
    if tol is None:
        tol = _REL_TOL * period
    if a.length >= period - tol or b.length >= period - tol:
        return True       # a full-period firing overlaps everything
    for k in (-1.0, 0.0, 1.0):
        if a.start < b.end + k * period + tol and \
                b.start + k * period < a.end + tol:
            return True
    return False


@dataclass(frozen=True)
class ScheduleCertificate:
    """Non-concurrency pairs certified by one LP schedule.

    ``pairs`` holds under the schedule identified by ``tag`` only — the
    planner and the verifier must carry the tag with any sharing
    decision derived from it (a different schedule, or a mapped design
    point run free-running instead of at the planned initiation times,
    voids the certificate).
    """

    tag: str
    theta: float
    pairs: FrozenSet[Pair]
    intervals: Tuple[BusyInterval, ...]

    def certifies(self, u: str, v: str) -> bool:
        return u != v and frozenset((u, v)) in self.pairs


def schedule_exclusive_pairs(schedule: Schedule,
                             tol: Optional[float] = None
                             ) -> ScheduleCertificate:
    """All unordered pairs whose busy intervals are disjoint mod period.

    Deterministic: a pure function of (sigma, tau, theta).  O(n^2) over
    the transitions — negligible next to one oracle invocation.
    """
    period = schedule.period
    ivs = busy_intervals(schedule)
    names = sorted(ivs)
    pairs = set()
    for i, u in enumerate(names):
        for v in names[i + 1:]:
            if not intervals_overlap(ivs[u], ivs[v], period, tol):
                pairs.add(frozenset((u, v)))
    return ScheduleCertificate(tag=schedule.tag(), theta=schedule.theta,
                               pairs=frozenset(pairs),
                               intervals=tuple(ivs[n] for n in names))


def compat_source_for(tmg: TMG, schedule: Optional[Schedule] = None
                      ) -> CompatSource:
    """The two-tier compatibility source for a TMG and (optionally) one
    of its LP schedules: structural one-token-cycle pairs plus the
    schedule-conditional busy-interval pairs, tagged."""
    base = CompatSource(structural=exclusive_pairs(tmg))
    if schedule is None:
        return base
    cert = schedule_exclusive_pairs(schedule)
    names = {t.name for t in tmg.transitions}
    missing = names - set(schedule.sigma)
    if missing:
        raise ValueError(f"schedule covers no initiation time for "
                         f"{sorted(missing)}")
    return base.with_conditional(cert.pairs, cert.tag)
