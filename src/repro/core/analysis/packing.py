"""Exhaustive optimal shared-bank packing (small graphs only).

The greedy planner (:mod:`repro.core.plm.planner`) is a heuristic; this
module computes the *certified optimum* by enumerating every set
partition of the requirements and pricing each feasible one with the
very same cost model (``shared_area`` for multi-member blocks, the
exact private PLM price for singletons).  Bell(8) = 4140 partitions, so
this is cheap up to the ``max_components`` guard and exponential past
it — it exists as an oracle for tests (the greedy optimality gate in
``tests/test_analysis.py``), not as a production planner.

A partition block is feasible exactly under the planner's own rules:
one unit per block, every pair certified non-concurrent by the supplied
:class:`~repro.core.plm.compat.CompatSource`, and no unsplittable
(capacity-0) requirement in a multi-member block.
"""

from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Tuple

from ..memgen import MemGen
from ..plm.compat import CompatSource
from ..plm.spec import MemoryGroup, MemoryPlan, PLMRequirement
from ..plm.planner import shared_area

__all__ = ["optimal_plan", "partitions"]

_MAX_COMPONENTS = 8


def partitions(items: Sequence) -> Iterator[List[List]]:
    """All set partitions of ``items`` (each element joins an existing
    block or opens a new one — canonical order, no duplicates)."""
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in partitions(rest):
        for i in range(len(part)):
            yield part[:i] + [[first] + part[i]] + part[i + 1:]
        yield [[first]] + part


def _block_feasible(block: Sequence[PLMRequirement],
                    source: CompatSource) -> bool:
    if len(block) == 1:
        return True
    if len({r.unit for r in block}) > 1:
        return False
    if any(r.capacity <= 0 for r in block):
        return False
    for i, u in enumerate(block):
        for v in block[i + 1:]:
            if not source.may_share(u.component, v.component):
                return False
    return True


def _price(block: Sequence[PLMRequirement], memgen: MemGen) -> float:
    # mirror the planner: singletons keep their exact private price
    if len(block) == 1:
        return block[0].area_plm
    return shared_area(sorted(block, key=lambda r: r.component),
                       memgen)[0]


def optimal_plan(requirements: Sequence[PLMRequirement],
                 source: CompatSource, *,
                 memgen: Optional[MemGen] = None,
                 max_components: int = _MAX_COMPONENTS) -> MemoryPlan:
    """The cheapest feasible plan, by exhaustive partition search.

    Deterministic: ties between equal-cost partitions resolve to the
    one with more groups (least sharing), then lexicographically by the
    sorted group members — so the structural optimum is stable across
    runs and the gate test can pin exact numbers.
    """
    if len(requirements) > max_components:
        raise ValueError(
            f"exhaustive packing is exponential: {len(requirements)} "
            f"components > max_components={max_components}")
    memgen = memgen or MemGen()
    reqs = sorted(requirements, key=lambda r: r.component)

    best: Optional[Tuple[float, int, Tuple[Tuple[str, ...], ...],
                         List[List[PLMRequirement]]]] = None
    for part in partitions(reqs):
        if not all(_block_feasible(b, source) for b in part):
            continue
        cost = sum(_price(b, memgen) for b in part)
        key = (cost, -len(part),
               tuple(sorted(tuple(sorted(r.component for r in b))
                            for b in part)))
        if best is None or key < best[:3]:
            best = (key[0], key[1], key[2], part)
    assert best is not None            # singletons are always feasible

    groups: List[MemoryGroup] = []
    logic = 0.0
    for block in sorted(best[3],
                        key=lambda b: sorted(r.component for r in b)):
        block = sorted(block, key=lambda r: r.component)
        area, cap, bits, ports, banks = shared_area(block, memgen)
        private = sum(r.area_plm for r in block)
        if len(block) == 1:
            area, banks = private, 0
        groups.append(MemoryGroup(
            members=tuple(r.component for r in block),
            capacity=cap, word_bits=bits, ports=ports, area=area,
            area_private=private, unit=block[0].unit, banks=banks,
            requirements=tuple(block)))
        logic += sum(r.area_logic for r in block)
    return MemoryPlan(groups=tuple(groups),
                      area_memory=sum(g.area for g in groups),
                      area_logic=logic, compat_tag=source.tag)
