"""Static analysis over schedules, PLM plans, and the registry.

Three tools, all derivation-only (nothing here compiles or times a
kernel):

* :mod:`.intervals` — schedule-conditional non-concurrency certificates
  from the LP's solved sigma/tau (busy-interval analysis mod the
  period), feeding the planner's two-tier
  :class:`~repro.core.plm.compat.CompatSource`;
* :mod:`.verify` — an independent race detector that re-proves every
  shared-bank group of an emitted :class:`~repro.core.plm.spec.MemoryPlan`
  pairwise non-concurrent, capacity-feasible, and dominance-guarded
  (``python -m repro.core.analysis.verify`` runs it over committed
  benchmark artifacts);
* :mod:`.lint` — the repo-wide static lint driver
  (``python -m repro.core.analysis.lint``): registry consistency,
  kernel-spec static feasibility, knob-space sanity, with stable rule
  IDs (docs/analysis.md).

:mod:`.packing` is the exhaustive-optimal shared-bank packer used to
gate the greedy planner on small graphs.

Submodules are imported lazily: :mod:`repro.core.plm.planner` pulls
:mod:`.intervals` at plan time, and an eager ``verify`` import here
would close an import cycle back into the planner.
"""

from __future__ import annotations

_SUBMODULES = ("intervals", "verify", "lint", "packing")

__all__ = list(_SUBMODULES) + [
    "BusyInterval", "ScheduleCertificate", "schedule_exclusive_pairs",
    "compat_source_for", "Violation", "PlanVerificationError",
    "verify_plan", "optimal_plan",
]

_LAZY = {
    "BusyInterval": "intervals",
    "ScheduleCertificate": "intervals",
    "schedule_exclusive_pairs": "intervals",
    "compat_source_for": "intervals",
    "Violation": "verify",
    "PlanVerificationError": "verify",
    "verify_plan": "verify",
    "optimal_plan": "packing",
}


def __getattr__(name: str):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    mod = _LAZY.get(name)
    if mod is not None:
        return getattr(importlib.import_module(f".{mod}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
