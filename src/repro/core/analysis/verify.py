"""Independent race detector for emitted PLM plans.

The planner (:mod:`repro.core.plm.planner`) *constructs* shared-bank
groups from non-concurrency certificates; this module *re-proves* them
from scratch, trusting nothing but the plan itself, the TMG, and the
schedule the plan conditions on.  Every multi-member group of a
:class:`~repro.core.plm.spec.MemoryPlan` must be

* **race-free** (rule ``V-RACE``): each member pair certified
  non-concurrent — structurally (one-token cycle) or by the schedule's
  busy intervals; a plan whose ``compat_tag`` names a schedule is only
  checked against a schedule with the *same* tag (``V-TAG``);
* **capacity-feasible** (``V-CAP``): the shared envelope covers every
  member requirement (capacity, word width, ports), members share one
  unit, and no unsplittable (capacity-0) requirement was merged;
* **honestly priced** (``V-AREA``): the group's recorded area matches
  an independent re-derivation through ``shared_area`` (multi-member)
  or the private PLM price (singleton);
* **dominance-guarded** (``V-GUARD``): the shared area never exceeds
  the private per-component sum the group replaces.

``python -m repro.core.analysis.verify [dir|file ...]`` verifies
committed plan artifacts (``*.plans.json``, written by
``benchmarks/fig10_pareto.py`` for every ``share_plm`` cell); with no
arguments it scans ``artifacts/bench/fig10``.  Exit status is the
number of violated plans (0 = everything proved).
"""

from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

from ..memgen import MemGen
from ..planning import Schedule
from ..plm.compat import exclusive_pairs
from ..plm.spec import MemoryPlan, memory_plan_from_json
from ..tmg import TMG
from .intervals import schedule_exclusive_pairs

__all__ = ["Violation", "PlanVerificationError", "verify_plan",
           "assert_plan_sound", "verify_plans_file", "main"]

_REL_TOL = 1e-9


@dataclass(frozen=True)
class Violation:
    """One failed proof obligation of a memory plan."""

    rule: str                     # V-RACE | V-TAG | V-CAP | V-AREA | V-GUARD
    group: Tuple[str, ...]
    detail: str

    def __str__(self) -> str:
        return f"{self.rule} [{'+'.join(self.group)}]: {self.detail}"


class PlanVerificationError(AssertionError):
    """Raised by :func:`assert_plan_sound` — an emitted plan failed
    independent re-verification."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations = tuple(violations)
        super().__init__("memory plan failed verification:\n  " +
                         "\n  ".join(str(v) for v in violations))


def verify_plan(plan: MemoryPlan, tmg: TMG,
                schedule: Optional[Schedule] = None, *,
                memgen: Optional[MemGen] = None) -> List[Violation]:
    """Re-prove ``plan`` sound; returns all violations ([] = proved).

    ``schedule`` supplies the conditional certificate tier.  It is only
    consulted when its tag matches the plan's ``compat_tag`` — a plan
    that conditions on schedule A is *not* proved race-free by the
    disjoint intervals of schedule B.
    """
    memgen = memgen or MemGen()
    out: List[Violation] = []
    structural = exclusive_pairs(tmg)
    known = {t.name for t in tmg.transitions}

    conditional = frozenset()
    if plan.compat_tag is not None:
        if schedule is None:
            out.append(Violation(
                "V-TAG", (),
                f"plan conditions on schedule {plan.compat_tag!r} but no "
                f"schedule was supplied for verification"))
        elif schedule.tag() != plan.compat_tag:
            out.append(Violation(
                "V-TAG", (),
                f"plan conditions on schedule {plan.compat_tag!r}; "
                f"got {schedule.tag()!r}"))
        else:
            conditional = schedule_exclusive_pairs(schedule).pairs
    certified = structural | conditional

    for g in plan.groups:
        members = tuple(g.members)
        unknown = [m for m in members if m not in known]
        if unknown:
            out.append(Violation("V-RACE", members,
                                 f"members not in the TMG: {unknown}"))
            continue
        if len(members) > 1:
            for i, u in enumerate(members):
                for v in members[i + 1:]:
                    if frozenset((u, v)) not in certified:
                        out.append(Violation(
                            "V-RACE", members,
                            f"no non-concurrency certificate for "
                            f"({u}, {v})"))
        # capacity / envelope / unit obligations need the requirements
        reqs = g.requirements
        if reqs:
            names = tuple(sorted(r.component for r in reqs))
            if names != tuple(sorted(members)):
                out.append(Violation(
                    "V-CAP", members,
                    f"requirements cover {names}, group covers "
                    f"{tuple(sorted(members))}"))
            units = {r.unit for r in reqs}
            if len(units) > 1:
                out.append(Violation("V-CAP", members,
                                     f"mixed units in one group: "
                                     f"{sorted(units)}"))
            if len(reqs) > 1:
                for r in reqs:
                    if r.capacity <= 0:
                        out.append(Violation(
                            "V-CAP", members,
                            f"unsplittable requirement {r.component} "
                            f"(capacity 0) was merged"))
                    if r.capacity > g.capacity:
                        out.append(Violation(
                            "V-CAP", members,
                            f"{r.component} needs capacity {r.capacity} "
                            f"> group envelope {g.capacity}"))
                    if r.word_bits > g.word_bits:
                        out.append(Violation(
                            "V-CAP", members,
                            f"{r.component} needs {r.word_bits}-bit words "
                            f"> group width {g.word_bits}"))
                    if r.ports > g.ports:
                        out.append(Violation(
                            "V-CAP", members,
                            f"{r.component} needs {r.ports} ports "
                            f"> group envelope {g.ports}"))
            # area re-derivation: the plan must charge what the shared
            # model (or the private price, for singletons) says
            if len(units) == 1:
                if len(reqs) == 1:
                    expect = reqs[0].area_plm
                else:
                    from ..plm.planner import shared_area
                    expect = shared_area(
                        sorted(reqs, key=lambda r: r.component), memgen)[0]
                if abs(g.area - expect) > _REL_TOL * max(1.0, expect):
                    out.append(Violation(
                        "V-AREA", members,
                        f"recorded area {g.area!r} != re-derived "
                        f"{expect!r}"))
        if g.area > g.area_private + _REL_TOL * max(1.0, g.area_private):
            out.append(Violation(
                "V-GUARD", members,
                f"shared area {g.area!r} exceeds private sum "
                f"{g.area_private!r}"))
    return out


def assert_plan_sound(plan: MemoryPlan, tmg: TMG,
                      schedule: Optional[Schedule] = None, *,
                      memgen: Optional[MemGen] = None) -> None:
    """:func:`verify_plan`, raising on the first unsound plan — the
    session's strict post-pass (``ExplorationSession(verify_plans=True)``)."""
    violations = verify_plan(plan, tmg, schedule, memgen=memgen)
    if violations:
        raise PlanVerificationError(violations)


# ----------------------------------------------------------------------
# committed-artifact verification (CLI)
# ----------------------------------------------------------------------
def verify_plans_file(path: str) -> Tuple[int, List[Violation]]:
    """Verify one committed ``*.plans.json`` artifact.

    Returns (number of plan points checked, all violations).  The file
    names its app; the TMG is rebuilt from the registry, so the proof is
    against the *current* structural model, not the one that emitted
    the plan.
    """
    with open(path) as f:
        doc = json.load(f)
    from ..registry import get_app
    tmg = get_app(doc["app"]).tmg()
    violations: List[Violation] = []
    points = doc.get("points", [])
    for pt in points:
        plan = memory_plan_from_json(pt["plan"])
        sched = pt.get("schedule")
        sched = Schedule.from_json(sched) if sched is not None else None
        for v in verify_plan(plan, tmg, sched):
            violations.append(Violation(
                v.rule, v.group,
                f"(theta={pt.get('theta_planned')}) {v.detail}"))
    return len(points), violations


def _find_plan_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(os.path.join(p, n) for n in sorted(os.listdir(p))
                       if n.endswith(".plans.json"))
        else:
            out.append(p)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.analysis.verify",
        description="re-prove committed PLM plan artifacts race-free")
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join("artifacts", "bench", "fig10")],
                    help="*.plans.json files or directories holding them")
    args = ap.parse_args(argv)
    files = _find_plan_files(args.paths)
    if not files:
        print(f"verify: no *.plans.json under {list(args.paths)}",
              file=sys.stderr)
        return 1
    bad = 0
    for path in files:
        n, violations = verify_plans_file(path)
        if violations:
            bad += 1
            print(f"FAIL {path}: {len(violations)} violation(s) "
                  f"across {n} plan(s)")
            for v in violations:
                print(f"  {v}")
        else:
            print(f"ok   {path}: {n} plan(s) proved race-free, "
                  f"capacity-feasible, dominance-guarded")
    return bad


if __name__ == "__main__":
    raise SystemExit(main())
