"""The App/Backend registry: one entry point for every workload x oracle.

COSMOS is compositional — the same characterize -> plan -> map
methodology applies to *any* accelerator — but until this module each
benchmark hand-wired its own ``if backend == "pallas"`` ladder and each
app grew bespoke session constructors.  The registry replaces both
seams with two small declarative records:

  * an :class:`App` bundles everything an
    :class:`~repro.core.session.ExplorationSession` needs about a
    workload: the TMG factory, the per-component knob spaces, fixed
    (software) latencies, the analytical tool, and — when the app has
    measured kernels — the ``PallasKernelSpec`` factory, its recordings
    on disk, the unit-calibrated fallback, and the PLM planner;
  * a :class:`Backend` bundles an oracle factory plus capability
    metadata: measured vs analytical, which recorded tiles it can
    replay for an app, and the calibration hook that puts an analytical
    model onto the measured axes.

``get_app("wami")`` / ``get_backend("pallas")`` resolve by name (apps
self-register on first use via their package import), and
:func:`build_session` is the single session constructor every benchmark
and example drives:

    session = build_session("wami", "pallas", share_plm=True)
    result = session.run()

Registering a new workload is one :func:`register_app` call — see
docs/backends.md for the how-to and the current apps x backends support
matrix.
"""

from __future__ import annotations

import importlib
import os
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

from .knobs import KnobSpace
from .pallas_oracle import MeasurementSet, PallasKernelSpec, PallasOracle
from .session import DSEQuery, ExplorationSession
from .tmg import TMG

__all__ = [
    "App",
    "Backend",
    "register_app",
    "register_backend",
    "get_app",
    "get_backend",
    "list_apps",
    "list_backends",
    "build_tool",
    "build_session",
    "build_query_session",
]


@dataclass(frozen=True)
class App:
    """One registered workload: everything a session needs, bundled.

    ``tmg``/``knob_spaces``/``analytical`` are zero-config factories
    (``knob_spaces`` must accept a ``tile_sizes=`` keyword when
    ``plm_tile_sizes`` is non-empty).  ``fixed`` maps software
    transitions to their fixed effective latency.  The measured-backend
    fields are optional: an app without ``kernel_specs`` simply does not
    support measured backends (``Backend.supports`` reports it).

    ``recorded_tiles`` lists every tile with a checked-in recording —
    capability metadata; ``default_tiles`` is the subset sessions load
    unless the caller opts into more (``build_session(tiles=...)``).
    The two differ on purpose: loading a new recording by default would
    silently re-price walks that previously fell back analytically.
    """

    name: str
    description: str
    tmg: Callable[[], TMG]
    knob_spaces: Callable[..., Dict[str, KnobSpace]]
    analytical: Callable[[], Any]
    fixed: Dict[str, float] = field(default_factory=dict)
    delta: float = 0.25
    # measured-backend surface (optional)
    kernel_specs: Optional[Callable[[int],
                                    Dict[str, PallasKernelSpec]]] = None
    native_tile: int = 0
    measurement_path: Optional[Callable[[int], str]] = None
    recorded_tiles: Tuple[int, ...] = ()
    default_tiles: Tuple[int, ...] = ()
    # called as calibrated_fallback(store=<native recording>) when the
    # caller already holds the loaded store, or with no arguments
    calibrated_fallback: Optional[Callable[..., Any]] = None
    record_hint: Optional[str] = None          # app's re-record command
    # memory-co-design surface (optional)
    plm_planner: Optional[Callable[[], Any]] = None
    plm_tile_sizes: Tuple[int, ...] = ()            # analytical tile axis
    plm_tile_sizes_measured: Tuple[int, ...] = ()   # measured-drive axis
    # interpret-mode parity cases: (tile) -> [(name, fn, oracle, args)]
    parity_cases: Optional[Callable[..., List]] = None

    def available_tiles(self) -> Tuple[int, ...]:
        """The recorded tiles whose store files exist on disk."""
        if self.measurement_path is None:
            return ()
        return tuple(t for t in self.recorded_tiles
                     if os.path.exists(self.measurement_path(t)))

    def recording_keys(self) -> List[Tuple[int, str, str, int]]:
        """Every recording on disk, as ``(tile, device_kind, file,
        points)`` — the ``(tile, device_kind)`` pairs are exactly the
        :class:`MeasurementSet` routing keys a measured backend can
        replay; ``file`` is the store's basename under
        ``artifacts/measurements/``."""
        out: List[Tuple[int, str, str, int]] = []
        if self.measurement_path is None:
            return out
        from .pallas_oracle import MeasurementStore
        for t in self.recorded_tiles:
            path = self.measurement_path(t)
            if not os.path.exists(path):
                continue
            store = MeasurementStore.load(path)
            out.append((store.tile or t, store.device_kind,
                        os.path.basename(path), len(store.entries)))
        return out

    def describe(self) -> Dict[str, Any]:
        """The app as a plain dict — what doc generation
        (``python -m benchmarks.run --emit-docs``) and skip reasons
        read.  Deterministic: sorted keys, recording basenames only."""
        return {
            "name": self.name,
            "description": self.description,
            "components": sorted(t.name for t in self.tmg().transitions),
            "fixed": sorted(self.fixed),
            "delta": self.delta,
            "measured": self.kernel_specs is not None,
            "native_tile": self.native_tile,
            "recorded_tiles": list(self.recorded_tiles),
            "available_tiles": list(self.available_tiles()),
            "recordings": [
                {"tile": t, "device_kind": kind, "file": name, "points": n}
                for t, kind, name, n in self.recording_keys()],
            "plm_planner": self.plm_planner is not None,
            "plm_tile_sizes": list(self.plm_tile_sizes),
            "plm_tile_sizes_measured": list(self.plm_tile_sizes_measured),
            "parity_cases": self.parity_cases is not None,
            "record_hint": self.record_hint,
        }

    def measurement_set(self, tiles: Optional[Sequence[int]] = None
                        ) -> MeasurementSet:
        """Load the app's recordings for ``tiles`` (default: the app's
        ``default_tiles``) into one routing set."""
        if self.measurement_path is None:
            raise ValueError(f"app {self.name!r} has no recordings")
        use = tuple(tiles if tiles is not None else self.default_tiles)
        return MeasurementSet.load(self.measurement_path(t) for t in use)


@dataclass(frozen=True)
class Backend:
    """One registered oracle family: factory + capability metadata.

    ``make_tool(app, share_plm=..., tiles=..., mode=...)`` returns the
    synthesis tool a session drives for ``app``.  ``measured`` says
    whether prices come from executing kernels (record/replay) or from
    a closed-form model; ``supports``/``supported_tiles`` are the
    capability questions benchmarks ask before wiring a scenario, and
    ``calibrate`` is the hook that returns the app's analytical model
    re-scaled onto this backend's measured axes (None when the backend
    is itself analytical, or the app has no recordings to fit against).
    """

    name: str
    description: str
    measured: bool
    make_tool: Callable[..., Any]
    supports: Callable[[App], bool] = lambda app: True
    supported_tiles: Callable[[App], Tuple[int, ...]] = lambda app: ()
    calibrate: Optional[Callable[[App], Any]] = None
    # why an unsupported app is unsupported, in the app's terms — the
    # scenario matrix reports it as the cell's skip reason
    explain: Optional[Callable[[App], Optional[str]]] = None

    def skip_reason(self, app: App) -> Optional[str]:
        """``None`` when this backend can drive ``app``; otherwise a
        non-empty human-readable reason (what the scenario matrix and
        generated docs print for a skipped cell)."""
        if self.supports(app):
            return None
        if self.explain is not None:
            reason = self.explain(app)
            if reason:
                return reason
        return (f"backend {self.name!r} does not support app "
                f"{app.name!r}")

    def describe(self, apps: Optional[Sequence[App]] = None
                 ) -> Dict[str, Any]:
        """The backend as a plain dict; with ``apps``, a per-app
        capability block (supported / tiles / skip reason)."""
        doc: Dict[str, Any] = {
            "name": self.name,
            "description": self.description,
            "measured": self.measured,
        }
        if apps is not None:
            doc["apps"] = {
                app.name: {
                    "supported": self.supports(app),
                    "tiles": list(self.supported_tiles(app)),
                    "skip_reason": self.skip_reason(app),
                } for app in apps}
        return doc


# ----------------------------------------------------------------------
# the registries
# ----------------------------------------------------------------------
_APPS: Dict[str, App] = {}
_BACKENDS: Dict[str, Backend] = {}

# built-in apps self-register when their package is imported; the lazy
# import (on first lookup) avoids a core -> apps import cycle
_BUILTIN_APP_MODULES: Dict[str, str] = {
    "wami": "repro.apps.wami",
    "fleet": "repro.apps.fleet",
}


def register_app(app: App) -> App:
    """Idempotent by name: re-registering the same name replaces the
    entry (module reloads in notebooks would otherwise error)."""
    _APPS[app.name] = app
    return app


def register_backend(backend: Backend) -> Backend:
    _BACKENDS[backend.name] = backend
    return backend


def _ensure_builtin_apps(name: Optional[str] = None) -> None:
    wanted = ([name] if name in _BUILTIN_APP_MODULES
              else list(_BUILTIN_APP_MODULES))
    for key in wanted:
        if key not in _APPS:
            importlib.import_module(_BUILTIN_APP_MODULES[key])


def get_app(name: str) -> App:
    """Resolve a registered workload by name (importing built-ins on
    first use).  Unknown names list what IS registered."""
    if name not in _APPS:
        _ensure_builtin_apps(name)
    try:
        return _APPS[name]
    except KeyError:
        raise KeyError(f"unknown app {name!r}; registered apps: "
                       f"{sorted(_APPS) or '<none>'}") from None


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered backends: "
                       f"{sorted(_BACKENDS)}") from None


def list_apps() -> List[App]:
    _ensure_builtin_apps()
    return [_APPS[n] for n in sorted(_APPS)]


def list_backends() -> List[Backend]:
    return [_BACKENDS[n] for n in sorted(_BACKENDS)]


# ----------------------------------------------------------------------
# the built-in backends
# ----------------------------------------------------------------------
def _analytical_tool(app: App, **_opts: Any) -> Any:
    return app.analytical()


def _pallas_supports(app: App) -> bool:
    return app.kernel_specs is not None and bool(app.available_tiles())


def _pallas_explain(app: App) -> Optional[str]:
    if app.kernel_specs is None:
        return (f"app {app.name!r} registers no Pallas kernel specs "
                f"(no measured surface)")
    if not app.available_tiles():
        hint = f"; {app.record_hint}" if app.record_hint else ""
        return (f"no recording on disk for tiles "
                f"{list(app.recorded_tiles)} under "
                f"artifacts/measurements/{hint}")
    return None


def _pallas_tool(app: App, *, share_plm: bool = False,
                 tiles: Optional[Sequence[int]] = None,
                 mode: str = "replay", missing: Optional[str] = None,
                 **opts: Any) -> PallasOracle:
    """The measured oracle for ``app``: replay its recordings through a
    :class:`MeasurementSet`, fall back analytically elsewhere.

    Plain drives keep the strict ``missing="error"`` semantics over the
    raw analytical tool; ``share_plm`` drives use the unit-calibrated
    fallback with ``missing="fallback"`` so the tile axis (and any
    mapped point outside the recorded walk) prices deterministically.
    """
    if app.kernel_specs is None:
        raise ValueError(f"app {app.name!r} has no Pallas kernel specs; "
                         f"measured backends are unsupported "
                         f"(supported apps: "
                         f"{[a.name for a in list_apps() if _pallas_supports(a)]})")
    measurements = app.measurement_set(tiles)
    if share_plm or missing == "fallback":
        missing = "fallback"
        if app.calibrated_fallback is not None:
            # hand the hook the already-loaded native recording so the
            # unit fit does not re-read the JSON from disk
            kind = ("interpret" if opts.get("interpret", True)
                    else "device")
            fallback = app.calibrated_fallback(
                store=measurements.get(app.native_tile, kind))
        else:
            fallback = app.analytical()
    else:
        fallback = app.analytical()
        missing = missing or "error"
    return PallasOracle(
        app.kernel_specs(app.native_tile), mode=mode,
        measurements=measurements,
        components_factory=app.kernel_specs,
        fallback=fallback, native_tile=app.native_tile,
        missing=missing, record_hint=app.record_hint, **opts)


def _pallas_calibrate(app: App) -> Any:
    if app.calibrated_fallback is None:
        return None
    return app.calibrated_fallback()


register_backend(Backend(
    name="analytical",
    description="closed-form models (HLS scheduler / XLA roofline); "
                "no recordings needed",
    measured=False,
    make_tool=_analytical_tool,
))

register_backend(Backend(
    name="pallas",
    description="measured Pallas kernels via MeasurementSet record/replay; "
                "unrecorded points fall back analytically",
    measured=True,
    make_tool=_pallas_tool,
    supports=_pallas_supports,
    supported_tiles=lambda app: app.available_tiles(),
    calibrate=_pallas_calibrate,
    explain=_pallas_explain,
))


# ----------------------------------------------------------------------
# the one session constructor
# ----------------------------------------------------------------------
def build_tool(app: App | str, backend: Backend | str = "analytical",
               **opts: Any) -> Any:
    """The oracle for (app, backend) without a session around it — what
    single-component benchmarks (fig4) and custom drives use."""
    app = get_app(app) if isinstance(app, str) else app
    backend = get_backend(backend) if isinstance(backend, str) else backend
    return backend.make_tool(app, **opts)


def build_session(app: App | str, backend: Backend | str = "analytical",
                  *, delta: Optional[float] = None, workers: int = 1,
                  share_plm: bool = False,
                  tile_sizes: Optional[Sequence[int]] = None,
                  tiles: Optional[Sequence[int]] = None,
                  tool: Any = None,
                  verify_plans: bool = False,
                  batch_pricing: bool = False,
                  guided: bool = False,
                  **kwargs: Any) -> ExplorationSession:
    """Build the :class:`ExplorationSession` for any registered
    workload x oracle pair.

    ``share_plm`` attaches the app's PLM planner and opens its tile
    axis (``tile_sizes`` overrides the app's per-backend default);
    ``tiles`` selects which recordings a measured backend loads
    (default: the app's ``default_tiles``); ``tool`` injects a
    pre-built oracle (skipping the backend factory).
    ``verify_plans=True`` turns on the strict map-phase post-pass:
    every memory plan the planner emits is independently re-proved
    race-free, capacity-feasible, and dominance-guarded by
    :mod:`repro.core.analysis.verify` before the session accepts it
    (only meaningful together with ``share_plm``).

    ``batch_pricing=True`` wraps an analytical tool in a
    :class:`~repro.core.pricing.BatchPricer` so every oracle request is
    a whole-grid lookup (bit-exact; non-analytical tools pass through
    unchanged).  ``guided=True`` additionally runs surrogate-guided
    characterization (:mod:`repro.core.surrogate`): the Algorithm-1
    walk prices from the grid and only the surrogate's top corner per
    component is confirmed through the real oracle — analytical
    backends only; raises for backends without a grid program.
    Remaining keywords flow to :class:`ExplorationSession`.
    """
    from .pricing import BatchPricer     # lazy: pricing imports backends
    app = get_app(app) if isinstance(app, str) else app
    backend = get_backend(backend) if isinstance(backend, str) else backend
    if tool is None and kwargs.get("ledger") is None:
        # a pre-built ledger already wraps its own tool; building one
        # here would be dead weight (and, for measured backends, I/O)
        tool = backend.make_tool(app, share_plm=share_plm, tiles=tiles)
    if guided:
        target = tool if tool is not None else kwargs["ledger"].tool
        pricer = BatchPricer.wrap(target)
        if not isinstance(pricer, BatchPricer):
            raise ValueError(
                f"guided characterization needs an analytical pricing "
                f"grid; backend {backend.name!r} tool "
                f"{type(target).__name__} has none (batch_pricing/guided "
                f"support HLSTool and XLATool)")
        kwargs.setdefault("pricer", pricer)
        if tool is not None:
            tool = pricer               # share one grid set end to end
    elif batch_pricing and tool is not None:
        tool = BatchPricer.wrap(tool)
    if share_plm:
        if app.plm_planner is not None:
            kwargs.setdefault("memory_planner", app.plm_planner())
        if tile_sizes is None:
            tile_sizes = (app.plm_tile_sizes_measured if backend.measured
                          else app.plm_tile_sizes)
    spaces = (app.knob_spaces(tile_sizes=tuple(tile_sizes))
              if tile_sizes else app.knob_spaces())
    return ExplorationSession(app.tmg(), tool, spaces,
                              delta=app.delta if delta is None else delta,
                              fixed=dict(app.fixed), workers=workers,
                              verify_plans=verify_plans,
                              **kwargs)


def build_query_session(query: DSEQuery, *, workers: Optional[int] = None,
                        **kwargs: Any) -> ExplorationSession:
    """Resolve a :class:`~repro.core.session.DSEQuery` into a session —
    the service's per-tenant resolution point.

    Unknown app/backend names raise the registry's listing errors
    *synchronously* (the service validates at submit time, before a
    query ever occupies a queue slot).  ``workers`` overrides the
    query's own fan-out; remaining keywords (``tool``, ``ledger``,
    ``verify_plans``, ...) flow to :func:`build_session`.
    """
    return build_session(
        query.app, query.backend, delta=query.delta,
        workers=query.workers if workers is None else workers,
        share_plm=query.share_plm, tile_sizes=query.tile_sizes,
        tiles=query.tiles, **kwargs)
