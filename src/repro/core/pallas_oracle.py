"""PallasOracle: a *measured* execution backend for the COSMOS loop.

Everything the DSE engine has priced so far came from closed-form models
(``HLSTool``'s scheduler, ``XLATool``'s roofline).  This module is the
backend the paper actually assumes exists: an oracle whose numbers come
from running the thing — each (component, knob) point compiles the
component's knob-parameterized Pallas kernel and *times* it
(docs/backends.md walks through the protocol):

  * latency lambda — measured wall clock per kernel launch, divided by
    ``ports``: the grid columns are parallel lane-banks (DESIGN.md §2),
    so the per-bank effective latency is what the TMG composes;
  * area alpha — the VMEM footprint: the double-buffered working set
    summed over the ``ports`` banks, plus a fixed per-bank pipeline
    overhead (the TPU shadow of Mnemosyne's bank-controller area);
  * the lambda-constraint — a knob point is infeasible when the grid
    does not divide (W % ports, H % unrolls) or the double-buffered
    block no longer fits the VMEM budget, and, like every backend, when
    ``max_states`` caps the Eq. (1) state estimate.

Measurements are memoized per (component, ports, unrolls) — one physical
point is timed exactly once per process, so a batched drive prices
identically to a sequential one — and flow through a
:class:`MeasurementStore` for record/replay: ``mode="record"`` times and
persists, ``mode="replay"`` is fully deterministic and machine-free (CI
has no TPU; the checked-in recording under ``artifacts/measurements/``
drives the same fronts byte-for-byte).  Components without a Pallas
kernel fall back to a wrapped analytical tool, so a mixed system (the
full WAMI TMG) still explores end-to-end.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from .knobs import CDFGFacts, Synthesis, SynthesisTool
from .oracle import OracleBatchMixin, call_synthesize

__all__ = [
    "PallasKernelSpec",
    "MeasurementStore",
    "MissingMeasurementError",
    "PallasOracle",
]

# one physical measurement: (component, ports, unrolls).  ``max_states``
# is NOT part of the key — feasibility under a cap is decided from the
# deterministic state model, never re-measured.
MeasureKey = Tuple[str, int, int]

_VMEM_BUDGET = 16 * 1024 * 1024     # bytes per TPU core


@dataclass(frozen=True)
class PallasKernelSpec:
    """One knob-parameterized kernel, as the oracle sees it.

    ``build(ports, unrolls, interpret)`` returns a zero-argument runner
    (inputs baked in, deterministic) whose launch the oracle times.
    ``vmem_bytes``/``grid_steps`` are the kernel package's cost models
    (``(H, W, ports=, unrolls=) -> int``).  ``n_in``/``n_out`` are the
    VMEM blocks the kernel streams per grid step — the Eq. (1)
    gamma_r/gamma_w analogues used for the state estimate.
    """

    name: str
    shape: Tuple[int, int]                      # (H, W) the stage processes
    build: Callable[[int, int, bool], Callable[[], Any]]
    vmem_bytes: Callable[..., int]
    grid_steps: Callable[..., int]
    n_in: int
    n_out: int

    def divisible(self, ports: int, unrolls: int) -> bool:
        H, W = self.shape
        return W % ports == 0 and H % unrolls == 0

    def facts(self) -> CDFGFacts:
        return CDFGFacts(gamma_r=self.n_in, gamma_w=self.n_out, eta=1,
                         trip=self.shape[0], has_plm_access=True)

    def states(self, ports: int, unrolls: int) -> int:
        return self.facts().h(unrolls, ports)


class MissingMeasurementError(KeyError):
    """Replay asked for a point the recording does not contain."""


class MeasurementStore:
    """A flat, deterministic JSON store of raw kernel timings.

    Maps (component, ports, unrolls) -> measured wall seconds per
    launch.  The derived quantities (per-bank lambda, VMEM area,
    feasibility) are recomputed by the oracle on replay, so a recording
    survives cost-model refinements.  ``save`` writes sorted keys —
    re-recording an identical machine state diffs clean.

    ``flush_every`` > 0 makes the store durable *incrementally*: every
    N-th ``put`` rewrites the file through the same atomic
    write-then-rename step the :class:`PersistentOracleCache` uses, so a
    killed recording campaign loses at most the last N-1 timings and a
    restart (the record-mode oracle consults the store before timing)
    never re-pays for a flushed point.  0 keeps the legacy behaviour:
    the file is only written on an explicit ``save``/oracle ``flush``.
    """

    def __init__(self, path: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 flush_every: int = 0):
        self.path = path
        self.meta: Dict[str, Any] = dict(meta or {})
        self.entries: Dict[MeasureKey, float] = {}
        self.flush_every = max(0, int(flush_every))
        self._dirty = 0
        self._save_lock = threading.Lock()

    @classmethod
    def load(cls, path: str, *, flush_every: int = 0) -> "MeasurementStore":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != 1:
            raise ValueError(f"unknown measurement-store version "
                             f"{doc.get('version')!r} in {path}")
        store = cls(path=path, meta=doc.get("meta", {}),
                    flush_every=flush_every)
        for k, wall_s in doc["entries"].items():
            comp, p, u = k.rsplit(":", 2)
            store.entries[(comp, int(p[1:]), int(u[1:]))] = float(wall_s)
        return store

    @staticmethod
    def _key_str(key: MeasureKey) -> str:
        comp, ports, unrolls = key
        return f"{comp}:p{ports}:u{unrolls}"

    def get(self, key: MeasureKey) -> Optional[float]:
        return self.entries.get(key)

    def put(self, key: MeasureKey, wall_s: float) -> None:
        if self.flush_every:
            # the write happens under the save lock so a concurrent
            # autoflush never iterates a mutating dict
            with self._save_lock:
                self.entries[key] = float(wall_s)
                self._dirty += 1
                if self._dirty >= self.flush_every and self.path:
                    self._save_locked(self.path)
        else:
            self.entries[key] = float(wall_s)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("MeasurementStore has no path")
        with self._save_lock:
            return self._save_locked(path)

    def _save_locked(self, path: str) -> str:
        doc = {"version": 1, "meta": self.meta,
               "entries": {self._key_str(k): self.entries[k]
                           for k in sorted(self.entries)}}
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)     # atomic: a kill leaves old or new, never torn
        self.path = path
        self._dirty = 0
        return path

    def __len__(self) -> int:
        return len(self.entries)


class PallasOracle(OracleBatchMixin):
    """The measured synthesis oracle (SynthesisTool/Oracle protocols).

    ``mode``:
      * ``"measure"`` — compile + time every new point (memoized);
      * ``"record"``  — measure, and persist every timing into ``store``;
      * ``"replay"``  — never execute; raise
        :class:`MissingMeasurementError` on a point absent from
        ``store`` (re-record with ``examples/wami_pallas.py --record``).

    ``fallback`` prices components that have no Pallas kernel (e.g. the
    6x6 matrix stages of WAMI) through an analytical tool, so a mixed
    TMG explores end-to-end.  ``timer(component, ports, unrolls, runner)
    -> seconds`` replaces the wall-clock measurement — tests inject a
    deterministic one to make a *fresh* drive byte-comparable to a
    replayed one.

    ``native_tile`` declares the PLM tile the kernel specs (and the
    recording) were built at.  A synthesis requested at any other tile
    is routed to the fallback tool, which re-prices the component at
    that tile analytically — the recording stays single-tile, the tile
    knob axis still explores (pair with a unit-calibrated fallback,
    :mod:`repro.core.plm.units`, to keep the axes comparable).

    ``missing`` picks the replay behaviour for a point absent from the
    recording: ``"error"`` (default) raises
    :class:`MissingMeasurementError` — the strict CI semantics;
    ``"fallback"`` prices it through the fallback tool instead, which is
    what a drive whose walk *extends* the recorded one (e.g. the tile
    knob reshapes the LP and hence the mapped unroll choices) needs to
    stay deterministic and machine-free.
    """

    def __init__(self, components: Dict[str, PallasKernelSpec], *,
                 mode: str = "measure",
                 store: Optional[MeasurementStore] = None,
                 fallback: Optional[SynthesisTool] = None,
                 interpret: bool = True,
                 vmem_budget: int = _VMEM_BUDGET,
                 bank_overhead_bytes: int = 4096,
                 reps: int = 3,
                 native_tile: int = 0,
                 missing: str = "error",
                 timer: Optional[Callable[..., float]] = None):
        if mode not in ("measure", "record", "replay"):
            raise ValueError(f"unknown mode {mode!r}")
        if missing not in ("error", "fallback"):
            raise ValueError(f"unknown missing policy {missing!r}")
        if missing == "fallback" and fallback is None:
            raise ValueError("missing='fallback' requires a fallback tool")
        if mode in ("record", "replay") and store is None:
            raise ValueError(f"mode={mode!r} requires a MeasurementStore")
        self.components = dict(components)
        self.mode = mode
        self.store = store
        self.fallback = fallback
        self.interpret = interpret
        self.vmem_budget = int(vmem_budget)
        self.bank_overhead_bytes = int(bank_overhead_bytes)
        self.reps = max(1, int(reps))
        self.native_tile = int(native_tile)
        self.missing = missing
        self.timer = timer
        self._measured: Dict[MeasureKey, float] = {}
        self._lock = threading.Lock()
        # timing under a thread-pool fan-out measures contention, not the
        # kernel: _measure_lock serializes every real measurement even
        # when a ledger/session fans synthesize() out over its own pool;
        # replay never executes and can fan out freely
        self._measure_lock = threading.Lock()
        self.batch_workers = 8 if mode == "replay" else 1

    # ------------------------------------------------------------------
    # measurement
    # ------------------------------------------------------------------
    def _time_runner(self, runner: Callable[[], Any]) -> float:
        import jax
        jax.block_until_ready(runner())            # compile + warm up
        best = float("inf")
        for _ in range(self.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(runner())
            best = min(best, time.perf_counter() - t0)
        return best

    def _wall_s(self, spec: PallasKernelSpec, ports: int,
                unrolls: int) -> float:
        key: MeasureKey = (spec.name, ports, unrolls)
        with self._lock:
            hit = self._measured.get(key)
        if hit is not None:
            return hit
        if self.mode == "replay":
            wall = self.store.get(key)
            if wall is None:
                raise MissingMeasurementError(
                    f"no recorded measurement for {key}; re-record with "
                    f"`python examples/wami_pallas.py --record`")
        elif self.mode == "record" and self.store.get(key) is not None:
            # resumed campaign: the point was already paid for (and
            # flushed) by the killed run — never re-time it
            wall = self.store.get(key)
        else:
            with self._measure_lock:
                with self._lock:              # raced while waiting?
                    hit = self._measured.get(key)
                if hit is not None:
                    return hit
                if self.timer is not None:
                    wall = float(self.timer(spec.name, ports, unrolls,
                                            spec.build(ports, unrolls,
                                                       self.interpret)))
                else:
                    wall = self._time_runner(spec.build(ports, unrolls,
                                                        self.interpret))
        with self._lock:
            # a racing measurement of the same key keeps the first value,
            # so every consumer sees one number per physical point
            wall = self._measured.setdefault(key, wall)
            if self.mode == "record" and self.store.get(key) != wall:
                self.store.put(key, wall)    # may autoflush (flush_every)
        return wall

    # ------------------------------------------------------------------
    # cost composition
    # ------------------------------------------------------------------
    def _area_bytes(self, spec: PallasKernelSpec, ports: int,
                    unrolls: int) -> float:
        H, W = spec.shape
        step = spec.vmem_bytes(H, W, ports=ports, unrolls=unrolls)
        # double-buffered working set in every parallel bank + fixed
        # per-bank pipeline overhead (descriptors, semaphores)
        return float(2 * step * ports + self.bank_overhead_bytes * ports)

    def _infeasible(self, ports: int, unrolls: int, states: int,
                    tile: int = 0) -> Synthesis:
        return Synthesis(lam=float("inf"), area=float("inf"), ports=ports,
                         unrolls=unrolls, states_per_iter=states,
                         feasible=False, tile=tile)

    # ------------------------------------------------------------------
    # SynthesisTool protocol
    # ------------------------------------------------------------------
    def _route_fallback(self, component: str, tile: int) -> bool:
        """True when (component, tile) is priced by the fallback tool:
        the component has no kernel, or the tile is not the recording's."""
        if component not in self.components:
            return True
        return bool(tile and self.native_tile
                    and tile != self.native_tile)

    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        if (tile and not self.native_tile
                and component in self.components):
            # without a declared native tile the oracle cannot tell
            # whether the request matches the kernels/recording — pricing
            # it anyway would fabricate a tile axis out of one tile's
            # measurements (and collide store keys in record mode)
            raise ValueError(
                f"tile={tile} requested for {component!r} but this "
                f"PallasOracle declares no native_tile; pass native_tile= "
                f"so tile routing is defined")
        if self._route_fallback(component, tile):
            if self.fallback is None:
                raise KeyError(f"no Pallas kernel or fallback tool for "
                               f"component {component!r} (tile={tile})")
            return call_synthesize(self.fallback, component,
                                   unrolls=unrolls, ports=ports,
                                   max_states=max_states, tile=tile)
        spec = self.components[component]
        if not spec.divisible(ports, unrolls):
            return self._infeasible(ports, unrolls, 0, tile)
        states = spec.states(ports, unrolls)
        if max_states is not None and states > max_states:
            return self._infeasible(ports, unrolls, states, tile)
        H, W = spec.shape
        step = spec.vmem_bytes(H, W, ports=ports, unrolls=unrolls)
        if 2 * step > self.vmem_budget:
            # the TPU lambda-constraint: the double-buffered block no
            # longer fits VMEM — discarded, and counted, like any other
            # failed synthesis
            return self._infeasible(ports, unrolls, states, tile)
        try:
            wall = self._wall_s(spec, ports, unrolls)
        except MissingMeasurementError:
            if self.missing != "fallback":
                raise
            return call_synthesize(self.fallback, component,
                                   unrolls=unrolls, ports=ports,
                                   max_states=max_states, tile=tile)
        lam = wall / ports                       # parallel lane-banks
        area = self._area_bytes(spec, ports, unrolls)
        return Synthesis(
            lam=lam, area=area, ports=ports, unrolls=unrolls,
            states_per_iter=states, feasible=True,
            detail={"wall_s": wall, "vmem_step_bytes": float(step),
                    "grid_steps": float(spec.grid_steps(
                        H, W, ports=ports, unrolls=unrolls))},
            tile=tile)

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        # a feasible native-tile synthesis without a measured wall came
        # from the missing="fallback" path: its Eq. (1) facts must match
        # the model that actually scheduled it, or the derived caps get
        # applied across two different state models
        fallback_priced = (self.missing == "fallback" and synth.feasible
                           and "wall_s" not in (synth.detail or {}))
        if self._route_fallback(component, synth.tile) or fallback_priced:
            if self.fallback is None:
                raise KeyError(component)
            return self.fallback.cdfg_facts(component, synth)
        return self.components[component].facts()

    def plm_requirement(self, component: str, synth: Synthesis):
        """The measured component's memory demand: its entire area IS
        VMEM footprint (the TPU shadow of the PLM), so capacity = area
        bytes and the datapath share is zero.  Fallback-priced points
        delegate to the fallback tool — including native-tile points the
        ``missing="fallback"`` policy priced analytically, recognizable
        by the absence of the measured ``wall_s`` detail."""
        from .plm.spec import PLMRequirement      # lazy: avoid cycles
        if (self._route_fallback(component, synth.tile)
                or "wall_s" not in (synth.detail or {})):
            fn = getattr(self.fallback, "plm_requirement", None)
            return None if fn is None else fn(component, synth)
        area = float(synth.area)
        return PLMRequirement(component=component, capacity=int(area),
                              word_bits=32, ports=synth.ports,
                              area_plm=area, area_logic=0.0,
                              unit="bytes", tile=synth.tile)

    # ------------------------------------------------------------------
    def flush(self) -> Optional[str]:
        """Persist the store (record mode); no-op otherwise."""
        if self.mode == "record" and self.store is not None:
            return self.store.save()
        return None
