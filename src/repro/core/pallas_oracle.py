"""PallasOracle: a *measured* execution backend for the COSMOS loop.

Everything the DSE engine has priced so far came from closed-form models
(``HLSTool``'s scheduler, ``XLATool``'s roofline).  This module is the
backend the paper actually assumes exists: an oracle whose numbers come
from running the thing — each (component, knob) point compiles the
component's knob-parameterized Pallas kernel and *times* it
(docs/backends.md walks through the protocol):

  * latency lambda — measured wall clock per kernel launch, divided by
    ``ports``: the grid columns are parallel lane-banks (DESIGN.md §2),
    so the per-bank effective latency is what the TMG composes;
  * area alpha — the VMEM footprint: the double-buffered working set
    summed over the ``ports`` banks, plus a fixed per-bank pipeline
    overhead (the TPU shadow of Mnemosyne's bank-controller area);
  * the lambda-constraint — a knob point is infeasible when the grid
    does not divide (W % ports, H % unrolls) or the double-buffered
    block no longer fits the VMEM budget, and, like every backend, when
    ``max_states`` caps the Eq. (1) state estimate.

Measurements are memoized per (component, ports, unrolls, tile) — one
physical point is timed exactly once per process, so a batched drive
prices identically to a sequential one — and flow through a
:class:`MeasurementSet` for record/replay: a keyed map
``(tile, device_kind) -> MeasurementStore`` the oracle routes every
request through.  Tiles with a recording replay their measured walls;
unrecorded tiles fall through to the analytical ``fallback`` (or raise,
under ``missing="error"``), so a tile knob axis stays deterministic and
machine-free even when only some tiles are measured.  ``mode="record"``
times and persists, ``mode="replay"`` is fully deterministic and
machine-free (CI has no TPU; the checked-in recordings under
``artifacts/measurements/`` drive the same fronts byte-for-byte).
Components without a Pallas kernel fall back to a wrapped analytical
tool, so a mixed system (the full WAMI TMG) still explores end-to-end.
"""

from __future__ import annotations

import json
import os
import threading
import time
import warnings
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, List, Optional, Tuple)

from .knobs import CDFGFacts, Synthesis, SynthesisTool
from .oracle import OracleBatchMixin, call_synthesize

__all__ = [
    "PallasKernelSpec",
    "MeasurementStore",
    "MeasurementSet",
    "MissingMeasurementError",
    "PallasOracle",
    "open_recording",
]

# one physical measurement inside one store: (component, ports, unrolls).
# ``max_states`` is NOT part of the key — feasibility under a cap is
# decided from the deterministic state model, never re-measured.  The
# tile lives one level up: it selects WHICH store via the
# :class:`MeasurementSet` key (tile, device_kind).
MeasureKey = Tuple[str, int, int]

# a MeasurementSet routing key: (tile, device_kind); tile 0 = the
# component's native tile, device_kind "interpret" = CPU interpret mode
SetKey = Tuple[int, str]

_VMEM_BUDGET = 16 * 1024 * 1024     # bytes per TPU core


@dataclass(frozen=True)
class PallasKernelSpec:
    """One knob-parameterized kernel, as the oracle sees it.

    ``build(ports, unrolls, interpret)`` returns a zero-argument runner
    (inputs baked in, deterministic) whose launch the oracle times.
    ``vmem_bytes``/``grid_steps`` are the kernel package's cost models
    (``(H, W, ports=, unrolls=) -> int``).  ``n_in``/``n_out`` are the
    VMEM blocks the kernel streams per grid step — the Eq. (1)
    gamma_r/gamma_w analogues used for the state estimate.
    """

    name: str
    shape: Tuple[int, int]                      # (H, W) the stage processes
    build: Callable[[int, int, bool], Callable[[], Any]]
    vmem_bytes: Callable[..., int]
    grid_steps: Callable[..., int]
    n_in: int
    n_out: int

    def divisible(self, ports: int, unrolls: int) -> bool:
        H, W = self.shape
        return W % ports == 0 and H % unrolls == 0

    def facts(self) -> CDFGFacts:
        return CDFGFacts(gamma_r=self.n_in, gamma_w=self.n_out, eta=1,
                         trip=self.shape[0], has_plm_access=True)

    def states(self, ports: int, unrolls: int) -> int:
        return self.facts().h(unrolls, ports)


class MissingMeasurementError(KeyError):
    """Replay asked for a point the recording does not contain."""


class MeasurementStore:
    """A flat, deterministic JSON store of raw kernel timings.

    Maps (component, ports, unrolls) -> measured wall seconds per
    launch.  The derived quantities (per-bank lambda, VMEM area,
    feasibility) are recomputed by the oracle on replay, so a recording
    survives cost-model refinements.  ``save`` writes sorted keys —
    re-recording an identical machine state diffs clean.

    ``flush_every`` > 0 makes the store durable *incrementally*: every
    N-th ``put`` rewrites the file through the same atomic
    write-then-rename step the :class:`PersistentOracleCache` uses, so a
    killed recording campaign loses at most the last N-1 timings and a
    restart (the record-mode oracle consults the store before timing)
    never re-pays for a flushed point.  0 keeps the legacy behaviour:
    the file is only written on an explicit ``save``/oracle ``flush``.
    """

    def __init__(self, path: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 flush_every: int = 0):
        self.path = path
        self.meta: Dict[str, Any] = dict(meta or {})
        self.entries: Dict[MeasureKey, float] = {}
        self.flush_every = max(0, int(flush_every))
        self._dirty = 0
        self._save_lock = threading.Lock()

    @classmethod
    def load(cls, path: str, *, flush_every: int = 0) -> "MeasurementStore":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != 1:
            raise ValueError(f"unknown measurement-store version "
                             f"{doc.get('version')!r} in {path}")
        store = cls(path=path, meta=doc.get("meta", {}),
                    flush_every=flush_every)
        for k, wall_s in doc["entries"].items():
            comp, p, u = k.rsplit(":", 2)
            store.entries[(comp, int(p[1:]), int(u[1:]))] = float(wall_s)
        return store

    @property
    def tile(self) -> int:
        """The tile this recording was made at (0 when untagged)."""
        return int(self.meta.get("tile", 0))

    @property
    def device_kind(self) -> str:
        """Where the walls came from: ``"interpret"`` (CPU interpret
        mode) or the real device platform the recording tags."""
        kind = self.meta.get("device_kind")
        if kind:
            return str(kind)
        return "interpret" if self.meta.get("interpret", True) else "device"

    @staticmethod
    def _key_str(key: MeasureKey) -> str:
        comp, ports, unrolls = key
        return f"{comp}:p{ports}:u{unrolls}"

    def get(self, key: MeasureKey) -> Optional[float]:
        return self.entries.get(key)

    def put(self, key: MeasureKey, wall_s: float) -> None:
        if self.flush_every:
            # the write happens under the save lock so a concurrent
            # autoflush never iterates a mutating dict
            with self._save_lock:
                self.entries[key] = float(wall_s)
                self._dirty += 1
                if self._dirty >= self.flush_every and self.path:
                    self._save_locked(self.path)
        else:
            self.entries[key] = float(wall_s)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("MeasurementStore has no path")
        with self._save_lock:
            return self._save_locked(path)

    def _save_locked(self, path: str) -> str:
        doc = {"version": 1, "meta": self.meta,
               "entries": {self._key_str(k): self.entries[k]
                           for k in sorted(self.entries)}}
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)     # atomic: a kill leaves old or new, never torn
        self.path = path
        self._dirty = 0
        return path

    def __len__(self) -> int:
        return len(self.entries)


class MeasurementSet:
    """Multi-recording routing table: (tile, device_kind) -> store.

    One oracle can now hold one :class:`MeasurementStore` per measured
    tile (and per device kind — the same tile recorded in interpret mode
    and on real hardware are different recordings).  The oracle resolves
    every request's tile to a set key; a hit replays/records through
    that store, a miss falls through to the analytical fallback or
    raises, per the ``missing`` policy.

    Stores keyed by tile 0 are "native tile" recordings from before the
    tile axis existed; :meth:`from_store` (the legacy one-store shim)
    additionally aliases such a store under its ``meta`` tile so a drive
    that names the tile explicitly still hits the measured walls.
    """

    def __init__(self, stores: Optional[Dict[SetKey, MeasurementStore]] = None):
        self._stores: Dict[SetKey, MeasurementStore] = dict(stores or {})

    # -- construction --------------------------------------------------
    @classmethod
    def from_store(cls, store: MeasurementStore, *, tile: Optional[int] = None,
                   device_kind: Optional[str] = None) -> "MeasurementSet":
        """Back-compat shim: wrap a single legacy store.

        ``tile``/``device_kind`` default to the store's ``meta`` tags.
        When the caller declares no tile (0) but the recording tags one,
        the store is reachable under BOTH keys — tile-0 ("native")
        requests and requests naming the recorded tile resolve to the
        same measured walls, which is what the old single-store design
        got wrong (it errored on the explicit spelling).
        """
        kind = device_kind or store.device_kind
        keyed = tile if tile is not None else store.tile
        out = cls({(int(keyed), kind): store})
        meta_tile = store.tile
        if meta_tile and (int(keyed), kind) != (meta_tile, kind):
            out._stores.setdefault((meta_tile, kind), store)
        if keyed:
            # an explicitly-tiled store also answers "native" requests
            # when it is the only recording for its device kind
            out._stores.setdefault((0, kind), store)
        return out

    @classmethod
    def load(cls, paths: Iterable[str], *, flush_every: int = 0,
             device_kind: Optional[str] = None) -> "MeasurementSet":
        """Load several store files, keyed by their ``meta`` tags."""
        out = cls()
        for path in paths:
            store = MeasurementStore.load(path, flush_every=flush_every)
            out.add(store, device_kind=device_kind)
        return out

    def add(self, store: MeasurementStore, *, tile: Optional[int] = None,
            device_kind: Optional[str] = None) -> "MeasurementSet":
        key = (int(tile if tile is not None else store.tile),
               device_kind or store.device_kind)
        if key in self._stores:
            raise ValueError(f"MeasurementSet already holds a store for "
                             f"key (tile={key[0]}, device={key[1]!r})")
        self._stores[key] = store
        return self

    # -- lookup --------------------------------------------------------
    def get(self, tile: int, device_kind: str) -> Optional[MeasurementStore]:
        return self._stores.get((int(tile), device_kind))

    def keys(self) -> List[SetKey]:
        return sorted(self._stores)

    def tiles(self, device_kind: Optional[str] = None) -> Tuple[int, ...]:
        return tuple(sorted({t for t, k in self._stores
                             if device_kind is None or k == device_kind}))

    def stores(self) -> List[MeasurementStore]:
        """The distinct stores (aliases collapse), in key order."""
        seen: List[MeasurementStore] = []
        for key in self.keys():
            store = self._stores[key]
            if not any(store is s for s in seen):
                seen.append(store)
        return seen

    def save_all(self) -> List[str]:
        """Persist every store that has a path (record-mode flush)."""
        return [s.save() for s in self.stores() if s.path is not None]

    def describe(self) -> str:
        return ", ".join(f"(tile={t}, device={k!r})" for t, k in self.keys()) \
            or "<empty>"

    def __contains__(self, key: SetKey) -> bool:
        return (int(key[0]), key[1]) in self._stores

    def __len__(self) -> int:
        return len(self._stores)


class PallasOracle(OracleBatchMixin):
    """The measured synthesis oracle (SynthesisTool/Oracle protocols).

    ``mode``:
      * ``"measure"`` — compile + time every new point (memoized);
      * ``"record"``  — measure, and persist every timing into the
        resolved tile's store;
      * ``"replay"``  — never execute; a point absent from the resolved
        store follows the ``missing`` policy below.

    ``measurements`` is a :class:`MeasurementSet` — the multi-recording
    map ``(tile, device_kind) -> MeasurementStore`` every request routes
    through.  The legacy single-store spelling
    (``store=..., native_tile=...``) still works via
    :meth:`MeasurementSet.from_store` but is deprecated.

    ``fallback`` prices components that have no Pallas kernel (e.g. the
    6x6 matrix stages of WAMI) through an analytical tool, so a mixed
    TMG explores end-to-end.  ``timer(component, ports, unrolls, runner)
    -> seconds`` replaces the wall-clock measurement — tests inject a
    deterministic one to make a *fresh* drive byte-comparable to a
    replayed one.

    ``native_tile`` declares the tile the ``components`` kernel specs
    were built at; a request's tile resolves to it when unset (tile 0).
    A resolved tile with a recording in ``measurements`` replays (or
    records) measured walls; any other tile is routed to the fallback
    tool, which re-prices the component at that tile analytically (pair
    with a unit-calibrated fallback, :mod:`repro.core.plm.units`, to
    keep the axes comparable).  ``components_factory(tile)`` — when
    given — rebuilds the kernel specs at a measured non-native tile so
    multi-tile recordings price with the right geometry.

    ``missing`` picks the replay behaviour for a point absent from the
    resolved recording: ``"error"`` (default) raises
    :class:`MissingMeasurementError` naming the missing
    ``(tile, device_kind)`` key — the strict CI semantics;
    ``"fallback"`` prices it through the fallback tool instead, which is
    what a drive whose walk *extends* the recorded one (e.g. the tile
    knob reshapes the LP and hence the mapped unroll choices) needs to
    stay deterministic and machine-free.
    """

    def __init__(self, components: Dict[str, PallasKernelSpec], *,
                 mode: str = "measure",
                 store: Optional[MeasurementStore] = None,
                 measurements: Optional[MeasurementSet] = None,
                 components_factory: Optional[
                     Callable[[int], Dict[str, PallasKernelSpec]]] = None,
                 fallback: Optional[SynthesisTool] = None,
                 interpret: bool = True,
                 vmem_budget: int = _VMEM_BUDGET,
                 bank_overhead_bytes: int = 4096,
                 reps: int = 3,
                 native_tile: int = 0,
                 missing: str = "error",
                 device_kind: Optional[str] = None,
                 record_hint: Optional[str] = None,
                 timer: Optional[Callable[..., float]] = None):
        if mode not in ("measure", "record", "replay"):
            raise ValueError(f"unknown mode {mode!r}")
        if missing not in ("error", "fallback"):
            raise ValueError(f"unknown missing policy {missing!r}")
        if missing == "fallback" and fallback is None:
            raise ValueError("missing='fallback' requires a fallback tool")
        if store is not None and measurements is not None:
            raise ValueError("pass either store= (legacy, one recording) "
                             "or measurements= (MeasurementSet), not both")
        self.interpret = interpret
        self.device_kind = device_kind or (
            "interpret" if interpret else _default_device_kind())
        if store is not None:
            warnings.warn(
                "PallasOracle(store=...) is the legacy single-recording "
                "surface; pass measurements=MeasurementSet.from_store(...) "
                "(or build a multi-tile set) instead",
                DeprecationWarning, stacklevel=2)
            measurements = MeasurementSet.from_store(
                store, tile=native_tile or None,
                device_kind=self.device_kind)
        if mode in ("record", "replay") and (measurements is None
                                             or len(measurements) == 0):
            raise ValueError(f"mode={mode!r} requires a MeasurementStore "
                             f"or a non-empty MeasurementSet")
        self.components = dict(components)
        self.mode = mode
        self.measurements = measurements or MeasurementSet()
        self.fallback = fallback
        self.vmem_budget = int(vmem_budget)
        self.bank_overhead_bytes = int(bank_overhead_bytes)
        self.reps = max(1, int(reps))
        self.native_tile = int(native_tile)
        self.missing = missing
        # the app-specific re-record command shown in miss errors (the
        # oracle serves many apps now; a WAMI hint on a fleet miss
        # would point at the wrong recording)
        self.record_hint = record_hint
        self.timer = timer
        self._factory = components_factory
        # tiles whose requests resolve onto the native ``components``
        # specs: the declared native tile, the untagged 0, and — for the
        # legacy shim — whatever tile the native store's meta carries
        self._native_tiles = {0, self.native_tile}
        native_store = self.measurements.get(self.native_tile,
                                             self.device_kind)
        if native_store is not None and native_store.tile:
            self._native_tiles.add(native_store.tile)
        self._specs_cache: Dict[int, Dict[str, PallasKernelSpec]] = {}
        self._measured: Dict[Tuple[str, int, int, int], float] = {}
        self._lock = threading.Lock()
        # timing under a thread-pool fan-out measures contention, not the
        # kernel: _measure_lock serializes every real measurement even
        # when a ledger/session fans synthesize() out over its own pool;
        # replay never executes and can fan out freely
        self._measure_lock = threading.Lock()
        self.batch_workers = 8 if mode == "replay" else 1

    # ------------------------------------------------------------------
    # routing: request tile -> (specs, store)
    # ------------------------------------------------------------------
    @property
    def store(self) -> Optional[MeasurementStore]:
        """The native-tile recording (legacy surface; may be None)."""
        return self.measurements.get(self.native_tile, self.device_kind)

    def _resolve_tile(self, tile: int) -> int:
        return tile or self.native_tile

    def _store_for(self, resolved: int) -> Optional[MeasurementStore]:
        return self.measurements.get(resolved, self.device_kind)

    def _specs_for(self, resolved: int
                   ) -> Optional[Dict[str, PallasKernelSpec]]:
        if resolved in self._native_tiles:
            return self.components
        if self._factory is None:
            return None
        specs = self._specs_cache.get(resolved)
        if specs is None:
            specs = dict(self._factory(resolved))
            self._specs_cache[resolved] = specs
        return specs

    def _measured_here(self, component: str, resolved: int) -> bool:
        """True when (component, resolved tile) is priced by running /
        replaying a kernel rather than by the fallback tool."""
        if component not in self.components:
            return False        # kernel coverage is per component name
        if self._specs_for(resolved) is None:
            return False
        if self.mode in ("record", "replay"):
            return self._store_for(resolved) is not None
        return True             # measure mode: time it live

    # ------------------------------------------------------------------
    # measurement
    # ------------------------------------------------------------------
    def _time_runner(self, runner: Callable[[], Any]) -> float:
        import jax
        jax.block_until_ready(runner())            # compile + warm up
        best = float("inf")
        for _ in range(self.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(runner())
            best = min(best, time.perf_counter() - t0)
        return best

    def _missing_error(self, key: MeasureKey, resolved: int
                       ) -> MissingMeasurementError:
        comp, ports, unrolls = key
        hint = self.record_hint or ("re-record the recording for this key "
                                    "(docs/backends.md)")
        return MissingMeasurementError(
            f"no recorded measurement for {comp!r} (ports={ports}, "
            f"unrolls={unrolls}) under key (tile={resolved}, "
            f"device={self.device_kind!r}); recorded keys: "
            f"{self.measurements.describe()}; {hint}")

    def _wall_s(self, spec: PallasKernelSpec, ports: int, unrolls: int,
                resolved: int) -> float:
        memo_key = (spec.name, ports, unrolls, resolved)
        key: MeasureKey = (spec.name, ports, unrolls)
        store = self._store_for(resolved)
        with self._lock:
            hit = self._measured.get(memo_key)
        if hit is not None:
            return hit
        if self.mode == "replay":
            wall = store.get(key)
            if wall is None:
                raise self._missing_error(key, resolved)
        elif self.mode == "record" and store.get(key) is not None:
            # resumed campaign: the point was already paid for (and
            # flushed) by the killed run — never re-time it
            wall = store.get(key)
        else:
            with self._measure_lock:
                with self._lock:              # raced while waiting?
                    hit = self._measured.get(memo_key)
                if hit is not None:
                    return hit
                if self.timer is not None:
                    wall = float(self.timer(spec.name, ports, unrolls,
                                            spec.build(ports, unrolls,
                                                       self.interpret)))
                else:
                    wall = self._time_runner(spec.build(ports, unrolls,
                                                        self.interpret))
        with self._lock:
            # a racing measurement of the same key keeps the first value,
            # so every consumer sees one number per physical point
            wall = self._measured.setdefault(memo_key, wall)
            if self.mode == "record" and store.get(key) != wall:
                store.put(key, wall)         # may autoflush (flush_every)
        return wall

    # ------------------------------------------------------------------
    # cost composition
    # ------------------------------------------------------------------
    def _area_bytes(self, spec: PallasKernelSpec, ports: int,
                    unrolls: int) -> float:
        H, W = spec.shape
        step = spec.vmem_bytes(H, W, ports=ports, unrolls=unrolls)
        # double-buffered working set in every parallel bank + fixed
        # per-bank pipeline overhead (descriptors, semaphores)
        return float(2 * step * ports + self.bank_overhead_bytes * ports)

    def _infeasible(self, ports: int, unrolls: int, states: int,
                    tile: int = 0) -> Synthesis:
        return Synthesis(lam=float("inf"), area=float("inf"), ports=ports,
                         unrolls=unrolls, states_per_iter=states,
                         feasible=False, tile=tile)

    # ------------------------------------------------------------------
    # SynthesisTool protocol
    # ------------------------------------------------------------------
    def _route_fallback(self, component: str, tile: int) -> bool:
        """True when (component, tile) is priced by the fallback tool:
        the component has no kernel, or the resolved tile has no
        recording (and cannot be measured live)."""
        return not self._measured_here(component, self._resolve_tile(tile))

    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        resolved = self._resolve_tile(tile)
        measured = self._measured_here(component, resolved)
        if (tile and not measured and not self.native_tile
                and self._factory is None
                and component in self.components):
            # without a declared native tile (or a spec factory, or a
            # recording covering this tile) the oracle cannot tell
            # whether the request matches the kernels — pricing it
            # anyway would fabricate a tile axis out of one tile's
            # measurements (and collide store keys in record mode)
            raise ValueError(
                f"tile={tile} requested for {component!r} but this "
                f"PallasOracle declares no native_tile and no recording "
                f"covers key (tile={tile}, device={self.device_kind!r}) "
                f"(recorded keys: {self.measurements.describe()}); pass "
                f"native_tile= or add a MeasurementStore for that key")
        if not measured:
            if self.fallback is None:
                raise KeyError(f"no Pallas kernel or fallback tool for "
                               f"component {component!r} (tile={tile})")
            return call_synthesize(self.fallback, component,
                                   unrolls=unrolls, ports=ports,
                                   max_states=max_states, tile=tile)
        spec = self._specs_for(resolved)[component]
        if not spec.divisible(ports, unrolls):
            return self._infeasible(ports, unrolls, 0, tile)
        states = spec.states(ports, unrolls)
        if max_states is not None and states > max_states:
            return self._infeasible(ports, unrolls, states, tile)
        H, W = spec.shape
        step = spec.vmem_bytes(H, W, ports=ports, unrolls=unrolls)
        if 2 * step > self.vmem_budget:
            # the TPU lambda-constraint: the double-buffered block no
            # longer fits VMEM — discarded, and counted, like any other
            # failed synthesis
            return self._infeasible(ports, unrolls, states, tile)
        try:
            wall = self._wall_s(spec, ports, unrolls, resolved)
        except MissingMeasurementError:
            if self.missing != "fallback":
                raise
            return call_synthesize(self.fallback, component,
                                   unrolls=unrolls, ports=ports,
                                   max_states=max_states, tile=tile)
        lam = wall / ports                       # parallel lane-banks
        area = self._area_bytes(spec, ports, unrolls)
        return Synthesis(
            lam=lam, area=area, ports=ports, unrolls=unrolls,
            states_per_iter=states, feasible=True,
            detail={"wall_s": wall, "vmem_step_bytes": float(step),
                    "grid_steps": float(spec.grid_steps(
                        H, W, ports=ports, unrolls=unrolls))},
            tile=tile)

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        # a feasible measured-tile synthesis without a measured wall came
        # from the missing="fallback" path: its Eq. (1) facts must match
        # the model that actually scheduled it, or the derived caps get
        # applied across two different state models
        fallback_priced = (self.missing == "fallback" and synth.feasible
                           and "wall_s" not in (synth.detail or {}))
        if self._route_fallback(component, synth.tile) or fallback_priced:
            if self.fallback is None:
                raise KeyError(component)
            return self.fallback.cdfg_facts(component, synth)
        return self._specs_for(
            self._resolve_tile(synth.tile))[component].facts()

    def plm_requirement(self, component: str, synth: Synthesis):
        """The measured component's memory demand: its entire area IS
        VMEM footprint (the TPU shadow of the PLM), so capacity = area
        bytes and the datapath share is zero.  Fallback-priced points
        delegate to the fallback tool — including measured-tile points
        the ``missing="fallback"`` policy priced analytically,
        recognizable by the absence of the measured ``wall_s`` detail."""
        from .plm.spec import PLMRequirement      # lazy: avoid cycles
        if (self._route_fallback(component, synth.tile)
                or "wall_s" not in (synth.detail or {})):
            fn = getattr(self.fallback, "plm_requirement", None)
            return None if fn is None else fn(component, synth)
        area = float(synth.area)
        return PLMRequirement(component=component, capacity=int(area),
                              word_bits=32, ports=synth.ports,
                              area_plm=area, area_logic=0.0,
                              unit="bytes", tile=synth.tile)

    # ------------------------------------------------------------------
    def flush(self) -> Optional[str]:
        """Persist the recordings (record mode); no-op otherwise.
        Returns the native store's path when one was written."""
        if self.mode != "record":
            return None
        saved = self.measurements.save_all()
        native = self.store
        if native is not None and native.path in saved:
            return native.path
        return saved[0] if saved else None


def _default_device_kind() -> str:
    """The real-device tag for non-interpret measurements."""
    try:
        import jax
        return str(jax.default_backend())
    except Exception:           # pragma: no cover - jax always importable
        return "device"


def open_recording(path: str, *, mode: str, tile: int = 0,
                   interpret: bool = True,
                   flush_every: int = 16) -> MeasurementSet:
    """The record/replay bootstrap every app shares: load ``path`` when
    it exists (replay always loads — a missing file should fail loudly),
    otherwise start a fresh tagged store for a record campaign, and wrap
    the result as a single-recording :class:`MeasurementSet`.  Record
    mode autoflushes every ``flush_every`` timings; replay never writes.
    """
    autoflush = flush_every if mode == "record" else 0
    if mode == "replay" or os.path.exists(path):
        store = MeasurementStore.load(path, flush_every=autoflush)
    else:
        kind = "interpret" if interpret else _default_device_kind()
        store = MeasurementStore(path,
                                 meta={"tile": tile, "interpret": interpret,
                                       "device_kind": kind},
                                 flush_every=autoflush)
    return MeasurementSet.from_store(store, tile=tile)
