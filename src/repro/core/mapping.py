"""Synthesis mapping — the inverse function phi (Section 6.2, Eqs. 4-5).

Given the optimal per-component latency requirements returned by the LP
(synthesis planning), find knob settings whose synthesis meets them.
Within a region (fixed port count) the unroll count is estimated with a
rearranged Amdahl's law:

  mu_target = phi(lam_target, lam_min, lam_max, mu_min, mu_max)
            = [ (lam_min*lam_max*mu_max + lam_target*lam_max*mu_min)
              - (lam_min*lam_max*mu_min + lam_target*lam_min*mu_max) ]
              / [ lam_target * (lam_max - lam_min) ]                (Eq. 5)

Failure handling, both per the paper:
  * mapping picks a mu_target violating the lambda-constraint, or the
    synthesized latency misses lam_target -> increase the unrolls
    ("we are willing to trade area to preserve the throughput");
  * lam_target falls between regions -> use the slowest (lower-right)
    point of the next region with more ports; that corner was already
    synthesized by Algorithm 1, so no new tool invocation happens.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from .knobs import Region, Synthesis
from .oracle import OracleLedger

__all__ = ["phi", "MapOutcome", "map_target"]


def phi(lam_target: float, lam_min: float, lam_max: float,
        mu_min: int, mu_max: int) -> float:
    """Eq. (5).  Monotonically decreasing in lam_target on
    [lam_min, lam_max]; phi(lam_max)=mu_min, phi(lam_min)=mu_max."""
    if lam_max <= lam_min:
        return float(mu_max)
    num = ((lam_min * lam_max * mu_max + lam_target * lam_max * mu_min)
           - (lam_min * lam_max * mu_min + lam_target * lam_min * mu_max))
    den = lam_target * (lam_max - lam_min)
    return num / den


@dataclass(frozen=True)
class MapOutcome:
    component: str
    synthesis: Synthesis
    region: Optional[Region]
    requested_lam: float
    fallback: str = ""           # "", "next-region", "slowest", "fastest"


def _sorted_regions(regions: Sequence[Region]) -> List[Region]:
    return sorted(regions, key=lambda r: r.lam_max, reverse=True)


def _area_at(r: Region, lam: float) -> float:
    """Linear area estimate inside a region at latency ``lam`` (between
    the two characterized corners) — the ranking key when several
    regions contain the same latency target."""
    if r.lam_max <= r.lam_min:
        return min(r.area_min, r.area_max)
    t = (r.lam_max - lam) / (r.lam_max - r.lam_min)
    return r.area_min + min(1.0, max(0.0, t)) * (r.area_max - r.area_min)


def _pick_region(regs: Sequence[Region], lam_target: float
                 ) -> Optional[Region]:
    """The region to map ``lam_target`` in.

    Within one tile the paper's rule stands: first containing region in
    lam_max-descending order (fewest ports) — byte-compatible with the
    two-knob engine and with checked-in recordings of its walks.  The
    tile axis makes cross-tile overlap the norm, and there the slowest
    region is frequently a far more expensive large-tile one, so among
    candidates from *different* tiles we take the one expected cheapest
    at the target (legacy order as the deterministic tie-break)."""
    cands = [r for r in regs if r.contains_lambda(lam_target)]
    if not cands:
        return None
    if len({r.tile for r in cands}) <= 1:
        return cands[0]                      # regs is lam_max-descending
    return min(cands, key=lambda r: (_area_at(r, lam_target),
                                     -r.lam_max, r.ports, r.tile))


def map_target(tool: OracleLedger, component: str,
               regions: Sequence[Region], lam_target: float,
               *, max_unroll_bumps: int = 4) -> MapOutcome:
    """Map one component's lam_target to a synthesized implementation."""
    regs = _sorted_regions(regions)
    if not regs:
        raise ValueError(f"{component}: no regions")

    # 1. find the region to map in (cheapest containing lam_target)
    region = _pick_region(regs, lam_target)

    if region is None:
        if lam_target > regs[0].lam_max:
            # slower than every implementation: keep the cheapest point
            r = regs[0]
            s = tool.synthesize(component, unrolls=r.mu_min, ports=r.ports,
                                tile=r.tile)
            return MapOutcome(component, s, r, lam_target, fallback="slowest")
        faster = [r for r in regs if r.lam_max < lam_target]
        if faster:
            # between regions: conservative fallback to the slowest point
            # of the next region with a larger number of ports (already
            # synthesized during characterization -> cache hit).
            r = max(faster, key=lambda r: r.lam_max)
            s = tool.synthesize(component, unrolls=r.mu_min, ports=r.ports,
                                tile=r.tile)
            return MapOutcome(component, s, r, lam_target, fallback="next-region")
        r = min(regs, key=lambda r: r.lam_min)
        s = tool.synthesize(component, unrolls=r.mu_max, ports=r.ports,
                            max_states=(r.facts.h(r.mu_max, r.ports)
                                        if r.facts and r.facts.has_plm_access else None),
                            tile=r.tile)
        return MapOutcome(component, s, r, lam_target, fallback="fastest")

    # 2. Amdahl inverse inside the region
    mu = int(math.ceil(phi(lam_target, region.lam_min, region.lam_max,
                           region.mu_min, region.mu_max)))
    mu = max(region.mu_min, min(region.mu_max, mu))

    last: Optional[Synthesis] = None
    for bump in range(max_unroll_bumps + 1):
        mu_try = min(region.mu_max, mu + bump)
        cap = None
        if region.facts is not None and region.facts.has_plm_access:
            cap = region.facts.h(mu_try, region.ports)
        s = tool.synthesize(component, unrolls=mu_try, ports=region.ports,
                            max_states=cap, tile=region.tile)
        if s.feasible:
            last = s
            if s.lam <= lam_target * (1.0 + 1e-9):
                return MapOutcome(component, s, region, lam_target)
        if mu_try == region.mu_max:
            break
    if last is not None:
        # feasible but misses lam_target: keep it only if within the
        # region bound, else fall through to the next-ports region.
        if last.lam <= region.lam_max + 1e-12 and last.lam <= lam_target * 1.25:
            return MapOutcome(component, last, region, lam_target)

    # 3. trade area for throughput: slowest point of the next region up
    faster = [r for r in regs if r.lam_max < lam_target]
    if faster:
        r = max(faster, key=lambda r: r.lam_max)
        s = tool.synthesize(component, unrolls=r.mu_min, ports=r.ports,
                            tile=r.tile)
        return MapOutcome(component, s, r, lam_target, fallback="next-region")
    r = min(regs, key=lambda r: r.lam_min)
    cap = r.facts.h(r.mu_max, r.ports) if r.facts and r.facts.has_plm_access else None
    s = tool.synthesize(component, unrolls=r.mu_max, ports=r.ports,
                        max_states=cap, tile=r.tile)
    return MapOutcome(component, s, r, lam_target, fallback="fastest")
