"""Simulated HLS tool: a resource-constrained list scheduler + area model.

The paper drives Cadence C-to-Silicon against an industrial 32nm ASIC
library — neither is available here (DESIGN.md Section 2), so this module
is the synthesis *oracle* that COSMOS coordinates.  It is not a stub: it
schedules the component's real loop body (extracted from the jaxpr by
``apps.wami.cdfg``) under port/unroll constraints, reproducing the three
phenomena the paper's methodology exists to handle:

  1. memory dominates — the PLM (from ``core.memgen``) contributes most
     of the area, and the port count moves both latency and area by
     integer factors (Section 3.1);
  2. HLS heuristics are noisy — a deterministic, hash-seeded perturbation
     inserts extra states for controller/resource pressure, growing with
     the unroll factor (Section 3.2, ref [24]), so some syntheses are
     Pareto-dominated and some violate the lambda-constraint;
  3. diminishing returns — load/store phases and dependence depth give
     lambda(u) an Amdahl-shaped profile within a region, which is the
     assumption behind the mapping function phi (Section 6.2).

Everything is deterministic: same knobs => same (lambda, alpha).
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional

from .knobs import CDFGFacts, Synthesis
from .memgen import MemGen, PLMSpec
from .oracle import OracleBatchMixin
from .plm.spec import PLMRequirement

__all__ = ["LoopNest", "ComponentSpec", "HLSTool"]


def _hash01(*key) -> float:
    """Deterministic uniform [0,1) from a knob tuple (heuristic 'noise')."""
    h = hashlib.md5(repr(key).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass(frozen=True)
class LoopNest:
    """The dominant loop of a component, as seen by the scheduler.

    Derived from the jaxpr by ``apps.wami.cdfg.extract`` (or written by
    hand in unit tests).  All counts are per ORIGINAL (un-unrolled)
    iteration.
    """

    trip: int                  # iterations of the dominant loop
    gamma_r: int               # max reads of the same PLM array / iter
    gamma_w: int               # max writes of the same PLM array / iter
    arith_ops: int             # arithmetic ops per iteration
    dep_depth: int             # critical dependence-chain depth (states)
    live_values: int           # values alive across states (register cost)
    has_plm_access: bool = True


@dataclass(frozen=True)
class ComponentSpec:
    """A synthesizable component (SystemC module analogue)."""

    name: str
    loop: LoopNest
    words_in: int              # data loaded into the PLM per execution
    words_out: int             # data stored back per execution
    word_bits: int = 32
    plm_words: int = 0         # PLM capacity; defaults to in+out
    outer_repeats: int = 1     # executions of the loop per accelerator run
    base_tile: int = 0         # native PLM tile edge; 0 = tile-invariant

    def plm_size(self) -> int:
        return self.plm_words or (self.words_in + self.words_out)

    def retile(self, tile: int) -> "ComponentSpec":
        """Rescale the spec to a different PLM tile edge.

        Generic quadratic model: trip / words / PLM capacity scale with
        the tile area, outer repeats inversely (the frame is fixed).  A
        component with ``base_tile == 0`` is tile-invariant and returns
        itself — the tile knob is a no-op for it.  Backends with exact
        per-tile component tables (apps/wami) bypass this via the
        ``HLSTool(retile=...)`` factory instead.
        """
        if not tile or not self.base_tile or tile == self.base_tile:
            return self
        s = (tile / self.base_tile) ** 2
        loop = replace(self.loop, trip=max(1, round(self.loop.trip * s)))
        return replace(
            self, loop=loop,
            words_in=max(1, round(self.words_in * s)),
            words_out=max(1, round(self.words_out * s)),
            plm_words=round(self.plm_words * s) if self.plm_words else 0,
            outer_repeats=max(1, round(self.outer_repeats / s)),
            base_tile=tile)


# 32nm-flavoured area constants (mm^2).  Absolute values are calibrated so
# the WAMI components land in the paper's 0.01-1 mm^2 range; COSMOS's
# claims are about *ratios* (spans, invocation counts), which do not
# depend on the absolute calibration.
_AREA_PER_FU = 4.0e-4          # one arithmetic functional unit (~adder/mul mix)
_AREA_PER_REG = 1.2e-5         # one live 32-bit register
_AREA_CTRL_STATE = 1.0e-5      # controller area per FSM state
_FU_SHARING_EXP = 0.90         # resource sharing: area ~ (ops*u)^0.90
_DMA_WORDS_PER_CYCLE = 8       # 256-bit TLM channel / 32-bit words


class HLSTool(OracleBatchMixin):
    """SynthesisTool backend with the paper's HLS economics.

    Adapts directly to the batched ``Oracle`` protocol via
    :class:`~repro.core.oracle.OracleBatchMixin` (every synthesis is
    pure, so independent knob points fan out over a thread pool).
    ``noise`` scales the heuristic perturbation (0 disables it — useful in
    unit tests of the mapping function's exactness).
    """

    def __init__(self, components: Dict[str, ComponentSpec], *,
                 memgen: Optional[MemGen] = None, noise: float = 1.0,
                 seed: str = "cosmos",
                 retile: Optional[Callable[[int], Dict[str, ComponentSpec]]]
                 = None):
        self.components = dict(components)
        self.memgen = memgen or MemGen()
        self.noise = float(noise)
        self.seed = seed
        # exact per-tile component tables (one call per tile, memoized);
        # absent, ComponentSpec.retile's quadratic model is used
        self._retile = retile
        self._tile_specs: Dict[int, Dict[str, ComponentSpec]] = {}

    def _spec_at(self, component: str, tile: int) -> ComponentSpec:
        base = self.components[component]
        if not tile or tile == base.base_tile:
            return base
        if self._retile is not None:
            specs = self._tile_specs.get(tile)
            if specs is None:
                # benign race: retile factories are pure, setdefault keeps one
                specs = self._tile_specs.setdefault(tile,
                                                    dict(self._retile(tile)))
            if component in specs:
                return specs[component]
        return base.retile(tile)

    def grid_inputs(self, component: str, tile: int
                    ) -> "tuple[ComponentSpec, int]":
        """``(spec, tile_key)`` the scheduler prices at this tile.

        ``tile_key`` is 0 when retiling left the spec unchanged — the
        noise hash must then match the two-knob key exactly (see
        ``_states_per_iter``).  This is the whole-grid pricer's view of
        a component (:mod:`repro.core.pricing` prices every
        ``(ports, unrolls)`` point of one ``grid_inputs`` result in a
        single array dispatch).
        """
        base = self.components[component]
        spec = self._spec_at(component, tile)
        return spec, (0 if spec == base else tile)

    # ------------------------------------------------------------------
    # Scheduling model
    # ------------------------------------------------------------------
    def _states_per_iter(self, spec: ComponentSpec, unrolls: int, ports: int,
                         tile_key: int = 0) -> int:
        """States the scheduler needs for one unrolled loop iteration."""
        ln = spec.loop
        # Memory states: reads from the same array are serialized over the
        # read ports (stencil reads hit scattered addresses and cannot
        # coalesce).  Unrolled writes are unit-stride across interleaved
        # banks, so the write-combining path issues them in
        # ceil(gamma_w/ports) states regardless of the unroll factor —
        # this is why Eq. (1) does not scale gamma_w by the unrolls.
        rd = math.ceil(ln.gamma_r * unrolls / ports) if ln.gamma_r else 0
        wr = math.ceil(ln.gamma_w / ports) if ln.gamma_w else 0
        mem = rd + wr
        # Compute states: the dependence chain overlaps with memory states
        # except for its residue.
        comp = max(1, ln.dep_depth - max(0, mem - 1))
        states = max(1, mem + comp - 1)
        # Heuristic perturbation (Section 3.2, ref [24]): controller and
        # muxing pressure grows with the unrolled body; the scheduler
        # occasionally inserts extra states (which is what makes some
        # syntheses violate the lambda-constraint and some points
        # Pareto-dominated, as in Fig. 4's 7u/8u/9u).
        if self.noise > 0:
            # hash key grows the tile only when it changes the spec, so a
            # native-tile request reproduces the two-knob results exactly
            key = ((self.seed, spec.name, unrolls, ports, tile_key)
                   if tile_key else (self.seed, spec.name, unrolls, ports))
            r = _hash01(*key)
            p_extra = self.noise * (0.08 + 0.012 * unrolls)
            if r < p_extra:
                states += 1 + int(r * 7919) % max(1, unrolls // 4 + 1)
        return states

    def _latency_s(self, spec: ComponentSpec, unrolls: int, ports: int,
                   states: int, clock_ns: float) -> float:
        ln = spec.loop
        groups = math.ceil(ln.trip / unrolls)
        # load/compute/store phases (Fig. 3); load+store via the fixed
        # 256-bit channel, independent of the knobs (Amdahl's serial part).
        cyc_load = math.ceil(spec.words_in / _DMA_WORDS_PER_CYCLE)
        cyc_store = math.ceil(spec.words_out / _DMA_WORDS_PER_CYCLE)
        cyc_compute = groups * states + ln.dep_depth  # + drain
        cycles = (cyc_load + cyc_compute + cyc_store + 12) * spec.outer_repeats
        return cycles * clock_ns * 1e-9

    def _datapath_area(self, spec: ComponentSpec, unrolls: int, states: int) -> float:
        ln = spec.loop
        fus = (ln.arith_ops * unrolls) ** _FU_SHARING_EXP
        regs = ln.live_values * unrolls
        ctrl = states * math.log2(states + 1.0)
        return _AREA_PER_FU * fus + _AREA_PER_REG * regs + _AREA_CTRL_STATE * ctrl

    # ------------------------------------------------------------------
    # SynthesisTool protocol
    # ------------------------------------------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   clock_ns: float = 1.0, tile: int = 0) -> Synthesis:
        spec, tile_key = self.grid_inputs(component, tile)
        states = self._states_per_iter(spec, unrolls, ports, tile_key)
        if max_states is not None and states > max_states:
            # lambda-constraint violated: the synthesis fails and the
            # point is discarded (Algorithm 1 lines 5-7).
            return Synthesis(lam=float("inf"), area=float("inf"), ports=ports,
                             unrolls=unrolls, states_per_iter=states,
                             feasible=False, tile=tile)
        lam = self._latency_s(spec, unrolls, ports, states, clock_ns)
        area = self._datapath_area(spec, unrolls, states)
        plm = self.memgen.generate(PLMSpec(
            words=spec.plm_size(), word_bits=spec.word_bits, ports=ports))
        return Synthesis(lam=lam, area=area + plm.area, ports=ports,
                         unrolls=unrolls, states_per_iter=states,
                         feasible=True,
                         detail={"area_logic": area, "area_plm": plm.area,
                                 "banks": float(plm.banks),
                                 "plm_words": float(spec.plm_size()),
                                 "word_bits": float(spec.word_bits)},
                         tile=tile)

    def plm_requirement(self, component: str, synth: Synthesis
                        ) -> PLMRequirement:
        """What the synthesized point demands of the memory subsystem —
        the input of the system-level PLM planner (core.plm.planner)."""
        spec = self._spec_at(component, synth.tile)
        area_plm = synth.detail.get("area_plm")
        if area_plm is None:
            area_plm = self.memgen.generate(PLMSpec(
                words=spec.plm_size(), word_bits=spec.word_bits,
                ports=synth.ports)).area
        logic = synth.detail.get("area_logic", synth.area - area_plm)
        return PLMRequirement(component=component, capacity=spec.plm_size(),
                              word_bits=spec.word_bits, ports=synth.ports,
                              area_plm=float(area_plm),
                              area_logic=float(logic), unit="mm2")

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        """Eq. (1) inputs 'inferred by traversing the CDFG created by the
        HLS tool for scheduling the lower-right point' (Section 5)."""
        ln = self._spec_at(component, synth.tile).loop
        # eta: states not attributable to PLM accesses, observed on the
        # synthesized lower-right point.
        mem_states = (math.ceil(ln.gamma_r * synth.unrolls / synth.ports)
                      + math.ceil(ln.gamma_w / synth.ports))
        eta = max(1, synth.states_per_iter - mem_states)
        return CDFGFacts(gamma_r=ln.gamma_r, gamma_w=ln.gamma_w, eta=eta,
                         trip=ln.trip, has_plm_access=ln.has_plm_access)
