"""Synthesis planning — the theta-constrained cost-minimization LP (Eq. 2).

    min   sum_i f_i(tau_i)
    s.t.  A sigma + M0/theta >= tau^-          (one row per place)
          lam_min_i <= tau_i <= lam_max_i

where A is the TMG incidence matrix (Eq. 3), M0 the initial marking,
sigma the transition initiation times and tau^-_i the firing delay of the
transition feeding place i.  The cost functions f_i are unknown a-priori
and are approximated with convex piecewise-linear functions built from
the region corners produced by Algorithm 1 (Section 6.1) — implemented
here as the lower convex envelope of the corner points, entering the LP
through epigraph variables.

The LP is solved with scipy's HiGHS when available and with a small
self-contained dense simplex otherwise, so the repository runs with only
jax + numpy + pytest installed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .knobs import Region
from .tmg import TMG

__all__ = [
    "PiecewiseLinearCost",
    "ComponentModel",
    "Schedule",
    "PlanPoint",
    "theta_bounds",
    "plan",
    "sweep",
]


# ----------------------------------------------------------------------
# Convex piecewise-linear cost approximation
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class PiecewiseLinearCost:
    """f(tau) = max_k (a_k * tau + b_k): convex, decreasing in latency.

    Built as the lower convex envelope of the characterized (lambda,
    alpha) corner points, which is the tightest convex under-approximation
    available from Algorithm 1's output.
    """

    slopes: Tuple[float, ...]
    intercepts: Tuple[float, ...]

    def __call__(self, tau: float) -> float:
        return max(a * tau + b for a, b in zip(self.slopes, self.intercepts))

    @staticmethod
    def from_points(points: Sequence[Tuple[float, float]]) -> "PiecewiseLinearCost":
        """Lower convex hull (Andrew monotone chain, lower part) of
        (lambda, alpha) points -> segment slopes/intercepts."""
        pts = sorted(set(points))
        if not pts:
            raise ValueError("no points")
        if len(pts) == 1:
            (x, y), = pts
            return PiecewiseLinearCost(slopes=(0.0,), intercepts=(y,))
        hull: List[Tuple[float, float]] = []
        for p in pts:
            while len(hull) >= 2:
                (x1, y1), (x2, y2) = hull[-2], hull[-1]
                # drop hull[-1] if it lies above segment hull[-2]->p
                if (y2 - y1) * (p[0] - x1) >= (p[1] - y1) * (x2 - x1):
                    hull.pop()
                else:
                    break
            hull.append(p)
        slopes, intercepts = [], []
        for (x1, y1), (x2, y2) in zip(hull, hull[1:]):
            if x2 == x1:
                continue
            a = (y2 - y1) / (x2 - x1)
            slopes.append(a)
            intercepts.append(y1 - a * x1)
        if not slopes:  # all points at the same lambda
            ymin = min(y for _, y in pts)
            slopes, intercepts = [0.0], [ymin]
        return PiecewiseLinearCost(slopes=tuple(slopes), intercepts=tuple(intercepts))


@dataclass(frozen=True)
class ComponentModel:
    """What the planner knows about one transition after characterization."""

    name: str
    lam_min: float
    lam_max: float
    cost: PiecewiseLinearCost
    fixed: bool = False          # e.g. Matrix-Inv runs in software (Fig. 8)

    @staticmethod
    def from_regions(name: str, regions: Sequence[Region]) -> "ComponentModel":
        pts: List[Tuple[float, float]] = []
        for r in regions:
            pts.append((r.lam_max, r.area_min))
            pts.append((r.lam_min, r.area_max))
        return ComponentModel(
            name=name,
            lam_min=min(r.lam_min for r in regions),
            lam_max=max(r.lam_max for r in regions),
            cost=PiecewiseLinearCost.from_points(pts),
        )

    @staticmethod
    def fixed_latency(name: str, lam: float, area: float = 0.0) -> "ComponentModel":
        return ComponentModel(name=name, lam_min=lam, lam_max=lam,
                              cost=PiecewiseLinearCost((0.0,), (area,)),
                              fixed=True)


@dataclass(frozen=True)
class Schedule:
    """The periodic schedule the LP solved for: firing k of transition i
    starts at ``sigma[i] + k * period`` and holds its resources for
    ``tau[i]``.  Admissibility is exactly the Eq. (2) place constraints,
    so a returned Schedule is always a feasible steady-state execution
    of the TMG at throughput ``theta``.

    The schedule used to be solved and discarded; it is now first-class
    because the static-analysis layer (:mod:`repro.core.analysis`)
    derives schedule-conditional non-concurrency certificates from the
    busy intervals ``[sigma_i, sigma_i + tau_i) mod period``.
    """

    theta: float
    sigma: Dict[str, float]           # transition initiation offsets (s)
    tau: Dict[str, float]             # planned firing delays (s)

    @property
    def period(self) -> float:
        return 1.0 / self.theta

    def tag(self) -> str:
        """A short stable identifier of the design point this schedule
        (and any certificate derived from it) holds under."""
        return f"theta={self.theta:.9g}"

    def to_json(self) -> Dict[str, object]:
        return {"theta": self.theta, "sigma": dict(self.sigma),
                "tau": dict(self.tau)}

    @staticmethod
    def from_json(d: Dict[str, object]) -> "Schedule":
        return Schedule(theta=float(d["theta"]),
                        sigma={k: float(v) for k, v in d["sigma"].items()},
                        tau={k: float(v) for k, v in d["tau"].items()})


@dataclass(frozen=True)
class PlanPoint:
    """One LP solution along the theta sweep (a 'planned point', Fig. 10)."""

    theta: float
    cost: float                       # sum_i f_i(tau_i): theoretical area
    lam_targets: Dict[str, float]     # per-component latency requirements
    schedule: Optional[Schedule] = None   # the solved sigma/tau behind it


# ----------------------------------------------------------------------
# Bounds
# ----------------------------------------------------------------------
def theta_bounds(tmg: TMG, models: Dict[str, ComponentModel]) -> Tuple[float, float]:
    """theta_min from all-slowest corners, theta_max from all-fastest
    (Section 6.1: 'it is possible to determine theta_min and theta_max by
    labeling the transitions of the TMG with such latencies')."""
    slow = {n: m.lam_max for n, m in models.items()}
    fast = {n: m.lam_min for n, m in models.items()}
    return tmg.throughput(slow), tmg.throughput(fast)


# ----------------------------------------------------------------------
# LP assembly + solve
# ----------------------------------------------------------------------
def _solve_lp(c, A_ub, b_ub, bounds):
    try:
        from scipy.optimize import linprog
        res = linprog(c, A_ub=A_ub, b_ub=b_ub, bounds=bounds, method="highs")
        if not res.success:
            return None
        return np.asarray(res.x)
    except ImportError:  # pragma: no cover - exercised via _simplex tests
        return _simplex(c, A_ub, b_ub, bounds)


def _simplex(c, A_ub, b_ub, bounds):
    """Dependency-free fallback: convert to standard form and run a dense
    big-M simplex with Bland's rule.  Small problems only (n, m < ~200)."""
    c = np.asarray(c, dtype=float)
    A = np.asarray(A_ub, dtype=float)
    b = np.asarray(b_ub, dtype=float)
    n = c.size
    # shift variables to x' = x - lo >= 0; handle free vars via splitting
    shift = np.zeros(n)
    split = []
    for j, (lo, hi) in enumerate(bounds):
        if lo is None:
            split.append(j)
        else:
            shift[j] = lo
    b = b - A @ shift
    ub_rows, ub_rhs = [], []
    for j, (lo, hi) in enumerate(bounds):
        if hi is not None:
            row = np.zeros(n)
            row[j] = 1.0
            ub_rows.append(row)
            ub_rhs.append(hi - shift[j])
    if ub_rows:
        A = np.vstack([A] + [r[None, :] for r in ub_rows])
        b = np.concatenate([b, np.asarray(ub_rhs)])
    # split free variables x_j = u_j - v_j
    if split:
        A = np.hstack([A, -A[:, split]])
        c = np.concatenate([c, -c[split]])
        n = c.size
    m = A.shape[0]
    # slack + artificial (big-M) for negative rhs rows
    T = np.hstack([A, np.eye(m)])
    cc = np.concatenate([c, np.zeros(m)])
    basis = list(range(n, n + m))
    bigM = 1e9
    for i in range(m):
        if b[i] < 0:
            T[i, :] *= -1.0
            b = b.copy()
            b[i] *= -1.0
            art = np.zeros((m, 1)); art[i, 0] = 1.0
            T = np.hstack([T, art])
            cc = np.concatenate([cc, [bigM]])
            basis[i] = T.shape[1] - 1
    # simplex iterations
    for _ in range(20000):
        y = np.linalg.solve(T[:, basis].T, cc[basis])
        red = cc - y @ T
        enter = next((j for j in range(T.shape[1]) if red[j] < -1e-9), None)
        if enter is None:
            break
        d = np.linalg.solve(T[:, basis], T[:, enter])
        ratios = [(b_i / d_i, i) for i, (b_i, d_i) in
                  enumerate(zip(np.linalg.solve(T[:, basis], b), d)) if d_i > 1e-12]
        if not ratios:
            return None  # unbounded
        _, leave = min(ratios)
        basis[leave] = enter
    xb = np.linalg.solve(T[:, basis], b)
    x_full = np.zeros(T.shape[1])
    x_full[basis] = xb
    x = x_full[:n]
    if split:
        base = x[: n - len(split)].copy()
        for k, j in enumerate(split):
            base[j] = base[j] - x[n - len(split) + k]
        x = base
    return x + shift


def plan(tmg: TMG, models: Dict[str, ComponentModel], theta: float
         ) -> Optional[PlanPoint]:
    """Solve Eq. (2) for a single target throughput theta."""
    names = [t.name for t in tmg.transitions]
    for nme in names:
        if nme not in models:
            raise KeyError(f"no model for transition {nme}")
    n = len(names)
    A = tmg.incidence_matrix()          # m x n
    B = tmg.input_delay_selector()      # m x n
    M0 = tmg.initial_marking()
    m = A.shape[0]

    # variable layout: [sigma (n), tau (n), epigraph c (n)]
    nv = 3 * n
    rows: List[np.ndarray] = []
    rhs: List[float] = []

    # place rows:  -(A sigma - B tau) <= M0/theta
    for i in range(m):
        row = np.zeros(nv)
        row[0:n] = -A[i]
        row[n:2 * n] = B[i]
        rows.append(row)
        rhs.append(M0[i] / theta)

    # epigraph rows: a_k tau_i - c_i <= -b_k
    for i, nme in enumerate(names):
        mdl = models[nme]
        for a, bb in zip(mdl.cost.slopes, mdl.cost.intercepts):
            row = np.zeros(nv)
            row[n + i] = a
            row[2 * n + i] = -1.0
            rows.append(row)
            rhs.append(-bb)

    A_ub = np.vstack(rows)
    b_ub = np.asarray(rhs)

    bounds: List[Tuple[Optional[float], Optional[float]]] = []
    bounds += [(None, None)] * n                      # sigma free
    for nme in names:                                  # tau bounded
        mdl = models[nme]
        bounds.append((mdl.lam_min, mdl.lam_max))
    bounds += [(None, None)] * n                      # c free (epigraph)
    # pin sigma_0 (initiation times are translation-invariant)
    bounds[0] = (0.0, 0.0)

    c = np.zeros(nv)
    c[2 * n:] = 1.0

    x = _solve_lp(c, A_ub, b_ub, bounds)
    if x is None:
        return None
    sigma = {nme: float(x[i]) for i, nme in enumerate(names)}
    tau = {nme: float(x[n + i]) for i, nme in enumerate(names)}
    cost = float(sum(models[nme].cost(tau[nme]) for nme in names))
    return PlanPoint(theta=theta, cost=cost, lam_targets=tau,
                     schedule=Schedule(theta=theta, sigma=sigma, tau=tau))


def sweep(tmg: TMG, models: Dict[str, ComponentModel], delta: float,
          theta_min: Optional[float] = None, theta_max: Optional[float] = None
          ) -> List[PlanPoint]:
    """Problem 1 sweep: iterate theta from theta_min to theta_max with a
    ratio of (1 + delta) (Section 6.1), solving Eq. (2) at each step."""
    lo, hi = theta_bounds(tmg, models)
    theta_min = lo if theta_min is None else theta_min
    theta_max = hi if theta_max is None else theta_max
    out: List[PlanPoint] = []
    theta = theta_min
    while theta < theta_max * (1.0 + 1e-9):
        pt = plan(tmg, models, theta)
        if pt is not None:
            out.append(pt)
        theta *= (1.0 + delta)
    # always include the extreme
    if not out or abs(out[-1].theta - theta_max) / theta_max > 1e-9:
        pt = plan(tmg, models, theta_max)
        if pt is not None:
            out.append(pt)
    return out
