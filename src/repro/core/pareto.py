"""Pareto utilities: dominance, fronts, spans, and delta-granularity curves.

Two dominance conventions coexist in the paper, and every function here
is explicitly suffixed with the one it uses — mixing them silently
inverts a front:

  * **min-min** (components): performance is the effective latency
    lambda and cost is the area alpha, both minimized.  Algorithm 1
    regions, per-component fronts, and the exhaustive per-component
    sweep (``exhaustive_dse``) live here.
  * **max-min** (systems): performance is the effective throughput
    theta, MAXIMIZED, while cost alpha is still minimized.  Fig. 10's
    system curve, ``CosmosResult.pareto()``, and the delta-granularity
    condition of Problem 1 live here.

``span`` (max/min ratio over one metric, Section 1.3 / Table 1) is
convention-free; ``check_delta_curve`` is max-min by definition.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    # the point type
    "DesignPoint",
    # min-min convention (components: lambda down, alpha down)
    "dominates_min_min",
    "pareto_front_min_min",
    # max-min convention (systems: theta up, alpha down)
    "dominates_max_min",
    "pareto_front_max_min",
    "check_delta_curve",
    # convention-free diagnostics
    "span",
]


@dataclass(frozen=True)
class DesignPoint:
    """A synthesized implementation.

    ``perf``: latency (component view) or throughput (system view).
    ``cost``: area (mm^2 for hlsim; HBM bytes/device for the TPU tool).
    ``knobs``: the knob assignment that produced it.
    ``meta``: free-form (e.g. per-component lambda breakdown at system level).
    """

    perf: float
    cost: float
    knobs: Tuple[Tuple[str, int], ...] = ()
    meta: Tuple[Tuple[str, float], ...] = ()

    def knob(self, name: str) -> int:
        return dict(self.knobs)[name]


def dominates_min_min(a: DesignPoint, b: DesignPoint) -> bool:
    """a dominates b under the COMPONENT convention: both metrics
    minimized (perf = latency lambda, cost = area alpha).  Dominance is
    strict — no-worse on both axes AND strictly better on at least one,
    so duplicated points never dominate each other."""
    return (a.perf <= b.perf and a.cost <= b.cost) and (a.perf < b.perf or a.cost < b.cost)


def dominates_max_min(a: DesignPoint, b: DesignPoint) -> bool:
    """a dominates b under the SYSTEM convention: perf = throughput
    theta MAXIMIZED, cost = area alpha minimized.  Strict in the same
    sense as :func:`dominates_min_min`."""
    return (a.perf >= b.perf and a.cost <= b.cost) and (a.perf > b.perf or a.cost < b.cost)


def _front(points: Sequence[DesignPoint], dom) -> List[DesignPoint]:
    pts = list(points)
    out: List[DesignPoint] = []
    for p in pts:
        if not any(dom(q, p) for q in pts if q is not p):
            out.append(p)
    # dedupe identical (perf, cost) pairs
    seen, uniq = set(), []
    for p in out:
        key = (p.perf, p.cost)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def pareto_front_min_min(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Pareto-optimal subset under the component (min-min) convention,
    deduplicated on (perf, cost) and sorted by ascending latency — the
    left-to-right order of a Fig. 4 component curve."""
    return sorted(_front(points, dominates_min_min), key=lambda p: (p.perf, p.cost))


def pareto_front_max_min(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Pareto-optimal subset under the system (max-min) convention,
    deduplicated on (perf, cost) and sorted by ascending throughput —
    the left-to-right order of the Fig. 10 system curve (costs ascend
    with it, or the point would be dominated)."""
    return sorted(_front(points, dominates_max_min), key=lambda p: (p.perf, p.cost))


def span(values: Iterable[float]) -> float:
    """max/min ratio over one metric (the paper's lambda_span /
    alpha_span, Section 1.3 / Table 1).

    Returns 1.0 for an empty set (a degenerate single-point space) and
    +inf when the minimum is non-positive — an infeasible latency/area
    should never reach here, so the inf flags the upstream bug instead
    of masking it.
    """
    vals = [v for v in values]
    if not vals:
        return 1.0
    lo, hi = min(vals), max(vals)
    if lo <= 0:
        return float("inf")
    return hi / lo


def check_delta_curve(points: Sequence[DesignPoint], delta: float) -> bool:
    """Problem 1 condition (i), on the max-min (system) front of
    ``points``: consecutive Pareto points d, d' (ascending theta) must
    satisfy max(d'_alpha/d_alpha - 1, d'_theta/d_theta - 1) < delta.

    Returns False for fronts containing non-positive coordinates (the
    ratios would be meaningless).  The tolerance term absorbs float
    error at the boundary gap == delta, which counts as satisfied.
    """
    front = pareto_front_max_min(points)
    for d, d2 in zip(front, front[1:]):
        if d.perf <= 0 or d.cost <= 0:
            return False
        gap = max(d2.cost / d.cost - 1.0, d2.perf / d.perf - 1.0)
        if gap >= delta + 1e-12:
            return False
    return True
