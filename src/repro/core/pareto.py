"""Pareto utilities: dominance, fronts, spans, and delta-granularity curves.

Conventions follow the paper:
  * a design point is (performance, cost); for components performance is
    the effective latency lambda (lower is better) and cost is the area
    alpha (lower is better);
  * for systems, performance is the effective throughput theta (HIGHER is
    better) and cost is alpha (lower is better);
  * span = max/min over a point set for one metric (Section 1.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "DesignPoint",
    "dominates_min_min",
    "dominates_max_min",
    "pareto_front_min_min",
    "pareto_front_max_min",
    "span",
    "check_delta_curve",
]


@dataclass(frozen=True)
class DesignPoint:
    """A synthesized implementation.

    ``perf``: latency (component view) or throughput (system view).
    ``cost``: area (mm^2 for hlsim; HBM bytes/device for the TPU tool).
    ``knobs``: the knob assignment that produced it.
    ``meta``: free-form (e.g. per-component lambda breakdown at system level).
    """

    perf: float
    cost: float
    knobs: Tuple[Tuple[str, int], ...] = ()
    meta: Tuple[Tuple[str, float], ...] = ()

    def knob(self, name: str) -> int:
        return dict(self.knobs)[name]


def dominates_min_min(a: DesignPoint, b: DesignPoint) -> bool:
    """a dominates b when both metrics are to be minimized (lambda, alpha)."""
    return (a.perf <= b.perf and a.cost <= b.cost) and (a.perf < b.perf or a.cost < b.cost)


def dominates_max_min(a: DesignPoint, b: DesignPoint) -> bool:
    """a dominates b when perf=theta is maximized and cost minimized."""
    return (a.perf >= b.perf and a.cost <= b.cost) and (a.perf > b.perf or a.cost < b.cost)


def _front(points: Sequence[DesignPoint], dom) -> List[DesignPoint]:
    pts = list(points)
    out: List[DesignPoint] = []
    for p in pts:
        if not any(dom(q, p) for q in pts if q is not p):
            out.append(p)
    # dedupe identical (perf, cost) pairs
    seen, uniq = set(), []
    for p in out:
        key = (p.perf, p.cost)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def pareto_front_min_min(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Pareto-optimal subset, both metrics minimized, sorted by perf."""
    return sorted(_front(points, dominates_min_min), key=lambda p: (p.perf, p.cost))


def pareto_front_max_min(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Pareto-optimal subset for (throughput up, cost down), sorted by perf."""
    return sorted(_front(points, dominates_max_min), key=lambda p: (p.perf, p.cost))


def span(values: Iterable[float]) -> float:
    """max/min ratio (the paper's lambda_span / alpha_span, Table 1)."""
    vals = [v for v in values]
    if not vals:
        return 1.0
    lo, hi = min(vals), max(vals)
    if lo <= 0:
        return float("inf")
    return hi / lo


def check_delta_curve(points: Sequence[DesignPoint], delta: float) -> bool:
    """Problem 1 condition (i): consecutive Pareto points d, d' must satisfy
    max(d'_alpha/d_alpha - 1, d'_theta/d_theta - 1) < delta."""
    front = pareto_front_max_min(points)
    for d, d2 in zip(front, front[1:]):
        if d.perf <= 0 or d.cost <= 0:
            return False
        gap = max(d2.cost / d.cost - 1.0, d2.perf / d.perf - 1.0)
        if gap >= delta + 1e-12:
            return False
    return True
