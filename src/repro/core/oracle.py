"""The unified synthesis-oracle layer: one query interface for every backend.

COSMOS's headline result is *invocation frugality* — the system-level
Pareto front is recovered with up to 14.6x fewer tool calls than the
exhaustive baseline (Fig. 11) — so the seam between the DSE engine and
the expensive tool is the load-bearing interface of the repository.  This
module defines it once, for every oracle:

  * :class:`InvocationRequest` — one knob point to price/synthesize;
  * :class:`Oracle` — the protocol: ``evaluate`` one request or
    ``evaluate_batch`` many (independent knob points fan out over a
    thread pool, since every hlsim/XLA invocation is pure);
  * :class:`OracleLedger` — the accounting + caching layer that subsumes
    the old ``CountingTool``: repeats are cached and NOT counted
    (Section 7.3), infeasible points ARE counted (Fig. 11 includes the
    lambda-constraint discards), identical invocations issued
    concurrently are de-duplicated in flight, and every real tool call
    leaves a structured :class:`InvocationRecord`;
  * :class:`PersistentOracleCache` — a pluggable cache backed by
    :mod:`repro.checkpoint.store`, so a killed DSE run resumes without
    re-invoking the tool for any point it already paid for.

``CountingTool`` remains as a thin legacy alias so the seed's published
surface keeps working.
"""

from __future__ import annotations

import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

from .knobs import CDFGFacts, Synthesis
from .obs import NULL_TRACER, MetricsRegistry, OUTCOMES

__all__ = [
    "InvocationRequest",
    "InvocationRecord",
    "Oracle",
    "OracleBatchMixin",
    "OracleCache",
    "PersistentOracleCache",
    "OracleLedger",
    "SharedOracle",
    "CountingTool",
    "call_synthesize",
]


def call_synthesize(tool, component: str, *, unrolls: int, ports: int,
                    max_states: Optional[int] = None,
                    tile: int = 0) -> Synthesis:
    """Invoke ``tool.synthesize`` forwarding ``tile`` only when set.

    The single place that encodes the compatibility rule for the tile
    knob: two-knob backends (and pre-tile user tools) never see the
    keyword, so they keep working unchanged.
    """
    if tile:
        return tool.synthesize(component, unrolls=unrolls, ports=ports,
                               max_states=max_states, tile=tile)
    return tool.synthesize(component, unrolls=unrolls, ports=ports,
                           max_states=max_states)

# key type used everywhere below:
# (component, unrolls, ports, max_states, tile); tile 0 = native tile
Key = Tuple[str, int, int, Optional[int], int]


@dataclass(frozen=True)
class InvocationRequest:
    """One knob point submitted to an oracle.

    ``max_states`` carries the lambda-constraint of Algorithm 1 (the
    synthesis fails when the scheduler cannot fit an iteration within
    that many states); ``None`` means unconstrained.  ``tile`` is the
    third knob axis (PLM tile edge); 0 means the component's native
    tile, and is the only value two-knob backends ever see.
    """

    component: str
    unrolls: int
    ports: int
    max_states: Optional[int] = None
    tile: int = 0

    @property
    def key(self) -> Key:
        return (self.component, self.unrolls, self.ports, self.max_states,
                self.tile)


@dataclass(frozen=True)
class InvocationRecord:
    """One *real* tool call, as accounted in Fig. 11.

    Cache hits never produce a record — a record is money spent.
    ``phase`` tags which DSE phase paid for it (characterize/map/...),
    which is what the invocation-breakdown benchmarks aggregate.
    """

    component: str
    unrolls: int
    ports: int
    max_states: Optional[int]
    feasible: bool
    lam: float
    area: float
    phase: str = ""
    wall_s: float = 0.0
    tile: int = 0


@runtime_checkable
class Oracle(Protocol):
    """The expensive oracle COSMOS coordinates, batched form.

    ``evaluate`` prices/synthesizes a single knob point.
    ``evaluate_batch`` prices many *independent* points; implementations
    are free to fan out (thread pool, async compile service, RPC) as long
    as results come back in request order.  ``cdfg_facts`` exposes the
    Eq. (1) inputs extracted from a completed synthesis.
    """

    def evaluate(self, request: InvocationRequest) -> Synthesis: ...

    def evaluate_batch(self, requests: Sequence[InvocationRequest]
                       ) -> List[Synthesis]: ...

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts: ...


class OracleBatchMixin:
    """Adapts a ``synthesize``-style SynthesisTool to the Oracle protocol.

    Backends inherit this and only implement ``synthesize`` (+
    ``cdfg_facts``); the default batch is a thread-pool fan-out, valid
    because every backend invocation in this repo is pure.
    """

    batch_workers: int = 8
    #: class-level default: tracing is off unless a backend instance is
    #: handed a real tracer (``tool.tracer = tracer``)
    tracer = NULL_TRACER

    def evaluate(self, request: InvocationRequest) -> Synthesis:
        with self.tracer.span("tool.point", component=request.component,
                              unrolls=request.unrolls,
                              ports=request.ports, tile=request.tile):
            return call_synthesize(self, request.component,
                                   unrolls=request.unrolls,
                                   ports=request.ports,
                                   max_states=request.max_states,
                                   tile=request.tile)

    def evaluate_batch(self, requests: Sequence[InvocationRequest],
                       *, workers: Optional[int] = None) -> List[Synthesis]:
        reqs = list(requests)
        n = workers or self.batch_workers
        with self.tracer.span("tool.batch", n=len(reqs)):
            if len(reqs) <= 1 or n <= 1:
                return [self.evaluate(r) for r in reqs]
            with ThreadPoolExecutor(max_workers=min(n, len(reqs))) as pool:
                return list(pool.map(self.evaluate, reqs))


def _adopt_tracer(tool: Any, tracer: Any) -> None:
    """Hand a ledger/shared-oracle tracer down to its tool so
    ``tool.point``/``tool.batch`` spans land in the same trace.  Only
    fills the vacancy: a tool already wired to a real tracer keeps it,
    and tools without a ``tracer`` attribute are left alone."""
    if tracer is NULL_TRACER:
        return
    if getattr(tool, "tracer", _adopt_tracer) in (None, NULL_TRACER):
        try:
            tool.tracer = tracer
        except AttributeError:
            pass


# ----------------------------------------------------------------------
# Caches
# ----------------------------------------------------------------------
class OracleCache(Protocol):
    """Pluggable persistence for oracle results (keyed by knob point)."""

    def entries(self) -> Dict[Key, Synthesis]: ...

    def put(self, key: Key, synth: Synthesis) -> None: ...


def _synth_to_json(s: Synthesis) -> Dict[str, Any]:
    return {"lam": s.lam, "area": s.area, "ports": s.ports,
            "unrolls": s.unrolls, "states": s.states_per_iter,
            "feasible": s.feasible, "detail": dict(s.detail),
            "tile": s.tile}


def _synth_from_json(d: Dict[str, Any]) -> Synthesis:
    return Synthesis(lam=d["lam"], area=d["area"], ports=d["ports"],
                     unrolls=d["unrolls"], states_per_iter=d["states"],
                     feasible=d["feasible"], detail=dict(d["detail"]),
                     tile=d.get("tile", 0))


class PersistentOracleCache:
    """Synthesis results persisted via :mod:`repro.checkpoint.store`.

    Each flush writes the *whole* cache as one atomic checkpoint step
    (store's rename protocol: a crash leaves the previous complete step,
    never a torn one), then prunes older steps.  A killed DSE run that
    restarts with the same ``root`` resumes with every flushed
    invocation served from here.  Flushes are batched (a full rewrite
    per put would be O(n^2) disk I/O): a hard kill can lose at most the
    last ``flush_every - 1`` points — they are simply re-invoked on
    resume — and the ledger flushes the remainder when a session
    completes.  Set ``flush_every=1`` for per-invocation durability.

    ``root=None`` keeps the cache purely in memory (no store behind it)
    — what a :class:`SharedOracle` pool uses when the service has no
    durable cache directory configured.

    ``max_entries`` bounds the cache with LRU eviction: :meth:`get` and
    :meth:`put` move the key to most-recently-used, and a put beyond
    the bound drops the least-recently-used entry entirely — from
    memory *and* from the next flush, so an evicted point is re-invoked
    (exactly once) if it is ever needed again.  ``hits`` / ``misses`` /
    ``evictions`` count :meth:`get`/:meth:`put` traffic for the service
    soak bench; the bulk :meth:`entries` pre-seed path counts nothing
    and does not touch recency.
    """

    def __init__(self, root: Optional[str] = None, *, flush_every: int = 16,
                 keep: int = 2, max_entries: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None, name: str = ""):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.root = root
        self.name = name
        self.flush_every = max(1, flush_every)
        self.keep = max(1, keep)
        self.max_entries = max_entries
        # traffic counters live in a metrics registry (lock-consistent by
        # construction); the historical bare-int names remain as read-only
        # properties below
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        prefix = f"cache.{name}." if name else "cache."
        self._hits = self.metrics.counter(prefix + "hits")
        self._misses = self.metrics.counter(prefix + "misses")
        self._evictions = self.metrics.counter(prefix + "evictions")
        self._entries: Dict[Key, Synthesis] = {}
        self._restored: set = set()
        self._dirty = 0
        self._lock = threading.Lock()
        if root is not None:
            self._load()

    # -- store glue ----------------------------------------------------
    @staticmethod
    def _store():
        from ..checkpoint import store       # lazy: store imports jax
        return store

    def _load(self) -> None:
        import numpy as np
        store = self._store()
        step = store.latest_step(self.root)
        if step is None:
            return
        _, extra = store.restore(self.root, step,
                                 {"n_entries": np.asarray(0)})
        for rec in extra.get("entries", []):
            # pre-tile caches persisted 4-element keys; they reload as
            # native-tile (tile=0) points
            comp, unrolls, ports, max_states, *rest = rec["key"]
            tile = int(rest[0]) if rest else 0
            key = (comp, int(unrolls), int(ports),
                   None if max_states is None else int(max_states), tile)
            self._entries[key] = _synth_from_json(rec["synth"])
            self._restored.add(key)
        if self.max_entries is not None:
            # a persisted cache larger than the bound trims oldest-first
            # (flush order is insertion order) — not counted as traffic
            while len(self._entries) > self.max_entries:
                oldest = next(iter(self._entries))
                self._entries.pop(oldest)
                self._restored.discard(oldest)

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if self._dirty == 0 or self.root is None:
            return
        import numpy as np
        store = self._store()
        step = (store.latest_step(self.root) or 0) + 1
        payload = [{"key": list(k), "synth": _synth_to_json(s)}
                   for k, s in self._entries.items()]
        store.save(self.root, step,
                   {"n_entries": np.asarray(len(payload))},
                   extra={"entries": payload})
        self._dirty = 0
        for old in store.list_steps(self.root)[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{old:08d}"),
                          ignore_errors=True)

    # -- OracleCache protocol ------------------------------------------
    def entries(self) -> Dict[Key, Synthesis]:
        with self._lock:
            return dict(self._entries)

    def get(self, key: Key) -> Optional[Synthesis]:
        """LRU-aware lookup: a hit refreshes the key's recency."""
        with self._lock:
            hit = self._entries.pop(key, None)
            if hit is None:
                self._misses.inc()
                return None
            self._entries[key] = hit          # re-insert: most recent
            self._hits.inc()
            return hit

    def put(self, key: Key, synth: Synthesis) -> None:
        with self._lock:
            self._entries.pop(key, None)      # refresh recency on rewrite
            self._restored.discard(key)       # freshly paid for, not replay
            self._entries[key] = synth
            if self.max_entries is not None:
                while len(self._entries) > self.max_entries:
                    oldest = next(iter(self._entries))
                    self._entries.pop(oldest)
                    self._restored.discard(oldest)
                    self._evictions.inc()
            self._dirty += 1
            if self._dirty >= self.flush_every:
                self._flush_locked()

    def was_restored(self, key: Key) -> bool:
        """True when ``key``'s current entry came from the persisted
        store rather than being paid for during this process — the
        ``replay`` leg of the per-point outcome partition."""
        with self._lock:
            return key in self._restored

    def consume_restored(self, key: Key) -> bool:
        """:meth:`was_restored` with consume semantics: True exactly
        once per restored entry.  The first serve from a restored
        entry is the ``replay`` (it reconciles one-for-one against the
        restored invocation accounting); after that the entry behaves
        like any other cache entry and further serves are plain hits."""
        with self._lock:
            if key in self._restored:
                self._restored.discard(key)
                return True
            return False

    # historical bare-int counter names, now registry-backed (read-only)
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def stats(self) -> Dict[str, int]:
        with self._lock:
            entries = len(self._entries)
        return {"entries": entries, "hits": self._hits.value,
                "misses": self._misses.value,
                "evictions": self._evictions.value}

    def __len__(self) -> int:
        return len(self._entries)


# ----------------------------------------------------------------------
# Cross-tenant coalescing (the DSE-service substrate)
# ----------------------------------------------------------------------
class _Flight:
    """Rendezvous for one in-flight knob point: waiters hold a reference,
    so the result survives even if the shared cache evicts it before
    every joiner has read it."""

    __slots__ = ("event", "result", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.result: Optional[Synthesis] = None
        self.error: Optional[BaseException] = None


class SharedOracle:
    """One base tool multiplexed across many concurrent submitters.

    The multi-tenant seam of the DSE service
    (:mod:`repro.serve.dse_service`): every tenant wraps this in its own
    :class:`OracleLedger` (per-tenant Fig. 11 attribution, identical to
    an isolated run), while the SharedOracle dedups the *real* tool
    traffic across all of them:

      * a shared :class:`PersistentOracleCache` (optionally LRU-bounded)
        answers repeats from any tenant without a tool call;
      * identical points submitted concurrently join one in-flight call
        (``joins`` counts the coalesced waiters);
      * distinct points pending at the same moment are drained by a
        single dispatcher thread into ONE ``evaluate_batch`` call on the
        base tool — natural batching: while a batch is in flight, new
        arrivals accumulate for the next drain, so no timing window is
        needed and results stay deterministic per key.

    Errors are per-key and never cached: a batch that raises is re-priced
    point-by-point so the exception reaches exactly the tenants that
    asked for the failing key (``batch_retries`` counts these passes —
    the re-invocations they cost are the price of attribution, paid only
    on the failure path), and a later retry of that key dispatches (and
    counts) again, exactly like :class:`OracleLedger`'s retry rule.

    ``invocations``/``failed``/``total()`` mirror the ledger's counting
    surface — this IS the "shared ledger" the service reports: with any
    cross-tenant overlap its total is strictly below the sum of the
    per-tenant ledgers'.
    """

    def __init__(self, tool, *, cache: Optional[PersistentOracleCache] = None,
                 name: str = "", tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.tool = tool
        self.cache = cache
        self.name = name
        self.tracer = tracer if tracer is not None else NULL_TRACER
        _adopt_tracer(tool, self.tracer)
        self.invocations: Dict[str, int] = {}
        self.failed: Dict[str, int] = {}
        # hits (answered from the shared cache), joins (coalesced onto an
        # in-flight call), batches (dispatcher drains), batch_retries
        # (failed batches re-priced per point): registry-backed counters —
        # historically ``batches``/``batch_retries`` were bare ints bumped
        # on the dispatcher thread with no lock at all
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        prefix = f"shared.{name}." if name else "shared."
        self._hits = self.metrics.counter(prefix + "hits")
        self._joins = self.metrics.counter(prefix + "joins")
        self._batches = self.metrics.counter(prefix + "batches")
        self._batch_retries = self.metrics.counter(prefix + "batch_retries")
        self._outcome_counters = {
            o: self.metrics.counter(prefix + "points." + o)
            for o in OUTCOMES}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight: Dict[Key, _Flight] = {}
        self._pending: List[Tuple[InvocationRequest, _Flight]] = []
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False

    # -- submitter side ------------------------------------------------
    def evaluate(self, request: InvocationRequest, *,
                 _parent=None) -> Synthesis:
        key = request.key
        with self.tracer.span("shared.point", parent=_parent,
                              component=request.component,
                              unrolls=request.unrolls, ports=request.ports,
                              tile=request.tile) as sp:
            with self._cv:
                if self._closed:
                    raise RuntimeError(
                        f"SharedOracle {self.name!r} is closed")
                if self.cache is not None:
                    hit = self.cache.get(key)
                    if hit is not None:
                        self._hits.inc()
                        outcome = ("replay"
                                   if self.cache.consume_restored(key)
                                   else "cache_hit")
                        sp.set("outcome", outcome)
                        self._outcome_counters[outcome].inc()
                        return hit
                fl = self._inflight.get(key)
                if fl is not None:
                    self._joins.inc()
                    sp.set("outcome", "inflight_join")
                    self._outcome_counters["inflight_join"].inc()
                else:
                    fl = _Flight()
                    self._inflight[key] = fl
                    self._pending.append((request, fl))
                    # counted at dispatch admission, like the ledger's
                    # count-up-front rule (exceptions still count)
                    comp = request.component
                    self.invocations[comp] = \
                        self.invocations.get(comp, 0) + 1
                    sp.set("outcome", "fresh")
                    self._outcome_counters["fresh"].inc()
                    if self._dispatcher is None:
                        try:
                            self._dispatcher = threading.Thread(
                                target=self._dispatch_loop,
                                name=("shared-oracle-"
                                      f"{self.name or f'{id(self):x}'}"),
                                daemon=True)
                            self._dispatcher.start()
                        except BaseException:
                            # never strand a flight others could join: a
                            # dispatcher that failed to start completes
                            # nothing, so unregister before re-raising
                            self._dispatcher = None
                            self._inflight.pop(key, None)
                            self._pending.remove((request, fl))
                            raise
                    self._cv.notify_all()
            fl.event.wait()
            if fl.error is not None:
                raise RuntimeError(f"shared oracle invocation failed for "
                                   f"{key}: {fl.error}") from fl.error
            assert fl.result is not None
            return fl.result

    def evaluate_batch(self, requests: Sequence[InvocationRequest],
                       *, workers: Optional[int] = None) -> List[Synthesis]:
        reqs = list(requests)
        with self.tracer.span("shared.batch", n=len(reqs)) as sp:
            if len(reqs) <= 1:
                return [self.evaluate(r) for r in reqs]
            with ThreadPoolExecutor(max_workers=min(workers or 8,
                                                    len(reqs))) as pool:
                return list(pool.map(
                    lambda r: self.evaluate(r, _parent=sp), reqs))

    # -- dispatcher side -----------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
                batch = self._pending
                self._pending = []
            self._run_batch(batch)

    def _call_one(self, req: InvocationRequest) -> Synthesis:
        # prefer the Oracle protocol: it carries the tool.point span;
        # bare SynthesisTools (synthesize only) are priced directly
        tool = self.tool
        if hasattr(tool, "evaluate"):
            return tool.evaluate(req)
        return call_synthesize(tool, req.component,
                               unrolls=req.unrolls, ports=req.ports,
                               max_states=req.max_states, tile=req.tile)

    def _run_batch(self, batch: List[Tuple[InvocationRequest, _Flight]]
                   ) -> None:
        reqs = [r for r, _ in batch]
        self._batches.inc()
        outs: List[Optional[Synthesis]]
        errs: List[Optional[BaseException]]
        with self.tracer.span("shared.drain", n=len(reqs)) as sp:
            try:
                if len(reqs) > 1 and hasattr(self.tool, "evaluate_batch"):
                    outs = list(self.tool.evaluate_batch(reqs))
                else:
                    outs = [self._call_one(r) for r in reqs]
                errs = [None] * len(reqs)
            except BaseException as batch_exc:  # noqa: BLE001
                if len(reqs) == 1:
                    # already attributable — re-pricing would
                    # double-invoke the tool and mask the error on the
                    # retry
                    outs, errs = [None], [batch_exc]
                else:
                    # one failing point must not take the whole drain
                    # down: re-price per point so the error lands on the
                    # right key(s)
                    self._batch_retries.inc()
                    sp.set("retried", True)
                    outs, errs = [], []
                    for r in reqs:
                        try:
                            outs.append(self._call_one(r))
                            errs.append(None)
                        except BaseException as exc:  # noqa: BLE001
                            outs.append(None)
                            errs.append(exc)
            sp.set("errors", sum(1 for e in errs if e is not None))
        for (req, fl), out, err in zip(batch, outs, errs):
            with self._cv:
                if err is None:
                    assert out is not None
                    if not out.feasible:
                        comp = req.component
                        self.failed[comp] = self.failed.get(comp, 0) + 1
                    if self.cache is not None:
                        self.cache.put(req.key, out)
                    fl.result = out
                else:
                    fl.error = err          # transient: never cached
                self._inflight.pop(req.key, None)
            fl.event.set()

    # -- tool delegation (tenant ledgers call these through us) --------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        return self.evaluate(InvocationRequest(
            component=component, unrolls=unrolls, ports=ports,
            max_states=max_states, tile=tile))

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self.tool.cdfg_facts(component, synth)

    def plm_requirement(self, component: str, synth: Synthesis):
        fn = getattr(self.tool, "plm_requirement", None)
        return None if fn is None else fn(component, synth)

    # -- accounting ----------------------------------------------------
    # historical bare-int counter names, now registry-backed (read-only)
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def joins(self) -> int:
        return self._joins.value

    @property
    def batches(self) -> int:
        return self._batches.value

    @property
    def batch_retries(self) -> int:
        return self._batch_retries.value

    def total(self, component: Optional[str] = None) -> int:
        with self._lock:
            if component is not None:
                return self.invocations.get(component, 0)
            return sum(self.invocations.values())

    def outcome_counts(self) -> Dict[str, int]:
        """Per-point outcome partition at the shared (cross-tenant)
        level: ``fresh`` admissions to the dispatcher, shared-cache
        ``cache_hit``/``replay``, and ``inflight_join`` waiters."""
        return {o: c.value for o, c in self._outcome_counters.items()}

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = {
                "invocations": sum(self.invocations.values()),
                "failed": sum(self.failed.values()),
                "hits": self._hits.value, "joins": self._joins.value,
                "batches": self._batches.value,
                "batch_retries": self._batch_retries.value,
            }
        out["outcomes"] = self.outcome_counts()
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out

    def close(self) -> None:
        """Stop the dispatcher (pending work drains first) and flush the
        shared cache.  Idempotent."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            dispatcher = self._dispatcher
        if dispatcher is not None:
            dispatcher.join()
        if self.cache is not None:
            self.cache.flush()


# ----------------------------------------------------------------------
# The ledger
# ----------------------------------------------------------------------
class OracleLedger:
    """Invocation accounting + caching around any oracle or legacy tool.

    Semantics are exactly the old ``CountingTool``'s (Section 7.3 /
    Fig. 11): repeated invocations with identical knobs are served from
    cache and NOT counted; failed syntheses (lambda-constraint discards)
    ARE counted.  On top of that:

      * thread-safe, with in-flight de-duplication — two workers racing
        on the same knob point trigger ONE tool call, so batched and
        sequential drives count identically;
      * ``evaluate_batch`` fans independent points out over a pool;
      * every real call appends an :class:`InvocationRecord`;
      * an optional :class:`OracleCache` pre-seeds the in-memory cache
        (counts are reconstructed from it, one per persisted point, so a
        resumed run reports the same totals as an uninterrupted one) and
        receives every new result.
    """

    def __init__(self, tool, *, cache: Optional[OracleCache] = None,
                 workers: int = 8, tracer=None,
                 metrics: Optional[MetricsRegistry] = None, name: str = ""):
        self.tool = tool
        self.name = name
        self.workers = max(1, workers)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        _adopt_tracer(tool, self.tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        prefix = f"oracle.{name}." if name else "oracle."
        self._outcome_counters = {
            o: self.metrics.counter(prefix + "points." + o)
            for o in OUTCOMES}
        self._invoke_hist = self.metrics.histogram(prefix + "invoke_wall_s")
        self.invocations: Dict[str, int] = {}
        self.failed: Dict[str, int] = {}
        self.records: List[InvocationRecord] = []
        self.phase: str = ""
        self._cache: Dict[Key, Synthesis] = {}
        self._restored: set = set()
        self._persist = cache
        self._lock = threading.Lock()
        self._inflight: Dict[Key, threading.Event] = {}
        self._errors: Dict[Key, BaseException] = {}
        if cache is not None:
            # reconstruct the accounting one-for-one from the persisted
            # points, so a resumed run reports the same totals (and the
            # same per-phase record sums) as an uninterrupted one
            for key, synth in cache.entries().items():
                self._cache[key] = synth
                self._restored.add(key)
                comp = key[0]
                self.invocations[comp] = self.invocations.get(comp, 0) + 1
                if not synth.feasible:
                    self.failed[comp] = self.failed.get(comp, 0) + 1
                self.records.append(InvocationRecord(
                    component=comp, unrolls=key[1], ports=key[2],
                    max_states=key[3], feasible=synth.feasible,
                    lam=synth.lam, area=synth.area, phase="restored",
                    tile=key[4] if len(key) > 4 else 0))

    # ------------------------------------------------------------------
    def _call_tool(self, req: InvocationRequest) -> Synthesis:
        # prefer the Oracle protocol: it carries the tool.point span;
        # bare SynthesisTools (synthesize only) are priced directly
        tool = self.tool
        if hasattr(tool, "evaluate"):
            return tool.evaluate(req)
        return call_synthesize(tool, req.component,
                               unrolls=req.unrolls, ports=req.ports,
                               max_states=req.max_states,
                               tile=req.tile)

    def _note_outcome(self, sp, outcome: str) -> None:
        # caller holds self._lock; Counter has its own (leaf) lock
        sp.set("outcome", outcome)
        self._outcome_counters[outcome].inc()

    def evaluate(self, request: InvocationRequest, *,
                 _parent=None) -> Synthesis:
        key = request.key
        with self.tracer.span("oracle.point", parent=_parent,
                              component=request.component,
                              unrolls=request.unrolls, ports=request.ports,
                              tile=request.tile) as sp:
            with self._lock:
                hit = self._cache.get(key)
                if hit is not None:
                    if key in self._restored:
                        # first serve from a restored entry: the replay
                        # that reconciles against the restored total;
                        # later serves are ordinary cache hits
                        self._restored.discard(key)
                        self._note_outcome(sp, "replay")
                    else:
                        self._note_outcome(sp, "cache_hit")
                    return hit
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    self._errors.pop(key, None)  # a retry clears old failure
                    owner = True
                    # counted up-front, like the seed's CountingTool
                    comp = request.component
                    self.invocations[comp] = \
                        self.invocations.get(comp, 0) + 1
                    self._note_outcome(sp, "fresh")
                else:
                    owner = False
                    self._note_outcome(sp, "inflight_join")
            if not owner:
                ev.wait()
                with self._lock:
                    out = self._cache.get(key)
                    err = self._errors.get(key)
                if out is None:
                    if err is not None:
                        raise RuntimeError(
                            f"oracle invocation failed for {key}") from err
                    raise RuntimeError(
                        f"oracle invocation failed for {key}")
                return out
            t0 = time.monotonic()
            try:
                out = self._call_tool(request)
            except BaseException as exc:
                with self._lock:
                    self._errors[key] = exc
                    self._inflight.pop(key, None)
                ev.set()
                raise
            wall = time.monotonic() - t0
            self._invoke_hist.observe(wall)
            with self._lock:
                if not out.feasible:
                    comp = request.component
                    self.failed[comp] = self.failed.get(comp, 0) + 1
                self._cache[key] = out
                self._restored.discard(key)   # paid for in this process
                self.records.append(InvocationRecord(
                    component=request.component, unrolls=request.unrolls,
                    ports=request.ports, max_states=request.max_states,
                    feasible=out.feasible, lam=out.lam, area=out.area,
                    phase=self.phase, wall_s=wall,
                    tile=request.tile))
                self._inflight.pop(key, None)
            ev.set()
            if self._persist is not None:
                self._persist.put(key, out)
            return out

    def evaluate_batch(self, requests: Sequence[InvocationRequest],
                       *, workers: Optional[int] = None) -> List[Synthesis]:
        """Evaluate independent knob points, fanned out over a pool.

        Results come back in request order; duplicate keys inside the
        batch (and races with other concurrent callers) collapse to one
        tool call via the in-flight de-duplication in ``evaluate``.
        The batch gets one ``oracle.batch`` span; each point's
        ``oracle.point`` child carries its outcome tag (fan-out workers
        parent to the batch span explicitly, since they run on pool
        threads).
        """
        reqs = list(requests)
        n = self.workers if workers is None else max(1, workers)
        with self.tracer.span("oracle.batch", n=len(reqs),
                              phase=self.phase) as sp:
            if len(reqs) <= 1 or n <= 1:
                return [self.evaluate(r) for r in reqs]
            with ThreadPoolExecutor(max_workers=min(n, len(reqs))) as pool:
                return list(pool.map(
                    lambda r: self.evaluate(r, _parent=sp), reqs))

    # ------------------------------------------------------------------
    # Legacy CountingTool surface (the whole seed engine drives this)
    # ------------------------------------------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        return self.evaluate(InvocationRequest(
            component=component, unrolls=unrolls, ports=ports,
            max_states=max_states, tile=tile))

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self.tool.cdfg_facts(component, synth)

    def plm_requirement(self, component: str, synth: Synthesis):
        """Delegate PLM-requirement extraction (core.plm) to the backend;
        returns None for backends that do not expose one."""
        fn = getattr(self.tool, "plm_requirement", None)
        return None if fn is None else fn(component, synth)

    def total(self, component: Optional[str] = None) -> int:
        if component is not None:
            return self.invocations.get(component, 0)
        return sum(self.invocations.values())

    def flush(self) -> None:
        if self._persist is not None:
            self._persist.flush()

    def outcome_counts(self) -> Dict[str, int]:
        """Per-point outcome partition as seen by this ledger:
        ``fresh + cache_hit + inflight_join + replay`` partitions every
        ``evaluate`` call, and ``fresh + replay == total()`` when every
        restored entry is re-served (the standard resume; in general
        ``replay`` counts only restored entries actually used, so
        ``fresh + replay <= total()``) — the Fig. 11 trace-vs-ledger
        reconciliation invariants."""
        return {o: c.value for o, c in self._outcome_counters.items()}

    def records_by_phase(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.phase or "?"] = out.get(r.phase or "?", 0) + 1
        return out


class CountingTool(OracleLedger):
    """Legacy name for :class:`OracleLedger` (the seed's published API).

    Construction (``CountingTool(tool)``) and the ``synthesize`` /
    ``invocations`` / ``failed`` / ``total`` surface are unchanged.
    """
