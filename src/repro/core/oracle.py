"""The unified synthesis-oracle layer: one query interface for every backend.

COSMOS's headline result is *invocation frugality* — the system-level
Pareto front is recovered with up to 14.6x fewer tool calls than the
exhaustive baseline (Fig. 11) — so the seam between the DSE engine and
the expensive tool is the load-bearing interface of the repository.  This
module defines it once, for every oracle:

  * :class:`InvocationRequest` — one knob point to price/synthesize;
  * :class:`Oracle` — the protocol: ``evaluate`` one request or
    ``evaluate_batch`` many (independent knob points fan out over a
    thread pool, since every hlsim/XLA invocation is pure);
  * :class:`OracleLedger` — the accounting + caching layer that subsumes
    the old ``CountingTool``: repeats are cached and NOT counted
    (Section 7.3), infeasible points ARE counted (Fig. 11 includes the
    lambda-constraint discards), identical invocations issued
    concurrently are de-duplicated in flight, and every real tool call
    leaves a structured :class:`InvocationRecord`;
  * :class:`PersistentOracleCache` — a pluggable cache backed by
    :mod:`repro.checkpoint.store`, so a killed DSE run resumes without
    re-invoking the tool for any point it already paid for.

``CountingTool`` remains as a thin legacy alias so the seed's published
surface keeps working.
"""

from __future__ import annotations

import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

from .knobs import CDFGFacts, Synthesis

__all__ = [
    "InvocationRequest",
    "InvocationRecord",
    "Oracle",
    "OracleBatchMixin",
    "OracleCache",
    "PersistentOracleCache",
    "OracleLedger",
    "CountingTool",
    "call_synthesize",
]


def call_synthesize(tool, component: str, *, unrolls: int, ports: int,
                    max_states: Optional[int] = None,
                    tile: int = 0) -> Synthesis:
    """Invoke ``tool.synthesize`` forwarding ``tile`` only when set.

    The single place that encodes the compatibility rule for the tile
    knob: two-knob backends (and pre-tile user tools) never see the
    keyword, so they keep working unchanged.
    """
    if tile:
        return tool.synthesize(component, unrolls=unrolls, ports=ports,
                               max_states=max_states, tile=tile)
    return tool.synthesize(component, unrolls=unrolls, ports=ports,
                           max_states=max_states)

# key type used everywhere below:
# (component, unrolls, ports, max_states, tile); tile 0 = native tile
Key = Tuple[str, int, int, Optional[int], int]


@dataclass(frozen=True)
class InvocationRequest:
    """One knob point submitted to an oracle.

    ``max_states`` carries the lambda-constraint of Algorithm 1 (the
    synthesis fails when the scheduler cannot fit an iteration within
    that many states); ``None`` means unconstrained.  ``tile`` is the
    third knob axis (PLM tile edge); 0 means the component's native
    tile, and is the only value two-knob backends ever see.
    """

    component: str
    unrolls: int
    ports: int
    max_states: Optional[int] = None
    tile: int = 0

    @property
    def key(self) -> Key:
        return (self.component, self.unrolls, self.ports, self.max_states,
                self.tile)


@dataclass(frozen=True)
class InvocationRecord:
    """One *real* tool call, as accounted in Fig. 11.

    Cache hits never produce a record — a record is money spent.
    ``phase`` tags which DSE phase paid for it (characterize/map/...),
    which is what the invocation-breakdown benchmarks aggregate.
    """

    component: str
    unrolls: int
    ports: int
    max_states: Optional[int]
    feasible: bool
    lam: float
    area: float
    phase: str = ""
    wall_s: float = 0.0
    tile: int = 0


@runtime_checkable
class Oracle(Protocol):
    """The expensive oracle COSMOS coordinates, batched form.

    ``evaluate`` prices/synthesizes a single knob point.
    ``evaluate_batch`` prices many *independent* points; implementations
    are free to fan out (thread pool, async compile service, RPC) as long
    as results come back in request order.  ``cdfg_facts`` exposes the
    Eq. (1) inputs extracted from a completed synthesis.
    """

    def evaluate(self, request: InvocationRequest) -> Synthesis: ...

    def evaluate_batch(self, requests: Sequence[InvocationRequest]
                       ) -> List[Synthesis]: ...

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts: ...


class OracleBatchMixin:
    """Adapts a ``synthesize``-style SynthesisTool to the Oracle protocol.

    Backends inherit this and only implement ``synthesize`` (+
    ``cdfg_facts``); the default batch is a thread-pool fan-out, valid
    because every backend invocation in this repo is pure.
    """

    batch_workers: int = 8

    def evaluate(self, request: InvocationRequest) -> Synthesis:
        return call_synthesize(self, request.component,
                               unrolls=request.unrolls,
                               ports=request.ports,
                               max_states=request.max_states,
                               tile=request.tile)

    def evaluate_batch(self, requests: Sequence[InvocationRequest],
                       *, workers: Optional[int] = None) -> List[Synthesis]:
        reqs = list(requests)
        n = workers or self.batch_workers
        if len(reqs) <= 1 or n <= 1:
            return [self.evaluate(r) for r in reqs]
        with ThreadPoolExecutor(max_workers=min(n, len(reqs))) as pool:
            return list(pool.map(self.evaluate, reqs))


# ----------------------------------------------------------------------
# Caches
# ----------------------------------------------------------------------
class OracleCache(Protocol):
    """Pluggable persistence for oracle results (keyed by knob point)."""

    def entries(self) -> Dict[Key, Synthesis]: ...

    def put(self, key: Key, synth: Synthesis) -> None: ...


def _synth_to_json(s: Synthesis) -> Dict[str, Any]:
    return {"lam": s.lam, "area": s.area, "ports": s.ports,
            "unrolls": s.unrolls, "states": s.states_per_iter,
            "feasible": s.feasible, "detail": dict(s.detail),
            "tile": s.tile}


def _synth_from_json(d: Dict[str, Any]) -> Synthesis:
    return Synthesis(lam=d["lam"], area=d["area"], ports=d["ports"],
                     unrolls=d["unrolls"], states_per_iter=d["states"],
                     feasible=d["feasible"], detail=dict(d["detail"]),
                     tile=d.get("tile", 0))


class PersistentOracleCache:
    """Synthesis results persisted via :mod:`repro.checkpoint.store`.

    Each flush writes the *whole* cache as one atomic checkpoint step
    (store's rename protocol: a crash leaves the previous complete step,
    never a torn one), then prunes older steps.  A killed DSE run that
    restarts with the same ``root`` resumes with every flushed
    invocation served from here.  Flushes are batched (a full rewrite
    per put would be O(n^2) disk I/O): a hard kill can lose at most the
    last ``flush_every - 1`` points — they are simply re-invoked on
    resume — and the ledger flushes the remainder when a session
    completes.  Set ``flush_every=1`` for per-invocation durability.
    """

    def __init__(self, root: str, *, flush_every: int = 16, keep: int = 2):
        self.root = root
        self.flush_every = max(1, flush_every)
        self.keep = max(1, keep)
        self._entries: Dict[Key, Synthesis] = {}
        self._dirty = 0
        self._lock = threading.Lock()
        self._load()

    # -- store glue ----------------------------------------------------
    @staticmethod
    def _store():
        from ..checkpoint import store       # lazy: store imports jax
        return store

    def _load(self) -> None:
        import numpy as np
        store = self._store()
        step = store.latest_step(self.root)
        if step is None:
            return
        _, extra = store.restore(self.root, step,
                                 {"n_entries": np.asarray(0)})
        for rec in extra.get("entries", []):
            # pre-tile caches persisted 4-element keys; they reload as
            # native-tile (tile=0) points
            comp, unrolls, ports, max_states, *rest = rec["key"]
            tile = int(rest[0]) if rest else 0
            key = (comp, int(unrolls), int(ports),
                   None if max_states is None else int(max_states), tile)
            self._entries[key] = _synth_from_json(rec["synth"])

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if self._dirty == 0:
            return
        import numpy as np
        store = self._store()
        step = (store.latest_step(self.root) or 0) + 1
        payload = [{"key": list(k), "synth": _synth_to_json(s)}
                   for k, s in self._entries.items()]
        store.save(self.root, step,
                   {"n_entries": np.asarray(len(payload))},
                   extra={"entries": payload})
        self._dirty = 0
        for old in store.list_steps(self.root)[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{old:08d}"),
                          ignore_errors=True)

    # -- OracleCache protocol ------------------------------------------
    def entries(self) -> Dict[Key, Synthesis]:
        with self._lock:
            return dict(self._entries)

    def put(self, key: Key, synth: Synthesis) -> None:
        with self._lock:
            self._entries[key] = synth
            self._dirty += 1
            if self._dirty >= self.flush_every:
                self._flush_locked()

    def __len__(self) -> int:
        return len(self._entries)


# ----------------------------------------------------------------------
# The ledger
# ----------------------------------------------------------------------
class OracleLedger:
    """Invocation accounting + caching around any oracle or legacy tool.

    Semantics are exactly the old ``CountingTool``'s (Section 7.3 /
    Fig. 11): repeated invocations with identical knobs are served from
    cache and NOT counted; failed syntheses (lambda-constraint discards)
    ARE counted.  On top of that:

      * thread-safe, with in-flight de-duplication — two workers racing
        on the same knob point trigger ONE tool call, so batched and
        sequential drives count identically;
      * ``evaluate_batch`` fans independent points out over a pool;
      * every real call appends an :class:`InvocationRecord`;
      * an optional :class:`OracleCache` pre-seeds the in-memory cache
        (counts are reconstructed from it, one per persisted point, so a
        resumed run reports the same totals as an uninterrupted one) and
        receives every new result.
    """

    def __init__(self, tool, *, cache: Optional[OracleCache] = None,
                 workers: int = 8):
        self.tool = tool
        self.workers = max(1, workers)
        self.invocations: Dict[str, int] = {}
        self.failed: Dict[str, int] = {}
        self.records: List[InvocationRecord] = []
        self.phase: str = ""
        self._cache: Dict[Key, Synthesis] = {}
        self._persist = cache
        self._lock = threading.Lock()
        self._inflight: Dict[Key, threading.Event] = {}
        self._errors: Dict[Key, BaseException] = {}
        if cache is not None:
            # reconstruct the accounting one-for-one from the persisted
            # points, so a resumed run reports the same totals (and the
            # same per-phase record sums) as an uninterrupted one
            for key, synth in cache.entries().items():
                self._cache[key] = synth
                comp = key[0]
                self.invocations[comp] = self.invocations.get(comp, 0) + 1
                if not synth.feasible:
                    self.failed[comp] = self.failed.get(comp, 0) + 1
                self.records.append(InvocationRecord(
                    component=comp, unrolls=key[1], ports=key[2],
                    max_states=key[3], feasible=synth.feasible,
                    lam=synth.lam, area=synth.area, phase="restored",
                    tile=key[4] if len(key) > 4 else 0))

    # ------------------------------------------------------------------
    def _call_tool(self, req: InvocationRequest) -> Synthesis:
        tool = self.tool
        if hasattr(tool, "synthesize"):
            return call_synthesize(tool, req.component,
                                   unrolls=req.unrolls, ports=req.ports,
                                   max_states=req.max_states,
                                   tile=req.tile)
        return tool.evaluate(req)

    def evaluate(self, request: InvocationRequest) -> Synthesis:
        key = request.key
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                return hit
            ev = self._inflight.get(key)
            if ev is None:
                ev = threading.Event()
                self._inflight[key] = ev
                self._errors.pop(key, None)      # a retry clears old failure
                owner = True
                # counted up-front, like the seed's CountingTool
                comp = request.component
                self.invocations[comp] = self.invocations.get(comp, 0) + 1
            else:
                owner = False
        if not owner:
            ev.wait()
            with self._lock:
                out = self._cache.get(key)
                err = self._errors.get(key)
            if out is None:
                if err is not None:
                    raise RuntimeError(
                        f"oracle invocation failed for {key}") from err
                raise RuntimeError(f"oracle invocation failed for {key}")
            return out
        t0 = time.monotonic()
        try:
            out = self._call_tool(request)
        except BaseException as exc:
            with self._lock:
                self._errors[key] = exc
                self._inflight.pop(key, None)
            ev.set()
            raise
        with self._lock:
            if not out.feasible:
                comp = request.component
                self.failed[comp] = self.failed.get(comp, 0) + 1
            self._cache[key] = out
            self.records.append(InvocationRecord(
                component=request.component, unrolls=request.unrolls,
                ports=request.ports, max_states=request.max_states,
                feasible=out.feasible, lam=out.lam, area=out.area,
                phase=self.phase, wall_s=time.monotonic() - t0,
                tile=request.tile))
            self._inflight.pop(key, None)
        ev.set()
        if self._persist is not None:
            self._persist.put(key, out)
        return out

    def evaluate_batch(self, requests: Sequence[InvocationRequest],
                       *, workers: Optional[int] = None) -> List[Synthesis]:
        """Evaluate independent knob points, fanned out over a pool.

        Results come back in request order; duplicate keys inside the
        batch (and races with other concurrent callers) collapse to one
        tool call via the in-flight de-duplication in ``evaluate``.
        """
        reqs = list(requests)
        n = self.workers if workers is None else max(1, workers)
        if len(reqs) <= 1 or n <= 1:
            return [self.evaluate(r) for r in reqs]
        with ThreadPoolExecutor(max_workers=min(n, len(reqs))) as pool:
            return list(pool.map(self.evaluate, reqs))

    # ------------------------------------------------------------------
    # Legacy CountingTool surface (the whole seed engine drives this)
    # ------------------------------------------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        return self.evaluate(InvocationRequest(
            component=component, unrolls=unrolls, ports=ports,
            max_states=max_states, tile=tile))

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self.tool.cdfg_facts(component, synth)

    def plm_requirement(self, component: str, synth: Synthesis):
        """Delegate PLM-requirement extraction (core.plm) to the backend;
        returns None for backends that do not expose one."""
        fn = getattr(self.tool, "plm_requirement", None)
        return None if fn is None else fn(component, synth)

    def total(self, component: Optional[str] = None) -> int:
        if component is not None:
            return self.invocations.get(component, 0)
        return sum(self.invocations.values())

    def flush(self) -> None:
        if self._persist is not None:
            self._persist.flush()

    def records_by_phase(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.phase or "?"] = out.get(r.phase or "?", 0) + 1
        return out


class CountingTool(OracleLedger):
    """Legacy name for :class:`OracleLedger` (the seed's published API).

    Construction (``CountingTool(tool)``) and the ``synthesize`` /
    ``invocations`` / ``failed`` / ``total`` surface are unchanged.
    """
