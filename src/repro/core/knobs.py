"""Knob spaces, design-space regions, and the synthesis-tool protocol.

The paper's two knobs are the number of PLM ports (powers of two, Section
5) and the number of loop unrolls.  Regions group points with the same
port count and are bounded by an upper-left (lambda_min, alpha_max) and a
lower-right (lambda_max, alpha_min) corner (Algorithm 1).

``SynthesisTool`` is the expensive oracle being coordinated: the simulated
HLS scheduler (core.hlsim) for the WAMI reproduction, and the real XLA
compiler (core.xlatool / core.autotune) for the TPU instantiation.
Invocation accounting — the paper's efficiency metric (Fig. 11) — lives
in :mod:`repro.core.oracle` (``OracleLedger``) so both backends are
measured identically; the legacy ``CountingTool`` name resolves there.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Tuple

__all__ = [
    "KnobSpace",
    "Synthesis",
    "CDFGFacts",
    "Region",
    "SynthesisTool",
    "CountingTool",
    "powers_of_two",
]


def powers_of_two(lo: int, hi: int) -> List[int]:
    out, p = [], 1
    while p < lo:
        p *= 2
    while p <= hi:
        out.append(p)
        p *= 2
    return out


@dataclass(frozen=True)
class KnobSpace:
    """Designer-provided exploration bounds (Algorithm 1 inputs).

    ``tile_sizes`` is the optional third knob axis: the PLM tile edge the
    component processes per execution.  Empty (the default) keeps the
    component at its native tile — the paper's two-knob space — and the
    sentinel tile value 0 is used everywhere to mean "native tile".  A
    non-empty tuple makes characterization walk Algorithm 1 once per
    tile, trading PLM *capacity* against PLM *port count* (docs/memory.md).
    """

    clock_ns: float            # target clock period (ns)
    max_ports: int             # PLM ports, explored over powers of two
    max_unrolls: int           # loop unrolling upper bound
    min_ports: int = 1
    tile_sizes: Tuple[int, ...] = ()   # PLM tile edges; () = native only

    def ports(self) -> List[int]:
        return powers_of_two(self.min_ports, self.max_ports)

    def tiles(self) -> List[int]:
        """Tile axis values; [0] (native tile) when the axis is unused."""
        return list(self.tile_sizes) if self.tile_sizes else [0]

    def __post_init__(self):
        if self.max_ports < self.min_ports:
            raise ValueError("max_ports < min_ports")
        if self.max_unrolls < 1:
            raise ValueError("max_unrolls < 1")
        if any(t <= 0 for t in self.tile_sizes):
            raise ValueError("tile_sizes must be positive")


@dataclass(frozen=True)
class CDFGFacts:
    """Eq. (1) inputs, inferred from the CDFG of the lower-right synthesis.

    gamma_r: max reads of the same array per loop iteration.
    gamma_w: max writes of the same array per loop iteration.
    eta:     states needed by non-memory ops (dependence-depth residue).
    trip:    loop trip count of the dominant loop (for latency models).
    has_plm_access: Eq. (1) is inapplicable to loops without PLM accesses
                    (Section 5) — the fallback neighbourhood search is
                    used instead.
    """

    gamma_r: int
    gamma_w: int
    eta: int
    trip: int
    has_plm_access: bool = True

    def h(self, unrolls: int, ports: int) -> int:
        """Eq. (1): upper bound on states per unrolled loop iteration."""
        return (
            math.ceil(self.gamma_r * unrolls / ports)
            + math.ceil(self.gamma_w / ports)
            + self.eta
        )


@dataclass(frozen=True)
class Synthesis:
    """Result of one tool invocation: a characterized implementation."""

    lam: float                  # effective latency (seconds)
    area: float                 # cost alpha (mm^2 or bytes/device)
    ports: int
    unrolls: int
    states_per_iter: int = 0    # scheduler states per loop iteration
    feasible: bool = True       # False when the lambda-constraint failed
    detail: Dict[str, float] = field(default_factory=dict)
    tile: int = 0               # PLM tile edge; 0 = the component's native


@dataclass
class Region:
    """A design-space region (fixed port count and tile) found by
    Algorithm 1.  ``tile`` is 0 when the tile axis is unused."""

    ports: int
    lam_max: float              # lower-right corner: slowest, smallest
    area_min: float
    lam_min: float              # upper-left corner: fastest, largest
    area_max: float
    mu_min: int                 # unrolls at lam_max (== ports, line 3)
    mu_max: int                 # unrolls at lam_min (lambda-constraint sat)
    facts: Optional[CDFGFacts] = None
    tile: int = 0               # PLM tile edge of every point in the region

    def contains_lambda(self, lam: float) -> bool:
        return self.lam_min - 1e-12 <= lam <= self.lam_max + 1e-12

    @property
    def lam_span(self) -> float:
        return self.lam_max / self.lam_min if self.lam_min > 0 else float("inf")

    @property
    def area_span(self) -> float:
        return self.area_max / self.area_min if self.area_min > 0 else float("inf")


class SynthesisTool(Protocol):
    """The expensive oracle COSMOS coordinates (HLS tool + memory generator).

    ``synthesize`` runs datapath synthesis for (unrolls, ports, clock) and
    memory generation for ``ports``; it returns latency+area *including*
    the PLM (Algorithm 1 lines 9-10).  ``max_states`` (optional) imposes
    the lambda-constraint: synthesis FAILS (feasible=False) if the
    scheduler cannot fit an iteration within that many states.
    ``cdfg_facts`` exposes the Eq. (1) inputs extracted from the CDFG of a
    completed synthesis.

    Backends that support the tile knob accept an extra ``tile=<edge>``
    keyword; the engine only passes it when a knob space declares a tile
    axis, so two-knob backends (and pre-tile user tools) keep working
    unchanged.
    """

    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None) -> Synthesis: ...

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts: ...


def __getattr__(name: str):
    # CountingTool grew into repro.core.oracle.OracleLedger; the lazy
    # import keeps `from repro.core.knobs import CountingTool` working
    # without a knobs -> oracle -> knobs import cycle.
    if name == "CountingTool":
        from .oracle import CountingTool
        return CountingTool
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
