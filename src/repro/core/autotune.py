"""COSMOS-TPU: the paper's methodology with XLA as the synthesis oracle.

Mapping (DESIGN.md §2): one ``lower().compile()`` on the production mesh
is the expensive tool invocation; the memory planner below is the
Mnemosyne analogue (it prices a knob setting in HBM bytes *analytically*
so the LP/mapping layer can plan without compiling); the knobs are

  * ``microbatches``  — the unroll analogue (time/space trade at fixed
    sharding; pow-2);
  * ``remat``         — activation-checkpoint policy (none/dots/full);
  * ``accum_dtype``   — fp32 vs bf16 gradient accumulation.

``choose_train_knobs`` is Algorithm-1-shaped: walk the knob ladder from
cheapest-latency to cheapest-memory, keep the first point whose PRICED
footprint fits the HBM budget, then confirm with a single compile (the
invocation-frugality argument of the paper, applied to XLA).  Since the
oracle unification it is expressed as an :class:`XLAOracle` walk behind
the same ``Oracle``/``OracleLedger`` protocol as the HLS backend, so the
TPU path shares the planning/mapping machinery and its invocation
accounting.  The priced model is also what ``repro.ft.elastic`` re-plans
against on a mesh change — characterization is reused, only the mapped
compile re-runs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from ..configs.base import ModelConfig, ShapeSpec

__all__ = ["MemoryPlan", "price_train_step", "choose_train_knobs",
           "XLAOracle", "HBM_BYTES_PER_CHIP"]

HBM_BYTES_PER_CHIP = 16 * 1024 ** 3          # TPU v5e


@dataclass(frozen=True)
class MemoryPlan:
    microbatches: int
    remat: str
    accum_dtype: str
    est_bytes: int
    breakdown: Dict[str, float]

    @property
    def fits(self) -> bool:
        return self.est_bytes <= HBM_BYTES_PER_CHIP


def _mesh_sizes(mesh_shape: Dict[str, int]) -> Tuple[int, int]:
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    model = mesh_shape.get("model", 1)
    return data, model


def price_train_step(cfg: ModelConfig, shape: ShapeSpec,
                     mesh_shape: Dict[str, int], *, microbatches: int,
                     remat: str, accum_dtype: str = "float32"
                     ) -> MemoryPlan:
    """Analytic HBM footprint of one train step (per device, bytes).

    The napkin model behind every COSMOS-TPU planning decision; §Perf
    records its predictions against ``memory_analysis()`` ground truth.
    """
    dp, tp = _mesh_sizes(mesh_shape)
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(1, B // dp) / max(1, microbatches)   # tokens rows per mb
    d, L = cfg.d_model, cfg.n_layers
    N = cfg.param_count()
    N_shardable = max(N - cfg.vocab * d, 1)

    # ---- static state ---------------------------------------------------
    params = 2.0 * N / tp                            # bf16, TP-sharded
    grads = (4.0 if accum_dtype == "float32" else 2.0) * N / tp
    opt = 8.0 * N / (tp * dp)                        # fp32 mu+nu, ZeRO-1
    if cfg.family == "moe":
        # experts can shard 2D (model x data)
        params = 2.0 * N / (tp * dp) + 2.0 * cfg.vocab * d / tp
        grads = grads / dp
        opt = 8.0 * N / (tp * dp)

    # ---- residuals (layer boundaries saved by remat='full') ------------
    resid = L * b_loc * S * d * 2.0
    if remat == "none":
        # everything live: roughly x(10-20 tensors)/layer
        resid *= 12.0
    elif remat == "dots":
        resid *= 4.0

    # ---- peak transient inside one layer (recompute included) ----------
    H = max(cfg.n_heads, 1)
    heads_tp = H / tp if H % tp == 0 else 1.0
    if cfg.family in ("ssm", "hybrid"):
        Q = cfg.ssm_chunk
        n_ch = max(1, S // Q)
        hd_heads = cfg.ssm_heads()
        trans = (b_loc * Q * Q * hd_heads * 4.0      # decay matrices
                 + 4 * b_loc * S * cfg.d_inner() * 4.0 / tp) * 1.5
        trans += n_ch * b_loc * Q * Q * hd_heads * 4.0 / 4  # scan residuals
    else:
        kvc = 1024 if S >= 16384 else S
        trans = b_loc * (H / max(heads_tp, 1)) ** 0 * heads_tp * S * kvc * 4.0
        trans += 3 * b_loc * S * max(cfg.d_ff, cfg.expert_ff()) * 2.0 / tp
    if cfg.family == "moe":
        cap = b_loc * S * cfg.top_k * cfg.capacity_factor
        trans += 3 * cap * d * 2.0 / tp + cap * cfg.expert_ff() * 2.0 / tp

    # ---- loss chunk ------------------------------------------------------
    chunk = 512 if cfg.vocab >= 65536 else S
    loss = 2 * b_loc * chunk * cfg.vocab * 4.0 / tp

    # calibrated against compiled memory_analysis() on gemma2-9b /
    # qwen2-vl-72b train cells: XLA keeps ~2.2x the naive live-set in the
    # layer backward (multiple f32 score/grad buffers in flight)
    xla_fudge = 2.2
    total = params + grads + opt + xla_fudge * (resid + trans + loss)
    return MemoryPlan(
        microbatches=microbatches, remat=remat, accum_dtype=accum_dtype,
        est_bytes=int(total),
        breakdown={"params": params, "grads": grads, "opt": opt,
                   "residuals": resid, "transient": trans, "loss": loss})


_LADDER = [
    # fastest -> most memory-frugal (the Algorithm-1 walk)
    dict(microbatches=1, remat="dots"),
    dict(microbatches=1, remat="full"),
    dict(microbatches=2, remat="full"),
    dict(microbatches=4, remat="full"),
    dict(microbatches=8, remat="full"),
    dict(microbatches=16, remat="full"),
    dict(microbatches=32, remat="full"),
    dict(microbatches=64, remat="full"),
]

# relative recompute cost of each remat policy (step-time proxy weights)
_REMAT_FACTOR = {"none": 1.0, "dots": 1.15, "full": 4.0 / 3.0}


class XLAOracle:
    """The TPU memory-planner as a COSMOS oracle over knob-ladder rungs.

    A *component* is one train stage ``(cfg, shape, mesh_shape)``; the
    ``unrolls`` knob indexes the Algorithm-1 ladder (rung 1 = fastest,
    rung ``len(_LADDER)`` = most memory-frugal) and ``ports`` is unused
    (single region).  One evaluation runs the priced memory plan — the
    Mnemosyne analogue: alpha = per-chip HBM bytes, lambda = a monotone
    relative step-time proxy (recompute factor x microbatch weight-re-read
    overhead) that preserves the ladder's fastest-to-slowest order.  The
    one *real* compile happens only for the mapped rung, via
    ``repro.launch.dryrun`` — the paper's invocation-frugality discipline
    applied to XLA.
    """

    def __init__(self, stages: Optional[Dict[str, Tuple[ModelConfig,
                                                        ShapeSpec,
                                                        Dict[str, int]]]] = None):
        self.stages = dict(stages or {})

    def register(self, name: str, cfg: ModelConfig, shape: ShapeSpec,
                 mesh_shape: Dict[str, int]) -> str:
        prev = self.stages.get(name)
        if prev is not None and prev != (cfg, shape, mesh_shape):
            raise ValueError(f"stage {name!r} already registered with a "
                             f"different (cfg, shape, mesh)")
        self.stages[name] = (cfg, shape, mesh_shape)
        return name

    # -- SynthesisTool / Oracle protocol --------------------------------
    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states=None):
        from .knobs import Synthesis
        cfg, shape, mesh_shape = self.stages[component]
        dp, _ = _mesh_sizes(mesh_shape)
        accum = "bfloat16" if cfg.param_count() > 30e9 else "float32"
        if not 1 <= unrolls <= len(_LADDER):
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls, feasible=False)
        rung = _LADDER[unrolls - 1]
        mb = rung["microbatches"]
        if shape.global_batch // dp < mb:      # cannot split further
            return Synthesis(lam=float("inf"), area=float("inf"),
                             ports=ports, unrolls=unrolls, feasible=False)
        plan = price_train_step(cfg, shape, mesh_shape, microbatches=mb,
                                remat=rung["remat"], accum_dtype=accum)
        lam = _REMAT_FACTOR[rung["remat"]] + 0.02 * (mb - 1)
        detail = {"est_bytes": float(plan.est_bytes),
                  "microbatches": float(mb),
                  "fits": float(plan.est_bytes <= HBM_BYTES_PER_CHIP)}
        detail.update({f"bd_{k}": v for k, v in plan.breakdown.items()})
        return Synthesis(lam=lam, area=float(plan.est_bytes), ports=ports,
                         unrolls=unrolls, states_per_iter=mb, feasible=True,
                         detail=detail)

    #: class-level default, same convention as OracleBatchMixin: tracing
    #: is off unless an instance is handed a real tracer
    tracer = None

    def _tracer(self):
        from .obs import NULL_TRACER
        return self.tracer if self.tracer is not None else NULL_TRACER

    def evaluate(self, request):
        with self._tracer().span("tool.point", component=request.component,
                                 unrolls=request.unrolls,
                                 ports=request.ports):
            return self.synthesize(request.component,
                                   unrolls=request.unrolls,
                                   ports=request.ports,
                                   max_states=request.max_states)

    def evaluate_batch(self, requests, *, workers: Optional[int] = None):
        reqs = list(requests)
        with self._tracer().span("tool.batch", n=len(reqs)):
            return [self.evaluate(r) for r in reqs]   # pricing is cheap

    def cdfg_facts(self, component: str, synth):
        from .knobs import CDFGFacts
        _, shape, _ = self.stages[component]
        return CDFGFacts(gamma_r=1, gamma_w=1,
                         eta=max(1, synth.states_per_iter),
                         trip=shape.global_batch, has_plm_access=False)

    def plan_from_synthesis(self, component: str, synth) -> MemoryPlan:
        """Reconstruct the exact MemoryPlan a feasible synthesis priced."""
        cfg, _, _ = self.stages[component]
        rung = _LADDER[synth.unrolls - 1]
        accum = "bfloat16" if cfg.param_count() > 30e9 else "float32"
        breakdown = {k[len("bd_"):]: v for k, v in synth.detail.items()
                     if k.startswith("bd_")}
        return MemoryPlan(microbatches=rung["microbatches"],
                          remat=rung["remat"], accum_dtype=accum,
                          est_bytes=int(synth.detail["est_bytes"]),
                          breakdown=breakdown)


def choose_train_knobs(cfg: ModelConfig, shape: ShapeSpec,
                       mesh_shape: Dict[str, int], *,
                       budget: int = HBM_BYTES_PER_CHIP,
                       slack: float = 0.90,
                       ledger=None, stage: Optional[str] = None) -> MemoryPlan:
    """Pick the fastest knob setting whose priced footprint fits.

    Re-expressed as an :class:`XLAOracle` walk: every reachable ladder
    rung is priced in one ``evaluate_batch`` (rungs are independent) and
    the fastest fitting rung wins — the characterization half of the
    paper's methodology, with the single confirming compile (the mapped
    invocation) left to ``repro.launch.dryrun``.  Pass a shared
    ``ledger`` (an :class:`~repro.core.oracle.OracleLedger` wrapping an
    ``XLAOracle``) to account invocations across stages/re-plans — a
    repeated plan for the same stage is a cache hit, not a new pricing.

    Models >30B accumulate gradients in bf16 (halves the standing grad
    buffer; the EF-compression module covers the numerics argument).
    Falls back to the most frugal reachable rung if nothing fits (the
    caller reports the deficit honestly).
    """
    from .oracle import InvocationRequest, OracleLedger
    if ledger is None:
        ledger = OracleLedger(XLAOracle())
    oracle = ledger.tool
    if not isinstance(oracle, XLAOracle):
        raise TypeError("choose_train_knobs needs a ledger over an XLAOracle")
    name = oracle.register(
        stage or f"{cfg.name}/{shape.name}/{_mesh_key(mesh_shape)}",
        cfg, shape, mesh_shape)

    accum = "bfloat16" if cfg.param_count() > 30e9 else "float32"
    dp, _ = _mesh_sizes(mesh_shape)
    # the seed walked the ladder until the first unsplittable rung; the
    # reachable prefix is known a-priori, so it prices as one batch
    rungs = []
    for i, rung in enumerate(_LADDER):
        if shape.global_batch // dp < rung["microbatches"]:
            break
        rungs.append(i + 1)
    if not rungs:
        return price_train_step(cfg, shape, mesh_shape, microbatches=1,
                                remat="full", accum_dtype=accum)
    outs = ledger.evaluate_batch(
        [InvocationRequest(component=name, unrolls=u, ports=1)
         for u in rungs])
    best = None
    for s in outs:
        if not s.feasible:
            continue
        best = s
        if s.detail["est_bytes"] <= budget * slack:
            break
    if best is None:
        return price_train_step(cfg, shape, mesh_shape, microbatches=1,
                                remat="full", accum_dtype=accum)
    return oracle.plan_from_synthesis(name, best)


def _mesh_key(mesh_shape: Dict[str, int]) -> str:
    return "x".join(f"{k}{v}" for k, v in sorted(mesh_shape.items()))
