"""COSMOS-TPU: the paper's methodology with XLA as the synthesis oracle.

Mapping (DESIGN.md §2): one ``lower().compile()`` on the production mesh
is the expensive tool invocation; the memory planner below is the
Mnemosyne analogue (it prices a knob setting in HBM bytes *analytically*
so the LP/mapping layer can plan without compiling); the knobs are

  * ``microbatches``  — the unroll analogue (time/space trade at fixed
    sharding; pow-2);
  * ``remat``         — activation-checkpoint policy (none/dots/full);
  * ``accum_dtype``   — fp32 vs bf16 gradient accumulation.

``choose_train_knobs`` is Algorithm-1-shaped: walk the knob ladder from
cheapest-latency to cheapest-memory, keep the first point whose PRICED
footprint fits the HBM budget, then confirm with a single compile (the
invocation-frugality argument of the paper, applied to XLA).  The priced
model is also what ``repro.ft.elastic`` re-plans against on a mesh
change — characterization is reused, only the mapped compile re-runs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from ..configs.base import ModelConfig, ShapeSpec

__all__ = ["MemoryPlan", "price_train_step", "choose_train_knobs",
           "HBM_BYTES_PER_CHIP"]

HBM_BYTES_PER_CHIP = 16 * 1024 ** 3          # TPU v5e


@dataclass(frozen=True)
class MemoryPlan:
    microbatches: int
    remat: str
    accum_dtype: str
    est_bytes: int
    breakdown: Dict[str, float]

    @property
    def fits(self) -> bool:
        return self.est_bytes <= HBM_BYTES_PER_CHIP


def _mesh_sizes(mesh_shape: Dict[str, int]) -> Tuple[int, int]:
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    model = mesh_shape.get("model", 1)
    return data, model


def price_train_step(cfg: ModelConfig, shape: ShapeSpec,
                     mesh_shape: Dict[str, int], *, microbatches: int,
                     remat: str, accum_dtype: str = "float32"
                     ) -> MemoryPlan:
    """Analytic HBM footprint of one train step (per device, bytes).

    The napkin model behind every COSMOS-TPU planning decision; §Perf
    records its predictions against ``memory_analysis()`` ground truth.
    """
    dp, tp = _mesh_sizes(mesh_shape)
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(1, B // dp) / max(1, microbatches)   # tokens rows per mb
    d, L = cfg.d_model, cfg.n_layers
    N = cfg.param_count()
    N_shardable = max(N - cfg.vocab * d, 1)

    # ---- static state ---------------------------------------------------
    params = 2.0 * N / tp                            # bf16, TP-sharded
    grads = (4.0 if accum_dtype == "float32" else 2.0) * N / tp
    opt = 8.0 * N / (tp * dp)                        # fp32 mu+nu, ZeRO-1
    if cfg.family == "moe":
        # experts can shard 2D (model x data)
        params = 2.0 * N / (tp * dp) + 2.0 * cfg.vocab * d / tp
        grads = grads / dp
        opt = 8.0 * N / (tp * dp)

    # ---- residuals (layer boundaries saved by remat='full') ------------
    resid = L * b_loc * S * d * 2.0
    if remat == "none":
        # everything live: roughly x(10-20 tensors)/layer
        resid *= 12.0
    elif remat == "dots":
        resid *= 4.0

    # ---- peak transient inside one layer (recompute included) ----------
    H = max(cfg.n_heads, 1)
    heads_tp = H / tp if H % tp == 0 else 1.0
    if cfg.family in ("ssm", "hybrid"):
        Q = cfg.ssm_chunk
        n_ch = max(1, S // Q)
        hd_heads = cfg.ssm_heads()
        trans = (b_loc * Q * Q * hd_heads * 4.0      # decay matrices
                 + 4 * b_loc * S * cfg.d_inner() * 4.0 / tp) * 1.5
        trans += n_ch * b_loc * Q * Q * hd_heads * 4.0 / 4  # scan residuals
    else:
        kvc = 1024 if S >= 16384 else S
        trans = b_loc * (H / max(heads_tp, 1)) ** 0 * heads_tp * S * kvc * 4.0
        trans += 3 * b_loc * S * max(cfg.d_ff, cfg.expert_ff()) * 2.0 / tp
    if cfg.family == "moe":
        cap = b_loc * S * cfg.top_k * cfg.capacity_factor
        trans += 3 * cap * d * 2.0 / tp + cap * cfg.expert_ff() * 2.0 / tp

    # ---- loss chunk ------------------------------------------------------
    chunk = 512 if cfg.vocab >= 65536 else S
    loss = 2 * b_loc * chunk * cfg.vocab * 4.0 / tp

    # calibrated against compiled memory_analysis() on gemma2-9b /
    # qwen2-vl-72b train cells: XLA keeps ~2.2x the naive live-set in the
    # layer backward (multiple f32 score/grad buffers in flight)
    xla_fudge = 2.2
    total = params + grads + opt + xla_fudge * (resid + trans + loss)
    return MemoryPlan(
        microbatches=microbatches, remat=remat, accum_dtype=accum_dtype,
        est_bytes=int(total),
        breakdown={"params": params, "grads": grads, "opt": opt,
                   "residuals": resid, "transient": trans, "loss": loss})


_LADDER = [
    # fastest -> most memory-frugal (the Algorithm-1 walk)
    dict(microbatches=1, remat="dots"),
    dict(microbatches=1, remat="full"),
    dict(microbatches=2, remat="full"),
    dict(microbatches=4, remat="full"),
    dict(microbatches=8, remat="full"),
    dict(microbatches=16, remat="full"),
    dict(microbatches=32, remat="full"),
    dict(microbatches=64, remat="full"),
]


def choose_train_knobs(cfg: ModelConfig, shape: ShapeSpec,
                       mesh_shape: Dict[str, int], *,
                       budget: int = HBM_BYTES_PER_CHIP,
                       slack: float = 0.90) -> MemoryPlan:
    """Pick the fastest knob setting whose priced footprint fits.

    Models >30B accumulate gradients in bf16 (halves the standing grad
    buffer; the EF-compression module covers the numerics argument).
    Falls back to the most frugal rung if nothing fits (the caller
    reports the deficit honestly).
    """
    accum = "bfloat16" if cfg.param_count() > 30e9 else "float32"
    dp, _ = _mesh_sizes(mesh_shape)
    best = None
    for rung in _LADDER:
        if shape.global_batch // dp < rung["microbatches"]:
            break                      # cannot split further
        plan = price_train_step(cfg, shape, mesh_shape,
                                microbatches=rung["microbatches"],
                                remat=rung["remat"], accum_dtype=accum)
        best = plan
        if plan.est_bytes <= budget * slack:
            return plan
    return best if best is not None else price_train_step(
        cfg, shape, mesh_shape, microbatches=1, remat="full",
        accum_dtype=accum)
