"""Memory generator — the Mnemosyne analogue (paper refs [36, 37]).

Given a PLM specification (capacity, word width, required parallel ports),
produce a multi-bank memory architecture built from dual-ported SRAM
macros and report its area.  Behavioural fidelity targets (Sections 3.1
and 5.1 of the paper):

  * each SRAM macro provides 2 read/write ports, so ``ports`` parallel
    accesses need ceil(ports/2) macros-worth of banking at minimum, and
    cyclic bank interleaving needs the bank count to be a power of two so
    the selection logic stays negligible;
  * more banks => superlinear area: small macros amortize their sense
    amps/decoders worse (the ``_bank_eff`` factor), plus per-bank muxing;
  * memory takes 40-90% of component area on typical accelerators, which
    the constants below reproduce for the WAMI components.

For the TPU instantiation the analogous planner lives in
``core.autotune`` (sharding/remat => HBM bytes); this module is the ASIC
cost model used by ``core.hlsim``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

__all__ = ["PLMSpec", "PLM", "MemGen"]

# 32nm-flavoured SRAM constants (mm^2); see hlsim.py for the calibration note.
_CELL_AREA_MM2_PER_BIT = 3.0e-7      # 6T cell + array periphery (32nm macro)
_MACRO_OVERHEAD_MM2 = 2.6e-3         # decoders, sense amps, BIST per macro
_MUX_AREA_PER_PORT_BANK = 3.0e-5     # bank-select / crossbar slice
_MIN_MACRO_WORDS = 64


@dataclass(frozen=True)
class PLMSpec:
    words: int
    word_bits: int
    ports: int                      # parallel accesses required per cycle


@dataclass(frozen=True)
class PLM:
    banks: int
    words_per_bank: int
    area: float                     # mm^2
    ports: int
    word_bits: int = 32             # macro word width
    clients: int = 1                # components time-multiplexed onto it

    @property
    def bits(self) -> int:
        """Physical storage bits of the generated architecture."""
        return self.banks * self.words_per_bank * self.word_bits

    def total_bits(self, word_bits: int) -> int:
        """Storage bits at an explicit word width (pre-dates the stored
        ``word_bits``; equals ``bits`` when the widths agree)."""
        return self.banks * self.words_per_bank * word_bits


class MemGen:
    """Deterministic multi-bank PLM generator."""

    def generate(self, spec: PLMSpec) -> PLM:
        if spec.words <= 0:
            return PLM(banks=0, words_per_bank=0, area=0.0, ports=spec.ports,
                       word_bits=spec.word_bits)
        # Ports must be servable in one cycle: with dual-ported macros,
        # ceil(ports/2) banks minimum; round banks to a power of two so
        # the bank-select logic avoids Euclidean division (Section 5,
        # ref [46]).
        need = max(1, math.ceil(spec.ports / 2))
        banks = 1 << (need - 1).bit_length()
        words_per_bank = max(_MIN_MACRO_WORDS, math.ceil(spec.words / banks))
        # Efficiency: small macros amortize periphery worse.
        eff = 1.0 + 0.35 * math.log2(banks) if banks > 1 else 1.0
        bits = words_per_bank * spec.word_bits
        area_macros = banks * (_MACRO_OVERHEAD_MM2 + bits * _CELL_AREA_MM2_PER_BIT * eff)
        area_mux = spec.ports * banks * _MUX_AREA_PER_PORT_BANK
        return PLM(banks=banks, words_per_bank=words_per_bank,
                   area=area_macros + area_mux, ports=spec.ports,
                   word_bits=spec.word_bits)

    def generate_shared(self, specs: Sequence[PLMSpec]) -> PLM:
        """One physical PLM serving several *mutually exclusive* clients.

        Only one client accesses the memory at a time (the planner's
        compatibility certificate), so the shared architecture needs the
        envelope of the requirements — max capacity, max word width, max
        port count — not their sum; Mnemosyne's address-space sharing
        (paper refs [36, 37]) exploits exactly this.  Each client beyond
        the first pays an arbitration slice per port-bank pair (the
        client-select crossbar layer in front of the bank mux).
        """
        if not specs:
            raise ValueError("generate_shared needs at least one PLMSpec")
        env = PLMSpec(words=max(s.words for s in specs),
                      word_bits=max(s.word_bits for s in specs),
                      ports=max(s.ports for s in specs))
        plm = self.generate(env)
        arb = ((len(specs) - 1) * env.ports * max(1, plm.banks)
               * _MUX_AREA_PER_PORT_BANK)
        return PLM(banks=plm.banks, words_per_bank=plm.words_per_bank,
                   area=plm.area + arb, ports=plm.ports,
                   word_bits=plm.word_bits, clients=len(specs))
