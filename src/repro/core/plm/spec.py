"""PLM planning vocabulary: requirements, groups, and system memory plans.

The paper's system cost is the sum of per-component areas, each of which
*includes* a private PLM (hlsim folds Mnemosyne's area into every
synthesis).  The PLM planner breaks that sum apart: every mapped
component states what it *requires* of the memory subsystem
(:class:`PLMRequirement`), the planner groups requirements that may
share physical banks (:mod:`repro.core.plm.compat` certifies the
non-concurrency), and the resulting :class:`MemoryPlan` prices the
memory subsystem once — shared banks instead of private copies — while
datapath (logic) areas stay per-component.

Capacities and areas are unit-tagged (``"mm2"`` for the analytical
backends, ``"bytes"`` for the measured VMEM backend); requirements only
ever share within one unit, and :mod:`repro.core.plm.units` is the
exchange rate that brings a mixed system onto a single unit first.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional, Tuple

from ..knobs import Synthesis

__all__ = ["PLMRequirement", "MemoryGroup", "MemoryPlan",
           "requirement_from_synthesis",
           "memory_plan_to_json", "memory_plan_from_json"]


@dataclass(frozen=True)
class PLMRequirement:
    """One mapped component's demand on the memory subsystem.

    ``capacity`` is in words (unit ``"mm2"``) or bytes (unit
    ``"bytes"``); ``area_plm`` is the area of the *private* PLM the
    paper's per-component sum would charge for it, and ``area_logic``
    the datapath remainder.  ``capacity == 0`` marks a requirement whose
    memory cannot be split from its logic — the planner keeps it alone.
    """

    component: str
    capacity: int
    word_bits: int
    ports: int
    area_plm: float
    area_logic: float
    unit: str = "mm2"
    tile: int = 0


@dataclass(frozen=True)
class MemoryGroup:
    """One physical multi-bank PLM serving ``members`` in time-multiplex.

    ``area`` is the shared PLM's area; ``area_private`` what the same
    members would cost as private copies (the per-component sum).  The
    planner only forms groups with ``area <= area_private``, so
    ``saved`` is never negative.  ``requirements`` keeps the member
    requirements the group was formed from, so the independent race
    detector (:mod:`repro.core.analysis.verify`) can re-derive the
    shared envelope without trusting the planner.
    """

    members: Tuple[str, ...]
    capacity: int
    word_bits: int
    ports: int
    area: float
    area_private: float
    unit: str = "mm2"
    banks: int = 0
    requirements: Tuple["PLMRequirement", ...] = ()

    @property
    def saved(self) -> float:
        return self.area_private - self.area


@dataclass(frozen=True)
class MemoryPlan:
    """The planned system memory subsystem for one mapped design point.

    ``compat_tag`` records which certificate tier formed the plan's
    groups: ``None`` for structural-only compatibility, otherwise the
    :meth:`~repro.core.planning.Schedule.tag` of the schedule whose
    conditional certificates the planner consumed — the plan's sharing
    is only sound while the system runs that schedule.
    """

    groups: Tuple[MemoryGroup, ...]
    area_memory: float            # sum of group areas (shared banks)
    area_logic: float             # sum of per-component datapath areas
    compat_tag: Optional[str] = None

    @property
    def system_cost(self) -> float:
        return self.area_memory + self.area_logic

    @property
    def area_private(self) -> float:
        """The paper's naive cost: every component pays for its own PLM."""
        return self.area_logic + sum(g.area_private for g in self.groups)

    @property
    def saved(self) -> float:
        return sum(g.saved for g in self.groups)

    def group_of(self, component: str) -> Optional[MemoryGroup]:
        for g in self.groups:
            if component in g.members:
                return g
        return None


def memory_plan_to_json(plan: MemoryPlan) -> Dict[str, Any]:
    """The plan as a plain dict — what benchmark artifacts commit so the
    independent verifier (:mod:`repro.core.analysis.verify`) can re-prove
    an emitted plan without re-running the planner."""
    return {
        "compat_tag": plan.compat_tag,
        "area_memory": plan.area_memory,
        "area_logic": plan.area_logic,
        "groups": [
            {"members": list(g.members), "capacity": g.capacity,
             "word_bits": g.word_bits, "ports": g.ports, "area": g.area,
             "area_private": g.area_private, "unit": g.unit,
             "banks": g.banks,
             "requirements": [asdict(r) for r in g.requirements]}
            for g in plan.groups],
    }


def memory_plan_from_json(d: Dict[str, Any]) -> MemoryPlan:
    groups = tuple(
        MemoryGroup(
            members=tuple(g["members"]), capacity=int(g["capacity"]),
            word_bits=int(g["word_bits"]), ports=int(g["ports"]),
            area=float(g["area"]), area_private=float(g["area_private"]),
            unit=g["unit"], banks=int(g.get("banks", 0)),
            requirements=tuple(PLMRequirement(**r)
                               for r in g.get("requirements", ())))
        for g in d["groups"])
    return MemoryPlan(groups=groups,
                      area_memory=float(d["area_memory"]),
                      area_logic=float(d["area_logic"]),
                      compat_tag=d.get("compat_tag"))


def requirement_from_synthesis(component: str, synth: Synthesis, *,
                               unit: str = "mm2") -> PLMRequirement:
    """Generic extraction for backends without a ``plm_requirement``
    method: reads the conventional ``detail`` keys when present, and
    otherwise returns an unsplittable (capacity 0) requirement so the
    plan degrades to the naive per-component sum instead of guessing."""
    detail = synth.detail or {}
    area_plm = detail.get("area_plm")
    if area_plm is None:
        return PLMRequirement(component=component, capacity=0,
                              word_bits=0, ports=synth.ports,
                              area_plm=0.0, area_logic=float(synth.area),
                              unit=unit, tile=synth.tile)
    logic = detail.get("area_logic", synth.area - area_plm)
    return PLMRequirement(
        component=component,
        capacity=int(detail.get("plm_words", 0)),
        word_bits=int(detail.get("word_bits", 32)),
        ports=synth.ports,
        area_plm=float(area_plm), area_logic=float(logic),
        unit=unit, tile=synth.tile)
