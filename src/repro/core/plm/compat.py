"""Memory compatibility graph: which components may share a PLM.

Two components can share physical memory banks only if they never
execute concurrently.  For a timed marked graph that has a clean
structural certificate: the token count of every directed cycle is an
invariant of the firing rule, and a transition holds its cycle's tokens
for the whole firing (it consumes from the cycle at start and produces
back at end).  Hence

    **every pair of distinct transitions on a common cycle whose total
    initial marking is exactly one token is mutually exclusive** —
    while one fires the cycle holds zero free tokens, so the other
    cannot start.

On the WAMI TMG (Fig. 8) this certifies precisely the Lucas-Kanade
refinement loop: ``alg:matrix_resh->warp`` carries one token and the
forward edges carry none, so warp, matrix_sub, sd_update, matrix_mul,
matrix_add and matrix_resh serialize per LK iteration and their PLMs
may be one shared multi-bank memory.  Streaming neighbours connected
through multi-token ping-pong channels (debayer/grayscale, ...) stay
concurrent and keep private PLMs.

The sharing model assumes a stage's PLM holds live data only during its
own load-compute-store window (Fig. 3) — contents are handed over via
TLM channels, not retained between firings — which is the same
assumption Mnemosyne's "address-space compatibility" sharing makes.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, List, Set, Tuple

from ..tmg import TMG

__all__ = ["exclusive_pairs", "MemoryCompatGraph"]


def exclusive_pairs(tmg: TMG) -> FrozenSet[FrozenSet[str]]:
    """All unordered transition pairs certified mutually exclusive by a
    one-token cycle.  Deterministic: derived purely from the marking."""
    pairs: Set[FrozenSet[str]] = set()
    for cyc in tmg.simple_cycles():
        if sum(p.tokens for p in cyc) != 1:
            continue
        names = sorted({p.src for p in cyc})
        for i, u in enumerate(names):
            for v in names[i + 1:]:
                pairs.add(frozenset((u, v)))
    return frozenset(pairs)


class MemoryCompatGraph:
    """Adjacency view over :func:`exclusive_pairs` for the planner.

    ``may_share(u, v)`` is True when the TMG certifies u and v never
    overlap in time.  The graph is static per TMG — build it once and
    reuse it across every mapped design point.
    """

    def __init__(self, tmg: TMG):
        self.names: List[str] = [t.name for t in tmg.transitions]
        self._adj: Dict[str, Set[str]] = {n: set() for n in self.names}
        for pair in exclusive_pairs(tmg):
            u, v = sorted(pair)
            self._adj[u].add(v)
            self._adj[v].add(u)

    def may_share(self, u: str, v: str) -> bool:
        return u != v and v in self._adj.get(u, ())

    def neighbours(self, u: str) -> Tuple[str, ...]:
        return tuple(sorted(self._adj.get(u, ())))

    def cliques_containing(self, members: Tuple[str, ...], cand: str) -> bool:
        """True when ``cand`` is pairwise-compatible with every member."""
        return all(self.may_share(m, cand) for m in members)
