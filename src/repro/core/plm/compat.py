"""Memory compatibility: which components may share a PLM, and why.

Two components can share physical memory banks only if they never
execute concurrently.  The repo certifies that in two tiers:

**Tier 1 — structural.**  For a timed marked graph the token count of
every directed cycle is an invariant of the firing rule, and a
transition holds its cycle's tokens for the whole firing (it consumes
from the cycle at start and produces back at end).  Hence

    **every pair of distinct transitions on a common cycle whose total
    initial marking is exactly one token is mutually exclusive** —
    while one fires the cycle holds zero free tokens, so the other
    cannot start.

This holds for *every* admissible execution.  On the WAMI TMG (Fig. 8)
it certifies precisely the Lucas-Kanade refinement loop:
``alg:matrix_resh->warp`` carries one token and the forward edges carry
none, so warp, matrix_sub, sd_update, matrix_mul, matrix_add and
matrix_resh serialize per LK iteration and their PLMs may be one shared
multi-bank memory.

**Tier 2 — schedule-conditional.**  Streaming neighbours connected
through multi-token ping-pong channels (debayer/grayscale, ...) are
structurally concurrent, but the LP of Eq. (2) solves for initiation
times sigma that pin down exactly *when* each transition is busy.  When
two busy intervals ``[sigma_i, sigma_i + tau_i) mod period`` do not
overlap, the pair is non-concurrent *under that schedule* —
:mod:`repro.core.analysis.intervals` derives these certificates and
:class:`CompatSource` carries both tiers to the planner, tagged with
the schedule they hold under.

The sharing model assumes a stage's PLM holds live data only during its
own load-compute-store window (Fig. 3) — contents are handed over via
TLM channels, not retained between firings — which is the same
assumption Mnemosyne's "address-space compatibility" sharing makes.
"""

from __future__ import annotations

import weakref
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from ..tmg import TMG

__all__ = ["exclusive_pairs", "CompatSource", "MemoryCompatGraph"]

Pair = FrozenSet[str]

# per-TMG caches: the structural certificate is a pure function of the
# marking, so one exploration (hundreds of mapped design points over one
# TMG) computes it exactly once.  Keyed weakly so throwaway test graphs
# do not accumulate.
_PAIRS_CACHE: "weakref.WeakKeyDictionary[TMG, FrozenSet[Pair]]" = (
    weakref.WeakKeyDictionary())
_GRAPH_CACHE: "weakref.WeakKeyDictionary[TMG, MemoryCompatGraph]" = (
    weakref.WeakKeyDictionary())


def exclusive_pairs(tmg: TMG) -> FrozenSet[Pair]:
    """All unordered transition pairs certified mutually exclusive by a
    one-token cycle.  Deterministic: derived purely from the marking.
    Cached per TMG (the docstring's build-once promise, made true)."""
    cached = _PAIRS_CACHE.get(tmg)
    if cached is not None:
        return cached
    pairs: Set[Pair] = set()
    for cyc in tmg.simple_cycles():
        if sum(p.tokens for p in cyc) != 1:
            continue
        names = sorted({p.src for p in cyc})
        for i, u in enumerate(names):
            for v in names[i + 1:]:
                pairs.add(frozenset((u, v)))
    out = frozenset(pairs)
    _PAIRS_CACHE[tmg] = out
    return out


@dataclass(frozen=True)
class CompatSource:
    """The two-tier non-concurrency certificate set the planner consumes.

    ``structural`` pairs hold for every admissible execution of the TMG;
    ``conditional`` pairs hold only under the schedule identified by
    ``tag`` (a :meth:`repro.core.planning.Schedule.tag`).  ``tier``
    answers *why* a pair may share: ``"structural"``, ``"schedule"`` or
    ``None``.
    """

    structural: FrozenSet[Pair]
    conditional: FrozenSet[Pair] = frozenset()
    tag: Optional[str] = None

    def __post_init__(self):
        allp = frozenset(self.structural) | frozenset(self.conditional)
        object.__setattr__(self, "_all", allp)

    @property
    def pairs(self) -> FrozenSet[Pair]:
        return self._all          # type: ignore[attr-defined]

    def may_share(self, u: str, v: str) -> bool:
        return u != v and frozenset((u, v)) in self.pairs

    def tier(self, u: str, v: str) -> Optional[str]:
        key = frozenset((u, v))
        if u == v:
            return None
        if key in self.structural:
            return "structural"
        if key in self.conditional:
            return "schedule"
        return None

    def cliques_containing(self, members: Tuple[str, ...], cand: str) -> bool:
        """True when ``cand`` is pairwise-compatible with every member."""
        return all(self.may_share(m, cand) for m in members)

    @staticmethod
    def structural_for(tmg: TMG) -> "CompatSource":
        return CompatSource(structural=exclusive_pairs(tmg))

    def with_conditional(self, pairs: FrozenSet[Pair],
                         tag: Optional[str]) -> "CompatSource":
        """The same structural tier plus a schedule-conditional tier."""
        return CompatSource(structural=self.structural,
                            conditional=frozenset(pairs) - self.structural,
                            tag=tag)


class MemoryCompatGraph:
    """Adjacency view over :func:`exclusive_pairs` for the planner.

    ``may_share(u, v)`` is True when the TMG certifies u and v never
    overlap in time.  The graph is static per TMG — built once and
    cached (:meth:`for_tmg`), then reused across every mapped design
    point.
    """

    def __init__(self, tmg: TMG):
        self.names: List[str] = [t.name for t in tmg.transitions]
        self._adj: Dict[str, Set[str]] = {n: set() for n in self.names}
        for pair in exclusive_pairs(tmg):
            u, v = sorted(pair)
            self._adj[u].add(v)
            self._adj[v].add(u)

    @classmethod
    def for_tmg(cls, tmg: TMG) -> "MemoryCompatGraph":
        """The cached structural graph for ``tmg`` (built on first use)."""
        g = _GRAPH_CACHE.get(tmg)
        if g is None:
            g = cls(tmg)
            _GRAPH_CACHE[tmg] = g
        return g

    def as_source(self) -> CompatSource:
        """This graph's certificates as a (structural-only) CompatSource."""
        pairs = {frozenset((u, v))
                 for u, vs in self._adj.items() for v in vs}
        return CompatSource(structural=frozenset(pairs))

    def may_share(self, u: str, v: str) -> bool:
        return u != v and v in self._adj.get(u, ())

    def neighbours(self, u: str) -> Tuple[str, ...]:
        return tuple(sorted(self._adj.get(u, ())))

    def cliques_containing(self, members: Tuple[str, ...], cand: str) -> bool:
        """True when ``cand`` is pairwise-compatible with every member."""
        return all(self.may_share(m, cand) for m in members)
