"""One cost unit per system: exchange rates between backend area units.

A mixed drive — PallasOracle pricing the measured components in VMEM
bytes, an analytical fallback pricing the rest in mm² — used to sum the
two straight into one "system cost" (ROADMAP: "One cost unit per
system").  This module closes that hole: it fits, from a measurement
recording alone, (a) the per-component latency scales the analytical
model needs to sit on the measured latency axis and (b) ONE global area
exchange rate (bytes per mm²).  A single multiplier cannot reorder the
analytical backend's own areas, so per-backend dominance is preserved
exactly (property-tested in tests/test_calibrate.py) while the system
sum — and the PLM planner's cross-backend bank sharing — becomes
unit-clean.

Everything is computed from the store's *sorted* entries and an
analytical model query per entry, with no kernel execution: the
measured area is the oracle's own deterministic VMEM formula, so the
fit is byte-reproducible on any machine holding the recording.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from ..calibrate import (CalibratedTool, CalibrationFit, fit_area_scale,
                         fit_latency_scales)
from ..knobs import SynthesisTool

__all__ = ["UnitSystem", "fit_unit_system", "vmem_area_bytes"]


def vmem_area_bytes(spec, ports: int, unrolls: int, *,
                    bank_overhead_bytes: int = 4096) -> float:
    """The PallasOracle area formula, standalone: double-buffered working
    set over the parallel banks plus the per-bank pipeline overhead.
    ``spec`` is any PallasKernelSpec-shaped object (duck-typed)."""
    H, W = spec.shape
    step = spec.vmem_bytes(H, W, ports=ports, unrolls=unrolls)
    return float(2 * step * ports + bank_overhead_bytes * ports)


@dataclass(frozen=True)
class UnitSystem:
    """The fitted exchange rates for one mixed-backend system."""

    unit: str                       # the canonical cost unit ("bytes")
    lam: CalibrationFit             # per-component latency scales
    area_scale: float               # canonical-unit per model-unit
    area_points: int
    area_spread: float              # max/min residual ratio (1.0 = exact)

    def calibrated(self, model: SynthesisTool) -> CalibratedTool:
        """Wrap an analytical tool so it reports measured-axis latencies
        and canonical-unit areas — the fallback a mixed system drive
        (and the PLM planner) can consume directly."""
        return CalibratedTool(model, self.lam, area_scale=self.area_scale,
                              unit=self.unit)


def fit_unit_system(store, components: Dict[str, object],
                    model: SynthesisTool, *,
                    bank_overhead_bytes: int = 4096) -> UnitSystem:
    """Fit a :class:`UnitSystem` from a measurement recording.

    ``store`` is a :class:`~repro.core.pallas_oracle.MeasurementStore`
    (duck-typed: ``.entries`` maps (component, ports, unrolls) to wall
    seconds); ``components`` maps component name to its
    PallasKernelSpec.  For every recorded point the measured latency is
    wall/ports (the oracle's lane-bank convention) and the measured area
    is the oracle's VMEM formula; both fits skip points the analytical
    model deems infeasible.
    """
    lam_pts = []
    area_pts = []
    for key in sorted(store.entries):
        comp, ports, unrolls = key
        spec = components.get(comp)
        if spec is None or not spec.divisible(ports, unrolls):
            continue
        wall = store.entries[key]
        lam_pts.append((comp, ports, unrolls, wall / ports))
        area_pts.append((comp, ports, unrolls,
                         vmem_area_bytes(spec, ports, unrolls,
                                         bank_overhead_bytes=bank_overhead_bytes)))
    lam_fit = fit_latency_scales(model, lam_pts)
    scale, n, spread = fit_area_scale(model, area_pts)
    return UnitSystem(unit="bytes", lam=lam_fit, area_scale=scale,
                      area_points=n, area_spread=spread)
