"""System-level PLM planning: memory as a first-class DSE axis.

The subsystem the paper's memory-coordination story needs end to end:

  * :mod:`.spec`    — requirements, groups, and memory plans;
  * :mod:`.compat`  — the TMG one-token-cycle non-concurrency
    certificate (which components may share banks);
  * :mod:`.planner` — the deterministic greedy shared-bank planner whose
    benefit guard makes the planned system cost pointwise no worse than
    the paper's per-component sum;
  * :mod:`.units`   — fitted exchange rates (latency scales + one global
    area scale) so mixed measured+analytical systems price in one unit.

Entry points: hang a :class:`PLMPlanner` on an
:class:`~repro.core.session.ExplorationSession` (``memory_planner=``),
or run ``benchmarks/fig10_pareto.py --share-plm`` /
``examples/wami_plm.py`` for the WAMI walkthrough (docs/memory.md).
"""

from .compat import MemoryCompatGraph, exclusive_pairs
from .planner import PLMPlanner, shared_area
from .spec import (MemoryGroup, MemoryPlan, PLMRequirement,
                   requirement_from_synthesis)
from .units import UnitSystem, fit_unit_system, vmem_area_bytes

__all__ = [
    "PLMRequirement", "MemoryGroup", "MemoryPlan",
    "requirement_from_synthesis",
    "MemoryCompatGraph", "exclusive_pairs",
    "PLMPlanner", "shared_area",
    "UnitSystem", "fit_unit_system", "vmem_area_bytes",
]
