"""The system-level PLM planner: greedy shared-bank grouping.

Given one mapped implementation per component (a Fig. 10 design point),
the planner replaces the paper's naive memory cost — every component
pays for a private PLM — with a planned memory subsystem: components
certified mutually exclusive by the TMG (:mod:`.compat`) are greedily
packed onto shared multi-bank PLMs, and a group is only formed when the
shared architecture is genuinely cheaper than the private copies it
replaces.  That guard makes the planned system cost *pointwise* no
worse than the per-component sum, so the shared-PLM system front
dominates or equals the naive front by construction; the interesting
question — answered by ``benchmarks/fig10_pareto.py --share-plm`` — is
by how much.

Everything is deterministic: requirements are processed in a fixed
order (descending private PLM area, then name) and groups are scanned
in creation order, so identical inputs produce identical plans.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..knobs import Synthesis
from ..memgen import MemGen, PLMSpec
from ..tmg import TMG
from .compat import CompatSource, MemoryCompatGraph
from .spec import (MemoryGroup, MemoryPlan, PLMRequirement,
                   requirement_from_synthesis)

__all__ = ["PLMPlanner", "shared_area"]

# arbitration cost per extra client of a byte-unit (VMEM) shared bank:
# descriptors + semaphores for the second DMA stream into the same tile
_BYTES_ARB_PER_CLIENT = 4096


def shared_area(reqs: Sequence[PLMRequirement],
                memgen: MemGen) -> Tuple[float, int, int, int, int]:
    """Area of one PLM serving ``reqs`` exclusively, in their unit.

    Returns (area, capacity, word_bits, ports, banks).  ``"mm2"``
    requirements go through :meth:`MemGen.generate_shared`;
    ``"bytes"`` (VMEM) requirements take the envelope footprint plus a
    fixed arbitration overhead per extra client.
    """
    unit = reqs[0].unit
    if any(r.unit != unit for r in reqs):
        raise ValueError("mixed units in one shared group")
    if unit == "bytes":
        area = (max(float(r.area_plm) for r in reqs)
                + _BYTES_ARB_PER_CLIENT * (len(reqs) - 1))
        cap = max(r.capacity for r in reqs)
        return (area, cap, max(r.word_bits for r in reqs),
                max(r.ports for r in reqs), 0)
    plm = memgen.generate_shared([
        PLMSpec(words=r.capacity, word_bits=r.word_bits, ports=r.ports)
        for r in reqs])
    return (plm.area, max(r.capacity for r in reqs), plm.word_bits,
            plm.ports, plm.banks)


class PLMPlanner:
    """Plans the shared memory subsystem for mapped design points.

    ``tmg`` supplies the compatibility certificate (built once);
    ``exclude`` names transitions that have no PLM to share (software
    components such as WAMI's Matrix-Inv).  The planner is stateless
    across calls — every mapped point is planned independently, because
    the mapped port counts (and hence the shared envelopes) differ per
    point.
    """

    def __init__(self, tmg: TMG, *, memgen: Optional[MemGen] = None,
                 exclude: Sequence[str] = ()):
        self.tmg = tmg
        self.compat = MemoryCompatGraph.for_tmg(tmg)   # built once per TMG
        self.memgen = memgen or MemGen()
        self.exclude = frozenset(exclude)

    # ------------------------------------------------------------------
    def requirements(self, tool, syntheses: Dict[str, Synthesis]
                     ) -> List[PLMRequirement]:
        """Extract one requirement per component via the backend's
        ``plm_requirement`` (falling back to the generic detail-based
        extraction), skipping excluded components."""
        out: List[PLMRequirement] = []
        fn = getattr(tool, "plm_requirement", None)
        for name in sorted(syntheses):
            synth = syntheses[name]
            if name in self.exclude:
                # excluded = nothing to SHARE, not free: the component's
                # whole area stays in the plan as unsplittable logic, so
                # the planned cost never silently drops a component
                out.append(PLMRequirement(
                    component=name, capacity=0, word_bits=0,
                    ports=synth.ports, area_plm=0.0,
                    area_logic=float(synth.area), tile=synth.tile))
                continue
            req = fn(name, synth) if fn is not None else None
            if req is None:
                req = requirement_from_synthesis(name, synth)
            out.append(req)
        return out

    def plan(self, requirements: Sequence[PLMRequirement],
             compat: Optional[CompatSource] = None) -> MemoryPlan:
        """Greedy grouping with a strict benefit guard.

        Requirements are seeded largest-first; each one joins the first
        existing group whose members it may all share with (same unit,
        pairwise non-concurrent) *and* whose merged shared area does not
        exceed the group's current area plus the requirement's private
        PLM — otherwise it opens its own group.  Capacity-0
        requirements are unsplittable and always stay alone.

        ``compat`` overrides the planner's structural certificate source
        (e.g. a two-tier :class:`CompatSource` carrying
        schedule-conditional pairs); the plan records the source's tag.
        """
        source = compat if compat is not None else self.compat
        tag = getattr(source, "tag", None)
        order = sorted(requirements,
                       key=lambda r: (-r.area_plm, r.component))
        groups: List[List[PLMRequirement]] = []

        def price(g: List[PLMRequirement]) -> float:
            # a group's PLAN price: singletons keep their exact private
            # area (see the override below) — the guard must compare
            # against the same number the final plan charges, or a
            # backend whose area_plm undercuts the shared model could
            # merge into a group dearer than the private copies
            if len(g) == 1:
                return g[0].area_plm
            return shared_area(g, self.memgen)[0]

        for req in order:
            placed = False
            if req.capacity > 0:
                for g in groups:
                    if g[0].unit != req.unit or g[0].capacity <= 0:
                        continue
                    if not source.cliques_containing(
                            tuple(m.component for m in g), req.component):
                        continue
                    if price(g + [req]) <= price(g) + req.area_plm:
                        g.append(req)
                        placed = True
                        break
            if not placed:
                groups.append([req])

        out: List[MemoryGroup] = []
        logic = 0.0
        for g in groups:
            area, cap, bits, ports, banks = shared_area(g, self.memgen)
            private = sum(r.area_plm for r in g)
            if len(g) == 1:
                # a singleton keeps its exact private PLM price — the
                # shared model must not re-price what is not shared
                area, banks = private, 0
            out.append(MemoryGroup(
                members=tuple(sorted(r.component for r in g)),
                capacity=cap, word_bits=bits, ports=ports,
                area=area, area_private=private, unit=g[0].unit,
                banks=banks,
                requirements=tuple(sorted(
                    g, key=lambda r: r.component))))
            logic += sum(r.area_logic for r in g)
        return MemoryPlan(groups=tuple(out),
                          area_memory=sum(gr.area for gr in out),
                          area_logic=logic, compat_tag=tag)

    # ------------------------------------------------------------------
    def plan_point(self, tool, syntheses: Dict[str, Synthesis],
                   schedule=None, tracer=None) -> MemoryPlan:
        """requirements + plan in one call (what the session's map phase
        invokes per design point).

        ``schedule`` (a :class:`~repro.core.planning.Schedule`) opens
        the second certificate tier: busy-interval analysis of the LP
        solution certifies pairs beyond the structural one-token cycles
        (:mod:`repro.core.analysis.intervals`).  Both the structural-only
        and the two-tier plan are computed and the cheaper one wins
        (ties go structural), so the schedule-aware front is *pointwise*
        no worse than the structural-only front — the same dominance
        argument the benefit guard makes against the private sum.

        ``tracer`` records a ``plm.plan_point`` span tagged with which
        plan won (``plan="structural"|"two_tier"``), the certificate tier
        in play, and the chosen plan's cost/compat tag.
        """
        from ..obs import NULL_TRACER
        tr = tracer if tracer is not None else NULL_TRACER
        with tr.span("plm.plan_point", components=len(syntheses)) as sp:
            reqs = self.requirements(tool, syntheses)
            base = self.plan(reqs)
            if schedule is None:
                sp.set("tier", "structural")
                sp.set("plan", "structural")
                sp.set("cost", base.system_cost)
                sp.set("tag", getattr(base, "compat_tag", None))
                return base
            from ..analysis.intervals import compat_source_for
            sched_plan = self.plan(reqs,
                                   compat_source_for(self.tmg, schedule))
            sp.set("tier", "two_tier")
            if sched_plan.system_cost < base.system_cost:
                sp.set("plan", "two_tier")
                sp.set("cost", sched_plan.system_cost)
                sp.set("tag", getattr(sched_plan, "compat_tag", None))
                return sched_plan
            sp.set("plan", "structural")
            sp.set("cost", base.system_cost)
            sp.set("tag", getattr(base, "compat_tag", None))
            return base
