"""Fit the analytical backend's constants to measured oracle points.

COSMOS treats the synthesis tool as ground truth; analytical models like
``HLSTool`` are stand-ins whose *absolute* numbers are uncalibrated (the
paper's claims are about ratios — hlsim.py).  Once a measured backend
(:class:`~repro.core.pallas_oracle.PallasOracle`) has priced real
(component, knob) points, this module closes the loop: it fits one
latency scale per component — the geometric mean of measured/analytical
over the commonly-feasible points, i.e. the least-squares solution in
log space — and wraps the analytical tool so both backends report
Pareto fronts on a comparable latency axis.  Shapes are NOT refitted:
if the analytical Amdahl profile is wrong within a region, the residual
spread (``lam_spread``) reports it rather than hiding it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .knobs import CDFGFacts, Synthesis, SynthesisTool
from .oracle import InvocationRecord, call_synthesize

__all__ = ["CalibrationFit", "fit_latency_scales", "fit_area_scale",
           "CalibratedTool", "calibrate_to_records"]


@dataclass(frozen=True)
class CalibrationFit:
    """Per-component latency scales + goodness-of-fit diagnostics."""

    scales: Dict[str, float]            # lam_measured ~= scale * lam_model
    points: Dict[str, int]              # fitted points per component
    lam_spread: Dict[str, float]        # max/min residual ratio (1.0 = exact)

    def scale(self, component: str) -> float:
        return self.scales.get(component, 1.0)


def _log_ratios(model: SynthesisTool, measured: Iterable[Tuple],
                axis: str) -> Dict[str, List[float]]:
    """Per-component log(measured / model-``axis``) over usable points.

    ``measured`` rows are (component, ports, unrolls, value) with an
    optional trailing tile — tile-axis drives must be compared against
    the model *at their tile*, not the native one.  Non-positive /
    non-finite measurements and infeasible model points are skipped.
    """
    logs: Dict[str, List[float]] = {}
    for comp, ports, unrolls, value, *rest in measured:
        if not (value > 0.0) or not math.isfinite(value):
            continue
        synth = call_synthesize(model, comp, unrolls=unrolls, ports=ports,
                                tile=rest[0] if rest else 0)
        ref = getattr(synth, axis)
        if not synth.feasible or ref <= 0:
            continue
        logs.setdefault(comp, []).append(math.log(value / ref))
    # order-independent float sums -> deterministic fits
    return {comp: sorted(ls) for comp, ls in logs.items()}


def fit_latency_scales(
        model: SynthesisTool,
        measured: Iterable[Tuple[str, int, int, float]]) -> CalibrationFit:
    """``measured``: (component, ports, unrolls, lam_measured[, tile])
    points.

    Infeasible model points and non-positive measurements are skipped;
    a component with no usable overlap keeps scale 1.0 (reported with
    points=0).
    """
    scales, points, spread = {}, {}, {}
    for comp, ls in _log_ratios(model, measured, "lam").items():
        scales[comp] = math.exp(sum(ls) / len(ls))
        points[comp] = len(ls)
        spread[comp] = math.exp(ls[-1] - ls[0]) if len(ls) > 1 else 1.0
    return CalibrationFit(scales=scales, points=points, lam_spread=spread)


def fit_area_scale(model: SynthesisTool,
                   measured: Iterable[Tuple[str, int, int, float]]
                   ) -> Tuple[float, int, float]:
    """Fit ONE global area exchange rate measured-unit-per-model-unit.

    ``measured``: (component, ports, unrolls, area_measured[, tile])
    points in the measured backend's unit (e.g. VMEM bytes).  The scale
    is the log-space least-squares solution over every usable point —
    global rather than per-component on purpose: a single multiplier
    cannot reorder model-unit areas, so dominance relations *within*
    the analytical backend are preserved exactly
    (tests/test_calibrate.py proves the property).  Returns
    (scale, n_points, residual spread); (1.0, 0, 1.0) when nothing
    overlaps.
    """
    logs = sorted(ls for per_comp in
                  _log_ratios(model, measured, "area").values()
                  for ls in per_comp)
    if not logs:
        return 1.0, 0, 1.0
    scale = math.exp(sum(logs) / len(logs))
    spread = math.exp(logs[-1] - logs[0]) if len(logs) > 1 else 1.0
    return scale, len(logs), spread


def calibrate_to_records(model: SynthesisTool,
                         records: Sequence[InvocationRecord]
                         ) -> CalibrationFit:
    """Fit from an :class:`OracleLedger`'s records of a measured drive
    (the feasible ones carry the measured lambda; tile-axis records
    are compared against the model at their own tile)."""
    return fit_latency_scales(
        model, ((r.component, r.ports, r.unrolls, r.lam, r.tile)
                for r in records if r.feasible))


class CalibratedTool:
    """An analytical SynthesisTool with per-component latency scales.

    By default areas are left untouched — the two backends price cost in
    different units (mm^2 vs VMEM bytes) on purpose; only the latency
    axis, which the TMG throughput composes, is brought onto the
    measured scale.  Pass ``area_scale`` (see :func:`fit_area_scale` /
    :mod:`repro.core.plm.units`) to also convert areas into the measured
    backend's cost unit — a single global multiplier, so min-min
    dominance among this tool's own points is preserved; ``unit`` then
    tags the converted requirements for the PLM planner.
    """

    def __init__(self, model: SynthesisTool, fit: CalibrationFit, *,
                 area_scale: float = 1.0, unit: str = "mm2"):
        self.model = model
        self.fit = fit
        self.area_scale = float(area_scale)
        self.unit = unit

    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None,
                   tile: int = 0) -> Synthesis:
        s = call_synthesize(self.model, component, unrolls=unrolls,
                            ports=ports, max_states=max_states, tile=tile)
        if not s.feasible:
            return s
        k = self.fit.scale(component)
        a = self.area_scale
        detail = {**s.detail, "lam_scale": k}
        if a != 1.0:
            detail["area_scale"] = a
            for key in ("area_logic", "area_plm"):
                if key in detail:
                    detail[key] = detail[key] * a
        return Synthesis(lam=s.lam * k, area=s.area * a, ports=s.ports,
                         unrolls=s.unrolls,
                         states_per_iter=s.states_per_iter,
                         feasible=s.feasible,
                         detail=detail, tile=s.tile)

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self.model.cdfg_facts(component, synth)

    def plm_requirement(self, component: str, synth: Synthesis):
        """Requirements in this tool's unit, so calibrated components can
        share banks with (and sum cleanly against) the measured
        backend's.  Built from the already-converted synthesis detail —
        delegating to the model would re-scale areas a second time."""
        if self.area_scale == 1.0:
            fn = getattr(self.model, "plm_requirement", None)
            return None if fn is None else fn(component, synth)
        # lazy: repro.core.plm.units imports this module
        from dataclasses import replace as _replace

        from .plm.spec import requirement_from_synthesis
        req = requirement_from_synthesis(component, synth, unit=self.unit)
        if self.unit == "bytes" and req.capacity:
            # requirement_from_synthesis reports capacity in PLM words;
            # byte-unit groups compare capacities against VMEM bytes
            req = _replace(req,
                           capacity=req.capacity * max(8, req.word_bits) // 8)
        return req
