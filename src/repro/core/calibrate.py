"""Fit the analytical backend's constants to measured oracle points.

COSMOS treats the synthesis tool as ground truth; analytical models like
``HLSTool`` are stand-ins whose *absolute* numbers are uncalibrated (the
paper's claims are about ratios — hlsim.py).  Once a measured backend
(:class:`~repro.core.pallas_oracle.PallasOracle`) has priced real
(component, knob) points, this module closes the loop: it fits one
latency scale per component — the geometric mean of measured/analytical
over the commonly-feasible points, i.e. the least-squares solution in
log space — and wraps the analytical tool so both backends report
Pareto fronts on a comparable latency axis.  Shapes are NOT refitted:
if the analytical Amdahl profile is wrong within a region, the residual
spread (``lam_spread``) reports it rather than hiding it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .knobs import CDFGFacts, Synthesis, SynthesisTool
from .oracle import InvocationRecord

__all__ = ["CalibrationFit", "fit_latency_scales", "CalibratedTool",
           "calibrate_to_records"]


@dataclass(frozen=True)
class CalibrationFit:
    """Per-component latency scales + goodness-of-fit diagnostics."""

    scales: Dict[str, float]            # lam_measured ~= scale * lam_model
    points: Dict[str, int]              # fitted points per component
    lam_spread: Dict[str, float]        # max/min residual ratio (1.0 = exact)

    def scale(self, component: str) -> float:
        return self.scales.get(component, 1.0)


def fit_latency_scales(
        model: SynthesisTool,
        measured: Iterable[Tuple[str, int, int, float]]) -> CalibrationFit:
    """``measured``: (component, ports, unrolls, lam_measured) points.

    Infeasible model points and non-positive measurements are skipped;
    a component with no usable overlap keeps scale 1.0 (reported with
    points=0).
    """
    logs: Dict[str, List[float]] = {}
    for comp, ports, unrolls, lam in measured:
        if not (lam > 0.0) or not math.isfinite(lam):
            continue
        synth = model.synthesize(comp, unrolls=unrolls, ports=ports)
        if not synth.feasible or synth.lam <= 0:
            continue
        logs.setdefault(comp, []).append(math.log(lam / synth.lam))
    scales, points, spread = {}, {}, {}
    for comp, ls in logs.items():
        mean = sum(ls) / len(ls)
        scales[comp] = math.exp(mean)
        points[comp] = len(ls)
        spread[comp] = math.exp(max(ls) - min(ls)) if len(ls) > 1 else 1.0
    return CalibrationFit(scales=scales, points=points, lam_spread=spread)


def calibrate_to_records(model: SynthesisTool,
                         records: Sequence[InvocationRecord]
                         ) -> CalibrationFit:
    """Fit from an :class:`OracleLedger`'s records of a measured drive
    (the feasible ones carry the measured lambda)."""
    return fit_latency_scales(
        model, ((r.component, r.ports, r.unrolls, r.lam)
                for r in records if r.feasible))


class CalibratedTool:
    """An analytical SynthesisTool with per-component latency scales.

    Areas are left untouched — the two backends price cost in different
    units (mm^2 vs VMEM bytes) on purpose; only the latency axis, which
    the TMG throughput composes, is brought onto the measured scale.
    """

    def __init__(self, model: SynthesisTool, fit: CalibrationFit):
        self.model = model
        self.fit = fit

    def synthesize(self, component: str, *, unrolls: int, ports: int,
                   max_states: Optional[int] = None) -> Synthesis:
        s = self.model.synthesize(component, unrolls=unrolls, ports=ports,
                                  max_states=max_states)
        if not s.feasible:
            return s
        k = self.fit.scale(component)
        return Synthesis(lam=s.lam * k, area=s.area, ports=s.ports,
                         unrolls=s.unrolls,
                         states_per_iter=s.states_per_iter,
                         feasible=s.feasible,
                         detail={**s.detail, "lam_scale": k})

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self.model.cdfg_facts(component, synth)
