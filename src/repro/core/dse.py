"""The COSMOS driver (Fig. 1) and the exhaustive-search baseline.

``cosmos_dse`` — component characterization (Algorithm 1) + synthesis
planning (Eq. 2 LP over the TMG) + synthesis mapping (phi) — is now a
thin wrapper over :class:`repro.core.session.ExplorationSession`, which
batches every independent oracle invocation per phase; ``workers=1``
reproduces the seed's sequential drive call-for-call.  The exhaustive
baseline synthesizes every (ports x unrolls) combination per component —
the paper's Fig. 11 reference — in one batch (all points are
independent), and, for small systems, composes the per-component Pareto
fronts to the exact system front (Fig. 5), which is what COSMOS's mapped
curve is validated against in the tests.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .knobs import KnobSpace
from .oracle import InvocationRequest, OracleCache, OracleLedger
from .pareto import DesignPoint, pareto_front_max_min, pareto_front_min_min
from .session import (CosmosResult, ExplorationSession, ProgressEvent,
                      SystemPoint)
from .tmg import TMG

__all__ = ["SystemPoint", "CosmosResult", "cosmos_dse",
           "ExhaustiveResult", "exhaustive_dse", "compose_exhaustive"]


def cosmos_dse(tmg: TMG, tool, spaces: Dict[str, KnobSpace],
               *, delta: float = 0.25,
               fixed: Optional[Dict[str, float]] = None,
               counting: Optional[OracleLedger] = None,
               workers: int = 1,
               cache: Optional[OracleCache] = None,
               on_event: Optional[Callable[[ProgressEvent], None]] = None
               ) -> CosmosResult:
    """Run the complete COSMOS methodology on a system TMG.

    ``spaces`` maps component name -> knob bounds; ``fixed`` maps
    components executed in software (Matrix-Inv in Fig. 8) to their fixed
    effective latency — they are excluded from synthesis.  ``counting``
    accepts a pre-built :class:`OracleLedger` (the legacy ``CountingTool``
    is one) when the caller wants to share accounting across runs;
    ``workers`` > 1 batches each phase's independent invocations without
    changing any result or count.
    """
    session = ExplorationSession(tmg, tool, spaces, delta=delta, fixed=fixed,
                                 ledger=counting, cache=cache,
                                 workers=workers, on_event=on_event)
    return session.run()


# ----------------------------------------------------------------------
# Exhaustive baseline (Section 3.3 / Fig. 11 reference)
# ----------------------------------------------------------------------
@dataclass
class ExhaustiveResult:
    points: Dict[str, List[DesignPoint]]     # every synthesized point
    fronts: Dict[str, List[DesignPoint]]     # per-component Pareto fronts
    invocations: Dict[str, int]

    @property
    def total_invocations(self) -> int:
        return sum(self.invocations.values())

    def combinations(self) -> float:
        """Number of system-level combinations an exhaustive composition
        must check: prod_i |front_i| (paper: > 9e12 for WAMI)."""
        out = 1.0
        for f in self.fronts.values():
            out *= max(1, len(f))
        return out


def exhaustive_dse(components: Sequence[str], tool,
                   spaces: Dict[str, KnobSpace],
                   counting: Optional[OracleLedger] = None,
                   *, workers: int = 1) -> ExhaustiveResult:
    """Step (i) of the exhaustive method: synthesize ALL knob combinations.

    Every point is independent, so the whole sweep is a single
    ``evaluate_batch`` over the ledger; results (and counts — every
    unique point is invoked exactly once, feasible or not) are identical
    to the sequential drive regardless of ``workers``.
    """
    ctool = counting or OracleLedger(tool, workers=workers)
    requests: List[InvocationRequest] = []
    spans: List[Tuple[str, int, int]] = []      # (component, start, stop)
    for name in components:
        space = spaces[name]
        start = len(requests)
        for tile in space.tiles():
            for ports in space.ports():
                for unrolls in range(max(1, ports), space.max_unrolls + 1):
                    requests.append(InvocationRequest(
                        component=name, unrolls=unrolls, ports=ports,
                        tile=tile))
        spans.append((name, start, len(requests)))

    results = ctool.evaluate_batch(requests, workers=workers)

    points: Dict[str, List[DesignPoint]] = {}
    for name, start, stop in spans:
        pts: List[DesignPoint] = []
        for req, s in zip(requests[start:stop], results[start:stop]):
            if s.feasible:
                knobs = [("ports", req.ports), ("unrolls", req.unrolls)]
                if req.tile:
                    knobs.append(("tile", req.tile))
                pts.append(DesignPoint(perf=s.lam, cost=s.area,
                                       knobs=tuple(knobs)))
        points[name] = pts
    fronts = {n: pareto_front_min_min(p) for n, p in points.items()}
    inv = {n: ctool.invocations[n] for n, _, _ in spans
           if n in ctool.invocations}
    for name, n in ctool.invocations.items():
        inv.setdefault(name, n)
    return ExhaustiveResult(points=points, fronts=fronts, invocations=inv)


def compose_exhaustive(tmg: TMG, fronts: Dict[str, List[DesignPoint]],
                       fixed: Optional[Dict[str, float]] = None,
                       limit: int = 2_000_000) -> List[DesignPoint]:
    """Step (iii): compose per-component Pareto points into the exact
    system front.  Exponential — only for small systems / tests."""
    fixed = fixed or {}
    names = [t.name for t in tmg.transitions]
    choice_lists: List[List[Tuple[float, float]]] = []
    for n in names:
        if n in fixed:
            choice_lists.append([(fixed[n], 0.0)])
        else:
            choice_lists.append([(p.perf, p.cost) for p in fronts[n]])
    total = 1
    for cl in choice_lists:
        total *= len(cl)
    if total > limit:
        raise ValueError(f"{total} combinations exceed limit {limit}")
    out: List[DesignPoint] = []
    for combo in itertools.product(*choice_lists):
        delays = {n: c[0] for n, c in zip(names, combo)}
        cost = sum(c[1] for c in combo)
        out.append(DesignPoint(perf=tmg.throughput(delays), cost=cost))
    return pareto_front_max_min(out)
