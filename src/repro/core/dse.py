"""The full COSMOS driver (Fig. 1) and the exhaustive-search baseline.

COSMOS = component characterization (Algorithm 1) + synthesis planning
(Eq. 2 LP over the TMG) + synthesis mapping (phi).  The exhaustive
baseline synthesizes every (ports x unrolls) combination per component —
the paper's Fig. 11 reference — and, for small systems, composes the
per-component Pareto fronts to the exact system front (Fig. 5), which is
what COSMOS's mapped curve is validated against in the tests.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .characterize import CharacterizationResult, characterize_component
from .knobs import CountingTool, KnobSpace, SynthesisTool
from .mapping import MapOutcome, map_target
from .pareto import DesignPoint, pareto_front_max_min, pareto_front_min_min
from .planning import ComponentModel, PlanPoint, sweep, theta_bounds
from .tmg import TMG

__all__ = ["SystemPoint", "CosmosResult", "cosmos_dse",
           "ExhaustiveResult", "exhaustive_dse", "compose_exhaustive"]


@dataclass(frozen=True)
class SystemPoint:
    """A mapped system implementation (one point of Fig. 10)."""

    theta_planned: float
    cost_planned: float
    theta_actual: float
    cost_actual: float
    outcomes: Tuple[MapOutcome, ...]

    @property
    def sigma_mismatch(self) -> float:
        """sigma(d_p, d_m) = |d_m - d_p| / d_p  (Section 7.3)."""
        if self.cost_planned <= 0:
            return float("inf")
        return abs(self.cost_actual - self.cost_planned) / self.cost_planned

    def as_design_point(self) -> DesignPoint:
        return DesignPoint(perf=self.theta_actual, cost=self.cost_actual)


@dataclass
class CosmosResult:
    characterizations: Dict[str, CharacterizationResult]
    planned: List[PlanPoint]
    mapped: List[SystemPoint]
    invocations: Dict[str, int]         # total per component (char + map)
    theta_min: float
    theta_max: float

    @property
    def total_invocations(self) -> int:
        return sum(self.invocations.values())

    def pareto(self) -> List[DesignPoint]:
        return pareto_front_max_min([m.as_design_point() for m in self.mapped])


def cosmos_dse(tmg: TMG, tool: SynthesisTool, spaces: Dict[str, KnobSpace],
               *, delta: float = 0.25,
               fixed: Optional[Dict[str, float]] = None,
               counting: Optional[CountingTool] = None) -> CosmosResult:
    """Run the complete COSMOS methodology on a system TMG.

    ``spaces`` maps component name -> knob bounds; ``fixed`` maps
    components executed in software (Matrix-Inv in Fig. 8) to their fixed
    effective latency — they are excluded from synthesis.
    """
    fixed = fixed or {}
    ctool = counting or CountingTool(tool)

    # ---- step 1: component characterization (Algorithm 1) -------------
    chars: Dict[str, CharacterizationResult] = {}
    models: Dict[str, ComponentModel] = {}
    for t in tmg.transitions:
        name = t.name
        if name in fixed:
            models[name] = ComponentModel.fixed_latency(name, fixed[name])
            continue
        res = characterize_component(ctool, name, spaces[name])
        chars[name] = res
        models[name] = ComponentModel.from_regions(name, res.regions)

    # ---- step 2a: synthesis planning (Eq. 2 sweep) ---------------------
    th_lo, th_hi = theta_bounds(tmg, models)
    planned = sweep(tmg, models, delta)

    # ---- step 2b: synthesis mapping (phi) ------------------------------
    mapped: List[SystemPoint] = []
    for plan_pt in planned:
        outcomes: List[MapOutcome] = []
        lam_actual: Dict[str, float] = {}
        cost_actual = 0.0
        for t in tmg.transitions:
            name = t.name
            if name in fixed:
                lam_actual[name] = fixed[name]
                continue
            out = map_target(ctool, name, chars[name].regions,
                             plan_pt.lam_targets[name])
            outcomes.append(out)
            lam_actual[name] = out.synthesis.lam
            cost_actual += out.synthesis.area
        theta_actual = tmg.throughput(lam_actual)
        mapped.append(SystemPoint(theta_planned=plan_pt.theta,
                                  cost_planned=plan_pt.cost,
                                  theta_actual=theta_actual,
                                  cost_actual=cost_actual,
                                  outcomes=tuple(outcomes)))

    return CosmosResult(characterizations=chars, planned=planned,
                        mapped=mapped, invocations=dict(ctool.invocations),
                        theta_min=th_lo, theta_max=th_hi)


# ----------------------------------------------------------------------
# Exhaustive baseline (Section 3.3 / Fig. 11 reference)
# ----------------------------------------------------------------------
@dataclass
class ExhaustiveResult:
    points: Dict[str, List[DesignPoint]]     # every synthesized point
    fronts: Dict[str, List[DesignPoint]]     # per-component Pareto fronts
    invocations: Dict[str, int]

    @property
    def total_invocations(self) -> int:
        return sum(self.invocations.values())

    def combinations(self) -> float:
        """Number of system-level combinations an exhaustive composition
        must check: prod_i |front_i| (paper: > 9e12 for WAMI)."""
        out = 1.0
        for f in self.fronts.values():
            out *= max(1, len(f))
        return out


def exhaustive_dse(components: Sequence[str], tool: SynthesisTool,
                   spaces: Dict[str, KnobSpace],
                   counting: Optional[CountingTool] = None) -> ExhaustiveResult:
    """Step (i) of the exhaustive method: synthesize ALL knob combinations."""
    ctool = counting or CountingTool(tool)
    points: Dict[str, List[DesignPoint]] = {}
    for name in components:
        space = spaces[name]
        pts: List[DesignPoint] = []
        for ports in space.ports():
            for unrolls in range(max(1, ports), space.max_unrolls + 1):
                s = ctool.synthesize(name, unrolls=unrolls, ports=ports)
                if s.feasible:
                    pts.append(DesignPoint(
                        perf=s.lam, cost=s.area,
                        knobs=(("ports", ports), ("unrolls", unrolls))))
        points[name] = pts
    fronts = {n: pareto_front_min_min(p) for n, p in points.items()}
    return ExhaustiveResult(points=points, fronts=fronts,
                            invocations=dict(ctool.invocations))


def compose_exhaustive(tmg: TMG, fronts: Dict[str, List[DesignPoint]],
                       fixed: Optional[Dict[str, float]] = None,
                       limit: int = 2_000_000) -> List[DesignPoint]:
    """Step (iii): compose per-component Pareto points into the exact
    system front.  Exponential — only for small systems / tests."""
    fixed = fixed or {}
    names = [t.name for t in tmg.transitions]
    choice_lists: List[List[Tuple[float, float]]] = []
    for n in names:
        if n in fixed:
            choice_lists.append([(fixed[n], 0.0)])
        else:
            choice_lists.append([(p.perf, p.cost) for p in fronts[n]])
    total = 1
    for cl in choice_lists:
        total *= len(cl)
    if total > limit:
        raise ValueError(f"{total} combinations exceed limit {limit}")
    out: List[DesignPoint] = []
    for combo in itertools.product(*choice_lists):
        delays = {n: c[0] for n, c in zip(names, combo)}
        cost = sum(c[1] for c in combo)
        out.append(DesignPoint(perf=tmg.throughput(delays), cost=cost))
    return pareto_front_max_min(out)
