"""ExplorationSession: the batched, resumable COSMOS drive.

The seed's ``cosmos_dse`` was a one-shot, strictly sequential function
wired straight into a single-call tool.  This module re-expresses the
same methodology as an object with explicit phases —

    session.characterize()   # Algorithm 1, ALL components concurrently
    session.plan()           # Eq. (2) LP sweep over the TMG
    session.map()            # phi mapping, ALL plan points concurrently
    session.result()         # -> CosmosResult (unchanged surface)

— each phase batching every independent oracle invocation through the
:class:`~repro.core.oracle.OracleLedger`.  Because the ledger
de-duplicates identical knob points in flight and every backend is pure,
a batched drive produces *byte-identical* fronts and invocation counts
to the sequential one; only the wall clock changes.

Sessions also emit :class:`ProgressEvent`s and serialize/restore
mid-run: completed phases are checkpointed through
:mod:`repro.checkpoint.store` and a restored session continues from the
first unfinished phase (pair with a
:class:`~repro.core.oracle.PersistentOracleCache` to also skip the
already-paid tool invocations).

``cosmos_dse`` in :mod:`repro.core.dse` is now a thin wrapper over this
class, so the seed's published surface keeps working.
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

from .characterize import CharacterizationResult, characterize_component
from .knobs import CDFGFacts, KnobSpace, Region
from .mapping import MapOutcome, map_target
from .surrogate import RidgeSurrogate, guided_characterize_component
from .obs import NULL_TRACER
from .oracle import (OracleCache, OracleLedger, _synth_from_json,
                     _synth_to_json)
from .pareto import DesignPoint, pareto_front_max_min
from .planning import ComponentModel, PlanPoint, Schedule, sweep, theta_bounds
from .tmg import TMG

__all__ = ["SystemPoint", "CosmosResult", "ProgressEvent", "DSEQuery",
           "ExplorationSession"]


@dataclass(frozen=True)
class SystemPoint:
    """A mapped system implementation (one point of Fig. 10).

    When the session carries a PLM planner, ``cost_actual`` is the
    planned shared-memory system cost, ``cost_unshared`` keeps the
    paper's naive per-component sum for comparison, and ``plm_groups``
    records the shared-bank grouping (members of singleton groups are
    omitted).  Without a planner ``cost_unshared`` is None and
    ``cost_actual`` is the naive sum, exactly as before.
    """

    theta_planned: float
    cost_planned: float
    theta_actual: float
    cost_actual: float
    outcomes: Tuple[MapOutcome, ...]
    cost_unshared: Optional[float] = None
    plm_groups: Tuple[Tuple[str, ...], ...] = ()
    # the full emitted plan (None without a planner) — what benchmarks
    # commit as *.plans.json and the analysis verifier re-proves
    memory_plan: Optional[Any] = None
    schedule: Optional[Schedule] = None

    @property
    def sigma_mismatch(self) -> float:
        """sigma(d_p, d_m) = |d_m - d_p| / d_p  (Section 7.3)."""
        if self.cost_planned <= 0:
            return float("inf")
        return abs(self.cost_actual - self.cost_planned) / self.cost_planned

    def as_design_point(self) -> DesignPoint:
        return DesignPoint(perf=self.theta_actual, cost=self.cost_actual)


@dataclass
class CosmosResult:
    characterizations: Dict[str, CharacterizationResult]
    planned: List[PlanPoint]
    mapped: List[SystemPoint]
    invocations: Dict[str, int]         # total per component (char + map)
    theta_min: float
    theta_max: float

    @property
    def total_invocations(self) -> int:
        return sum(self.invocations.values())

    def pareto(self) -> List[DesignPoint]:
        return pareto_front_max_min([m.as_design_point() for m in self.mapped])


@dataclass(frozen=True)
class ProgressEvent:
    """One progress tick: ``done``/``total`` work units within ``phase``."""

    phase: str                   # "characterize" | "plan" | "map"
    label: str                   # component name / plan-point label
    done: int
    total: int


@dataclass(frozen=True)
class DSEQuery:
    """One DSE request, as data: the session-as-query entry point.

    Everything :func:`~repro.core.registry.build_session` resolves —
    app, backend, budget (``delta``), PLM sharing, tile axes — plus the
    ``tenant`` label the service uses for attribution.  Hashable, so a
    query can key caches and coalescing pools.

    ``pool_key`` names the oracle pool the query may share with other
    tenants: everything that changes what the *tool* answers for a knob
    key.  ``share_plm`` is part of it because the measured backends
    price unrecorded points through a different (unit-calibrated)
    fallback under ``share_plm``; ``delta``/``tile_sizes``/``workers``
    are not, because they only change which points a session asks for,
    never a point's price.
    """

    app: str
    backend: str = "analytical"
    delta: Optional[float] = None
    share_plm: bool = False
    tile_sizes: Optional[Tuple[int, ...]] = None
    tiles: Optional[Tuple[int, ...]] = None
    workers: int = 1
    tenant: str = ""

    def __post_init__(self):
        # tolerate list inputs (queries arrive from JSON-ish callers)
        for name in ("tile_sizes", "tiles"):
            val = getattr(self, name)
            if val is not None and not isinstance(val, tuple):
                object.__setattr__(self, name, tuple(val))

    @property
    def pool_key(self) -> Tuple[str, str, bool, Tuple[int, ...]]:
        return (self.app, self.backend, self.share_plm, self.tiles or ())


# ----------------------------------------------------------------------
# JSON codecs for mid-run serialization
# ----------------------------------------------------------------------
def _facts_to_json(f: Optional[CDFGFacts]) -> Optional[Dict[str, Any]]:
    if f is None:
        return None
    return {"gamma_r": f.gamma_r, "gamma_w": f.gamma_w, "eta": f.eta,
            "trip": f.trip, "has_plm_access": f.has_plm_access}


def _facts_from_json(d: Optional[Dict[str, Any]]) -> Optional[CDFGFacts]:
    if d is None:
        return None
    return CDFGFacts(**d)


def _region_to_json(r: Region) -> Dict[str, Any]:
    return {"ports": r.ports, "lam_max": r.lam_max, "area_min": r.area_min,
            "lam_min": r.lam_min, "area_max": r.area_max, "mu_min": r.mu_min,
            "mu_max": r.mu_max, "facts": _facts_to_json(r.facts),
            "tile": r.tile}


def _region_from_json(d: Dict[str, Any]) -> Region:
    d = dict(d)
    d["facts"] = _facts_from_json(d["facts"])
    d.setdefault("tile", 0)       # pre-tile session snapshots
    return Region(**d)


def _dp_to_json(p: DesignPoint) -> Dict[str, Any]:
    return {"perf": p.perf, "cost": p.cost,
            "knobs": [list(kv) for kv in p.knobs],
            "meta": [list(kv) for kv in p.meta]}


def _dp_from_json(d: Dict[str, Any]) -> DesignPoint:
    return DesignPoint(perf=d["perf"], cost=d["cost"],
                       knobs=tuple((k, v) for k, v in d["knobs"]),
                       meta=tuple((k, v) for k, v in d["meta"]))


def _char_to_json(c: CharacterizationResult) -> Dict[str, Any]:
    return {"component": c.component,
            "regions": [_region_to_json(r) for r in c.regions],
            "points": [_dp_to_json(p) for p in c.points],
            "invocations": c.invocations, "failed": c.failed}


def _char_from_json(d: Dict[str, Any]) -> CharacterizationResult:
    return CharacterizationResult(
        component=d["component"],
        regions=[_region_from_json(r) for r in d["regions"]],
        points=[_dp_from_json(p) for p in d["points"]],
        invocations=d["invocations"], failed=d["failed"])


def _plan_to_json(p: PlanPoint) -> Dict[str, Any]:
    out = {"theta": p.theta, "cost": p.cost,
           "lam_targets": dict(p.lam_targets)}
    if p.schedule is not None:
        out["schedule"] = p.schedule.to_json()
    return out


def _plan_from_json(d: Dict[str, Any]) -> PlanPoint:
    sched = d.get("schedule")     # pre-schedule snapshots: None
    if sched is not None:
        sched = Schedule.from_json(sched)
    return PlanPoint(theta=d["theta"], cost=d["cost"],
                     lam_targets=dict(d["lam_targets"]), schedule=sched)


def _outcome_to_json(o: MapOutcome) -> Dict[str, Any]:
    return {"component": o.component,
            "synthesis": _synth_to_json(o.synthesis),
            "region": None if o.region is None else _region_to_json(o.region),
            "requested_lam": o.requested_lam, "fallback": o.fallback}


def _outcome_from_json(d: Dict[str, Any]) -> MapOutcome:
    region = d["region"]
    return MapOutcome(component=d["component"],
                      synthesis=_synth_from_json(d["synthesis"]),
                      region=None if region is None
                      else _region_from_json(region),
                      requested_lam=d["requested_lam"],
                      fallback=d["fallback"])


def _system_to_json(m: SystemPoint) -> Dict[str, Any]:
    """Serialize one mapped point — including the PR-6 fields
    (``schedule`` and the memory plan's ``compat_tag``), which must
    survive a save/restore cycle byte-identically."""
    out: Dict[str, Any] = {
        "theta_planned": m.theta_planned, "cost_planned": m.cost_planned,
        "theta_actual": m.theta_actual, "cost_actual": m.cost_actual,
        "outcomes": [_outcome_to_json(o) for o in m.outcomes],
        "cost_unshared": m.cost_unshared,
        "plm_groups": [list(g) for g in m.plm_groups],
    }
    if m.memory_plan is not None:
        from .plm.spec import memory_plan_to_json
        out["memory_plan"] = memory_plan_to_json(m.memory_plan)
    if m.schedule is not None:
        out["schedule"] = m.schedule.to_json()
    return out


def _system_from_json(d: Dict[str, Any]) -> SystemPoint:
    mem = d.get("memory_plan")
    if mem is not None:
        from .plm.spec import memory_plan_from_json
        mem = memory_plan_from_json(mem)
    sched = d.get("schedule")
    if sched is not None:
        sched = Schedule.from_json(sched)
    return SystemPoint(
        theta_planned=d["theta_planned"], cost_planned=d["cost_planned"],
        theta_actual=d["theta_actual"], cost_actual=d["cost_actual"],
        outcomes=tuple(_outcome_from_json(o) for o in d["outcomes"]),
        cost_unshared=d["cost_unshared"],
        plm_groups=tuple(tuple(g) for g in d["plm_groups"]),
        memory_plan=mem, schedule=sched)


# ----------------------------------------------------------------------
# The session
# ----------------------------------------------------------------------
class ExplorationSession:
    """One COSMOS exploration of a system TMG over a synthesis oracle.

    ``tool`` is any oracle backend (``HLSTool``, ``XLATool``,
    ``XLAOracle``, or anything matching the ``SynthesisTool``/``Oracle``
    protocols); it is wrapped in an :class:`OracleLedger` unless a ledger
    is passed directly.  ``workers`` bounds the per-phase fan-out (1
    reproduces the seed's sequential drive call-for-call).  ``fixed``
    maps software components (Matrix-Inv in Fig. 8) to their fixed
    effective latency — they join the TMG but are never synthesized.
    ``memory_planner`` (a :class:`~repro.core.plm.planner.PLMPlanner`)
    replaces the map phase's naive per-component cost sum with the
    planned shared-PLM system cost; the naive sum is kept on every
    :class:`SystemPoint` as ``cost_unshared``.  Each plan point's solved
    LP schedule is handed to the planner (when its ``plan_point``
    accepts one), opening the schedule-conditional certificate tier.
    ``verify_plans=True`` adds a strict post-pass: every emitted memory
    plan is independently re-proved race-free by
    :mod:`repro.core.analysis.verify`, and the session raises
    :class:`~repro.core.analysis.verify.PlanVerificationError` on the
    first violation instead of returning an unsound point.
    """

    def __init__(self, tmg: TMG, tool, spaces: Dict[str, KnobSpace], *,
                 delta: float = 0.25,
                 fixed: Optional[Dict[str, float]] = None,
                 ledger: Optional[OracleLedger] = None,
                 cache: Optional[OracleCache] = None,
                 workers: int = 1,
                 memory_planner=None,
                 verify_plans: bool = False,
                 pricer=None,
                 surrogate=None,
                 tracer=None,
                 on_event: Optional[Callable[[ProgressEvent], None]] = None):
        self.tmg = tmg
        self.spaces = dict(spaces)
        self.delta = float(delta)
        self.fixed = dict(fixed or {})
        self.workers = max(1, int(workers))
        self.memory_planner = memory_planner
        self.verify_plans = bool(verify_plans)
        # surrogate-guided characterization (core.surrogate): a
        # BatchPricer turns the Algorithm-1 walk into grid lookups and
        # the surrogate picks which corner to confirm through the real
        # oracle; None keeps the unguided walk exactly as before
        self.pricer = pricer
        if surrogate is None and pricer is not None:
            surrogate = RidgeSurrogate()
        self.surrogate = surrogate
        self.guided: Optional[Dict[str, Any]] = None  # per-component stats
        self.on_event = on_event
        if tracer is not None:
            self.tracer = tracer
        elif ledger is not None:
            # one trace for the whole drive: adopt the ledger's tracer so
            # phase spans and oracle.point spans land in the same export
            self.tracer = getattr(ledger, "tracer", NULL_TRACER)
        else:
            self.tracer = NULL_TRACER
        if ledger is not None:
            if cache is not None:
                raise ValueError("pass `cache` to the ledger's constructor "
                                 "when supplying a pre-built ledger — a "
                                 "session-level cache would be silently "
                                 "ignored otherwise")
            self.ledger = ledger
        else:
            self.ledger = OracleLedger(tool, cache=cache,
                                       workers=self.workers,
                                       tracer=self.tracer)
        self._progress_lock = threading.Lock()
        # phase outputs (None = phase not run yet)
        self.characterizations: Optional[Dict[str, CharacterizationResult]] = None
        self.models: Optional[Dict[str, ComponentModel]] = None
        self.planned: Optional[List[PlanPoint]] = None
        self.mapped: Optional[List[SystemPoint]] = None
        self.theta_min: float = 0.0
        self.theta_max: float = 0.0

    # -- plumbing ------------------------------------------------------
    def _emit(self, phase: str, label: str, done: int, total: int) -> None:
        # progress is span-derived: the same tick that reaches on_event
        # lands in the trace as a zero-duration instant, so callbacks
        # (the legacy surface) and trace exports can never disagree
        self.tracer.instant("session.progress", phase=phase, label=label,
                            done=done, total=total)
        if self.on_event is not None:
            self.on_event(ProgressEvent(phase=phase, label=label,
                                        done=done, total=total))

    def _pool_map(self, fn, items: Sequence) -> List:
        """Run ``fn`` over ``items`` preserving order; fan out when the
        session has workers to spare."""
        if self.workers <= 1 or len(items) <= 1:
            return [fn(it) for it in items]
        with ThreadPoolExecutor(max_workers=min(self.workers,
                                                len(items))) as pool:
            return list(pool.map(fn, items))

    def _names(self) -> List[str]:
        return [t.name for t in self.tmg.transitions]

    # -- phase 1: characterization (Algorithm 1) -----------------------
    def characterize(self) -> Dict[str, CharacterizationResult]:
        """Characterize every non-fixed component; all components run
        concurrently (each component's corner walk stays sequential —
        Algorithm 1 is adaptive within a component)."""
        if self.characterizations is not None:
            self._build_models()
            return self.characterizations
        self.ledger.phase = "characterize"
        work = [n for n in self._names() if n not in self.fixed]
        with self.tracer.span("session.characterize",
                              components=len(work)) as phase_sp:
            self._emit("characterize", "", 0, len(work))

            done = [0]

            guided_stats: Dict[str, Any] = {}
            if self.pricer is not None and self.surrogate is not None:
                # phase-start fit from whatever the ledger already paid
                # for (a restored or pre-warmed session): every
                # component then ranks against the SAME surrogate state
                # regardless of fan-out order, so the guided books are
                # identical at any worker count
                self.surrogate.fit(self.ledger.records)

            def one(name: str) -> CharacterizationResult:
                # explicit parent: under a fan-out this runs on a pool
                # thread, where the thread-local stack is empty
                with self.tracer.span("session.component",
                                      parent=phase_sp,
                                      component=name) as sp:
                    if self.pricer is not None:
                        guided = guided_characterize_component(
                            self.ledger, name, self.spaces[name],
                            pricer=self.pricer, surrogate=self.surrogate,
                            refit=False)
                        res = guided.result
                        with self._progress_lock:
                            guided_stats[name] = {
                                "confirmed": guided.confirmed,
                                "fell_back": guided.fell_back,
                                "grid_invocations": guided.grid_invocations,
                            }
                        sp.set("guided", True)
                        sp.set("confirmed", guided.confirmed)
                    else:
                        res = characterize_component(self.ledger, name,
                                                     self.spaces[name])
                    sp.set("regions", len(res.regions))
                    sp.set("invocations", res.invocations)
                with self._progress_lock:
                    done[0] += 1
                    n_done = done[0]
                self._emit("characterize", name, n_done, len(work))
                return res

            results = self._pool_map(one, work)
            self.characterizations = dict(zip(work, results))
            if self.pricer is not None:
                self.guided = {n: guided_stats[n] for n in work}
                if self.surrogate is not None:
                    # phase-end refit from everything actually paid for
                    # (confirmations included) — guides the next session
                    # sharing this surrogate; fit() canonicalizes record
                    # order, so the weights are fan-out independent too
                    self.surrogate.fit(self.ledger.records)
        self._build_models()
        return self.characterizations

    def _build_models(self) -> None:
        assert self.characterizations is not None
        models: Dict[str, ComponentModel] = {}
        for name in self._names():
            if name in self.fixed:
                models[name] = ComponentModel.fixed_latency(name,
                                                            self.fixed[name])
            else:
                models[name] = ComponentModel.from_regions(
                    name, self.characterizations[name].regions)
        self.models = models

    # -- phase 2: synthesis planning (Eq. 2 sweep) ---------------------
    def plan(self) -> List[PlanPoint]:
        if self.planned is not None:
            return self.planned
        if self.models is None:
            self.characterize()
        self.ledger.phase = "plan"
        with self.tracer.span("session.plan", delta=self.delta) as sp:
            self._emit("plan", "", 0, 1)
            self.theta_min, self.theta_max = theta_bounds(self.tmg,
                                                          self.models)
            self.planned = sweep(self.tmg, self.models, self.delta)
            sp.set("points", len(self.planned))
            self._emit("plan", f"{len(self.planned)} points", 1, 1)
        return self.planned

    # -- phase 3: synthesis mapping (phi) ------------------------------
    def map(self) -> List[SystemPoint]:
        if self.mapped is not None:
            return self.mapped
        if self.planned is None:
            self.plan()
        self.ledger.phase = "map"
        planned = self.planned
        with self.tracer.span("session.map",
                              points=len(planned)) as phase_sp:
            self._emit("map", "", 0, len(planned))
            done = [0]

            def one(plan_pt: PlanPoint) -> SystemPoint:
                with self.tracer.span("session.map_point",
                                      parent=phase_sp,
                                      theta=plan_pt.theta) as sp:
                    outcomes: List[MapOutcome] = []
                    lam_actual: Dict[str, float] = {}
                    cost_naive = 0.0
                    for name in self._names():
                        if name in self.fixed:
                            lam_actual[name] = self.fixed[name]
                            continue
                        out = map_target(self.ledger, name,
                                         self.characterizations[name].regions,
                                         plan_pt.lam_targets[name])
                        outcomes.append(out)
                        lam_actual[name] = out.synthesis.lam
                        cost_naive += out.synthesis.area
                    theta_actual = self.tmg.throughput(lam_actual)
                    cost_actual, cost_unshared, groups = cost_naive, None, ()
                    mem = None
                    if self.memory_planner is not None:
                        mem = self._plan_memory(plan_pt, outcomes)
                        cost_actual = mem.system_cost
                        cost_unshared = cost_naive
                        groups = tuple(g.members for g in mem.groups
                                       if len(g.members) > 1)
                    sp.set("theta_actual", theta_actual)
                    sp.set("cost_actual", cost_actual)
                with self._progress_lock:
                    done[0] += 1
                    n_done = done[0]
                self._emit("map", f"theta={plan_pt.theta:.3g}", n_done,
                           len(planned))
                return SystemPoint(theta_planned=plan_pt.theta,
                                   cost_planned=plan_pt.cost,
                                   theta_actual=theta_actual,
                                   cost_actual=cost_actual,
                                   outcomes=tuple(outcomes),
                                   cost_unshared=cost_unshared,
                                   plm_groups=groups,
                                   memory_plan=mem,
                                   schedule=plan_pt.schedule)

            self.mapped = self._pool_map(one, planned)
        return self.mapped

    def _plan_memory(self, plan_pt: PlanPoint,
                     outcomes: Sequence[MapOutcome]):
        """Run the memory planner for one mapped point, handing it the
        plan point's LP schedule when the planner can take one, and —
        under ``verify_plans`` — re-proving the emitted plan sound."""
        import inspect
        synths = {o.component: o.synthesis for o in outcomes}
        planner = self.memory_planner
        params = inspect.signature(planner.plan_point).parameters
        kwargs: Dict[str, Any] = {}
        if "schedule" in params:
            kwargs["schedule"] = plan_pt.schedule
        if "tracer" in params:
            kwargs["tracer"] = self.tracer
        # pre-schedule / pre-tracer custom planners get neither keyword
        mem = planner.plan_point(self.ledger, synths, **kwargs)
        if self.verify_plans:
            from .analysis.verify import assert_plan_sound
            assert_plan_sound(mem, self.tmg, plan_pt.schedule)
        return mem

    # -- results -------------------------------------------------------
    def run(self) -> CosmosResult:
        self.map()           # pulls characterize() and plan() as needed
        self.ledger.flush()
        return self.result()

    def result(self) -> CosmosResult:
        if self.mapped is None:
            raise RuntimeError("session has not completed the map phase")
        # normalize invocation-dict ordering to the TMG transition order
        # (the seed's sequential drive produced exactly this order; under
        # a concurrent drive dict insertion order is racy otherwise)
        inv: Dict[str, int] = {}
        for name in self._names():
            if name in self.ledger.invocations:
                inv[name] = self.ledger.invocations[name]
        for name, n in self.ledger.invocations.items():
            inv.setdefault(name, n)
        return CosmosResult(characterizations=dict(self.characterizations),
                            planned=list(self.planned),
                            mapped=list(self.mapped),
                            invocations=inv,
                            theta_min=self.theta_min,
                            theta_max=self.theta_max)

    # -- mid-run serialization -----------------------------------------
    def state(self) -> Dict[str, Any]:
        """JSON-able snapshot of every completed phase.

        Version 2 also snapshots the mapped points (schedules, memory
        plans with their ``compat_tag``, map outcomes): a session saved
        after ``map()`` restores its full result without a single tool
        invocation.  Version-1 snapshots (no ``mapped``) still load —
        they re-map from the cached invocations as before.
        """
        return {
            "version": 2,
            "delta": self.delta,
            "fixed": dict(self.fixed),
            "characterizations": (
                None if self.characterizations is None else
                {n: _char_to_json(c)
                 for n, c in self.characterizations.items()}),
            "theta": [self.theta_min, self.theta_max],
            "planned": (None if self.planned is None else
                        [_plan_to_json(p) for p in self.planned]),
            "mapped": (None if self.mapped is None else
                       [_system_to_json(m) for m in self.mapped]),
        }

    def load_state(self, state: Dict[str, Any]) -> None:
        if state.get("version") not in (1, 2):
            raise ValueError(f"unknown session state version: "
                             f"{state.get('version')!r}")
        chars = state.get("characterizations")
        if chars is not None:
            self.characterizations = {n: _char_from_json(c)
                                      for n, c in chars.items()}
            self._build_models()
        planned = state.get("planned")
        if planned is not None:
            self.planned = [_plan_from_json(p) for p in planned]
            self.theta_min, self.theta_max = state["theta"]
        mapped = state.get("mapped")          # absent in version-1 snapshots
        if mapped is not None:
            self.mapped = [_system_from_json(m) for m in mapped]

    def save(self, root: str) -> None:
        """Checkpoint the completed phases atomically (store protocol)."""
        import numpy as np
        from ..checkpoint import store
        step = (store.latest_step(root) or 0) + 1
        n_done = sum(x is not None for x in (self.characterizations,
                                             self.planned, self.mapped))
        store.save(root, step, {"phases_done": np.asarray(n_done)},
                   extra={"session": self.state()})

    @classmethod
    def restore(cls, root: str, tmg: TMG, tool,
                spaces: Dict[str, KnobSpace], **kwargs) -> "ExplorationSession":
        """Rebuild a session from :meth:`save` output and continue from
        the first unfinished phase."""
        import numpy as np
        from ..checkpoint import store
        sess = cls(tmg, tool, spaces, **kwargs)
        step = store.latest_step(root)
        if step is not None:
            _, extra = store.restore(root, step,
                                     {"phases_done": np.asarray(0)})
            sess.load_state(extra["session"])
        return sess

    # -- session-as-query ----------------------------------------------
    @classmethod
    def from_query(cls, query: DSEQuery, **kwargs) -> "ExplorationSession":
        """Resolve a :class:`DSEQuery` through the App/Backend registry
        — what the DSE service runs per tenant.  Keywords (``ledger``,
        ``tool``, ``verify_plans``, ...) flow to
        :func:`~repro.core.registry.build_query_session`."""
        from .registry import build_query_session   # lazy: registry imports us
        return build_query_session(query, **kwargs)
