"""SoC composition: many accelerator instances, one chip.

The layer above one accelerator's DSE (ROADMAP: "chip-budget
composition").  :mod:`~repro.core.soc.budget` defines the global
area/power/bandwidth envelopes with Lumos-style tech-node scaling;
:mod:`~repro.core.soc.workload` the per-app traffic mix (resolved
through the registry); :mod:`~repro.core.soc.compose` the replica
x Pareto-point allocators (deterministic greedy + the exhaustive
small-instance packer); :mod:`~repro.core.soc.verify` the independent
re-checker.  See docs/soc.md.
"""

from .budget import (BUDGET_PRESETS, REF_TECH_NM, SoCBudget, TECH_NODES,
                     get_budget)
from .workload import DEFAULT_DEMANDS, AppDemand, TrafficMix

__all__ = [
    "SoCBudget", "BUDGET_PRESETS", "TECH_NODES", "REF_TECH_NM",
    "get_budget",
    "AppDemand", "TrafficMix", "DEFAULT_DEMANDS",
    "OperatingPoint", "Allocation", "Composition",
    "BudgetInfeasibleError", "operating_points", "greedy_composition",
    "optimal_composition", "SoCComposer",
    "CompositionVerificationError", "verify_composition",
    "assert_composition_sound",
]

# compose/verify are also `python -m` entry points: importing them
# eagerly here would double-import under runpy (same rule as
# repro.core.analysis), so their names resolve lazily
_COMPOSE_LAZY = {
    "OperatingPoint", "Allocation", "Composition",
    "BudgetInfeasibleError", "operating_points", "greedy_composition",
    "optimal_composition", "SoCComposer",
}
_VERIFY_LAZY = {
    "CompositionVerificationError", "verify_composition",
    "assert_composition_sound",
}


def __getattr__(name):
    if name in _COMPOSE_LAZY:
        from . import compose
        return getattr(compose, name)
    if name in _VERIFY_LAZY:
        from . import verify
        return getattr(verify, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
