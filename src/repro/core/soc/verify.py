"""Independent re-verification of SoC compositions.

The composer *constructs* a composition; this module *re-proves* it,
trusting nothing but the artifact itself (and, optionally, freshly
resolved fronts).  In the :mod:`repro.core.analysis.verify` style,
every obligation carries a stable rule ID:

* ``C-PROV`` — the artifact carries full provenance: the budget it was
  priced against and the mix it serves (the in-file half of lint rule
  SOC001);
* ``C-REPL`` — every demand in the mix gets exactly one allocation
  with a positive integer replica count, and no allocation serves an
  app outside the mix;
* ``C-PRICE`` — each allocation's per-replica area/power/bandwidth
  re-derives from its front point's native (theta, cost) through the
  demand's exchange rates and the budget's tech tables;
* ``C-AREA`` / ``C-POWER`` / ``C-BW`` — the re-summed totals fit the
  corresponding envelope;
* ``C-THETA`` — the claimed sustained throughput equals the re-derived
  ``min(capacity / share)`` over the normalized mix;
* ``C-FRONT`` — (only when fronts are supplied) every chosen operating
  point is actually on its app's Pareto front.

``python -m repro.core.soc.verify [dir|file ...]`` re-proves committed
``*.composition.json`` artifacts (default: ``artifacts/bench/soc``);
``--fronts`` additionally re-resolves each app's front through the
registry and checks ``C-FRONT`` against the *current* exploration.
Exit status is the number of violated artifacts (0 = everything
proved).
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from ..analysis.verify import Violation
from ..pareto import DesignPoint
from .compose import BUDGET_FIELDS, Composition, price_point

__all__ = ["CompositionVerificationError", "verify_composition",
           "assert_composition_sound", "verify_composition_file", "main"]

_REL_TOL = 1e-9


class CompositionVerificationError(AssertionError):
    """Raised by :func:`assert_composition_sound` — a composition
    failed independent re-verification."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations = tuple(violations)
        super().__init__("composition failed verification:\n  " +
                         "\n  ".join(str(v) for v in violations))


def verify_composition(comp: Composition, *,
                       fronts: Optional[Dict[str, Sequence[DesignPoint]]]
                       = None) -> List[Violation]:
    """Re-prove ``comp``; returns all violations ([] = proved)."""
    out: List[Violation] = []
    b = comp.budget

    # C-PROV: budget + mix provenance must be present and priceable
    if not comp.mix.demands:
        out.append(Violation("C-PROV", (), "mix carries no demands"))
        return out

    shares = comp.mix.shares()
    allocated = {}
    for a in comp.allocations:
        if a.app in allocated:
            out.append(Violation("C-REPL", (a.app,),
                                 "app allocated more than once"))
        allocated[a.app] = a
    for app in sorted(set(shares) - set(allocated)):
        out.append(Violation("C-REPL", (app,),
                             "demand in the mix has no allocation"))
    for app in sorted(set(allocated) - set(shares)):
        out.append(Violation("C-REPL", (app,),
                             "allocation for an app outside the mix"))

    for app in sorted(set(allocated) & set(shares)):
        a = allocated[app]
        if not (isinstance(a.replicas, int) and a.replicas >= 1):
            out.append(Violation("C-REPL", (app,),
                                 f"replica count {a.replicas!r} is not a "
                                 f"positive integer"))
            continue
        if abs(a.share - shares[app]) > _REL_TOL:
            out.append(Violation("C-REPL", (app,),
                                 f"recorded share {a.share!r} != "
                                 f"normalized mix share {shares[app]!r}"))
        # C-PRICE: re-derive the per-replica budget charges
        d = comp.mix.demand(app)
        area, power, bw = price_point(a.point.theta, a.point.cost, d, b)
        for field_, got, want in (("area_mm2", a.point.area_mm2, area),
                                  ("power_w", a.point.power_w, power),
                                  ("bw_gbps", a.point.bw_gbps, bw)):
            if abs(got - want) > _REL_TOL * max(1.0, abs(want)):
                out.append(Violation(
                    "C-PRICE", (app,),
                    f"recorded {field_} {got!r} != re-derived {want!r} "
                    f"(theta={a.point.theta}, cost={a.point.cost})"))
        # C-FRONT: the chosen point must be on the app's front
        if fronts is not None:
            front = fronts.get(app)
            if front is None:
                out.append(Violation("C-FRONT", (app,),
                                     "no front supplied for this app"))
            elif not any(abs(p.perf - a.point.theta)
                         <= _REL_TOL * max(1.0, abs(p.perf))
                         and abs(p.cost - a.point.cost)
                         <= _REL_TOL * max(1.0, abs(p.cost))
                         for p in front):
                out.append(Violation(
                    "C-FRONT", (app,),
                    f"operating point (theta={a.point.theta}, "
                    f"cost={a.point.cost}) is not on the app's "
                    f"{len(front)}-point Pareto front"))

    if any(v.rule == "C-REPL" for v in out):
        return out                     # totals below assume a clean cover

    # C-AREA / C-POWER / C-BW: re-summed totals fit the envelopes
    totals = (sum(a.area_mm2 for a in comp.allocations),
              sum(a.power_w for a in comp.allocations),
              sum(a.bw_gbps for a in comp.allocations))
    limits = (b.area_mm2, b.power_w, b.bw_gbps)
    rules = ("C-AREA", "C-POWER", "C-BW")
    for rule, field_, total, limit in zip(rules, BUDGET_FIELDS, totals,
                                          limits):
        if total > limit * (1 + _REL_TOL):
            out.append(Violation(
                rule, tuple(sorted(allocated)),
                f"re-summed {field_} {total:.6g} exceeds budget "
                f"{b.name!r} envelope {limit:.6g}"))

    # C-THETA: the throughput claim re-derives from the allocations
    t = min(a.capacity / shares[a.app] for a in comp.allocations)
    if abs(comp.sustained_throughput - t) > _REL_TOL * max(1.0, t):
        out.append(Violation(
            "C-THETA", tuple(sorted(allocated)),
            f"claimed sustained throughput {comp.sustained_throughput!r} "
            f"!= re-derived min(capacity/share) {t!r}"))
    return out


def assert_composition_sound(comp: Composition, *,
                             fronts: Optional[Dict[str,
                                                   Sequence[DesignPoint]]]
                             = None) -> None:
    """:func:`verify_composition`, raising on the first unsound
    composition — the bench's strict post-pass."""
    violations = verify_composition(comp, fronts=fronts)
    if violations:
        raise CompositionVerificationError(violations)


# ----------------------------------------------------------------------
# committed-artifact verification (CLI)
# ----------------------------------------------------------------------
def verify_composition_file(path: str, *, with_fronts: bool = False,
                            workers: int = 4
                            ) -> Tuple[int, List[Violation]]:
    """Verify one committed ``*.composition.json`` artifact.

    Returns (number of allocations checked, all violations).  With
    ``with_fronts=True`` each demand's front is re-resolved through the
    registry, so the proof also pins the chosen points to the *current*
    exploration's Pareto front (``C-FRONT``).
    """
    with open(path) as f:
        doc = json.load(f)
    missing = [k for k in ("budget", "mix", "allocations",
                           "sustained_throughput", "method") if k not in doc]
    if missing:
        return 0, [Violation("C-PROV", (),
                             f"artifact is missing provenance keys "
                             f"{missing}")]
    comp = Composition.from_json(doc)
    fronts = None
    if with_fronts:
        from .compose import SoCComposer
        fronts = SoCComposer(comp.budget, comp.mix,
                             workers=workers).fronts()
    return len(comp.allocations), verify_composition(comp, fronts=fronts)


def _find_composition_files(paths) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(os.path.join(p, n) for n in sorted(os.listdir(p))
                       if n.endswith(".composition.json"))
        else:
            out.append(p)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.soc.verify",
        description="independently re-prove committed SoC composition "
                    "artifacts feasible")
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join("artifacts", "bench", "soc")],
                    help="*.composition.json files or directories")
    ap.add_argument("--fronts", action="store_true",
                    help="re-resolve each app's Pareto front through the "
                         "registry and pin the chosen points (C-FRONT)")
    args = ap.parse_args(argv)
    files = _find_composition_files(args.paths)
    if not files:
        print(f"verify: no *.composition.json under {list(args.paths)}",
              file=sys.stderr)
        return 1
    bad = 0
    for path in files:
        n, violations = verify_composition_file(path,
                                                with_fronts=args.fronts)
        if violations:
            bad += 1
            print(f"FAIL {path}: {len(violations)} violation(s) "
                  f"across {n} allocation(s)")
            for v in violations:
                print(f"  {v}")
        else:
            extra = ", front-pinned" if args.fronts else ""
            print(f"ok   {path}: {n} allocation(s) re-priced, "
                  f"budget-feasible, throughput claim re-derived{extra}")
    return bad


if __name__ == "__main__":
    raise SystemExit(main())
