"""SoC composition: replicas x Pareto points under global chip budgets.

The layer above one accelerator's DSE.  Each registered app brings its
system-level Pareto front (from :class:`~repro.core.session.
ExplorationSession` — PLM-shared fronts included); a
:class:`~repro.core.soc.workload.TrafficMix` says what fraction of the
request stream each app must serve; an
:class:`~repro.core.soc.budget.SoCBudget` caps area, power, and DRAM
bandwidth chip-wide.  The :class:`SoCComposer` picks, per app, a
**replica count** and an **operating point** (one front point) to
maximize the *sustained mix throughput*

    T = min over apps of  (replicas_a * theta_a) / share_a

— the CHARM CDSE move (SNIPPETS.md: duplicated large/small accelerators
sized to the workload mix), applied to COSMOS fronts.

Two allocators, mirroring :mod:`repro.core.analysis.packing`:

* :func:`greedy_composition` — the production path: start every app at
  its cheapest point with one replica (or raise
  :class:`BudgetInfeasibleError` *naming the violated budget*), then
  repeatedly give the bottleneck app the feasible move with the best
  marginal utility (delta-capacity per delta-area), with full
  deterministic tie-breaking;
* :func:`optimal_composition` — the exhaustive packer: enumerate every
  (point, replicas) assignment on small instances (guarded by
  ``max_apps`` / ``max_configs``, exponential past them) — the oracle
  the tests and the bench gate the greedy against.

Every composition is wrapped in ``soc.compose`` spans and counters
through :mod:`repro.core.obs`, carries its budget + mix provenance
(lint rule SOC001), and is independently re-proved by
:mod:`repro.core.soc.verify`.  CLI::

    python -m repro.core.soc.compose --mix wami=0.6,fleet=0.4 \\
        --budget sys_medium --tech 45 --out composition.json --verify
"""

from __future__ import annotations

import json
import math
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..obs import NULL_TRACER, MetricsRegistry
from ..pareto import DesignPoint
from .budget import SoCBudget, get_budget
from .workload import TrafficMix

__all__ = ["OperatingPoint", "Allocation", "Composition",
           "BudgetInfeasibleError", "operating_points",
           "greedy_composition", "optimal_composition", "SoCComposer",
           "main"]

#: deterministic order the three envelopes are checked in — the *first*
#: violated one names a :class:`BudgetInfeasibleError`
BUDGET_FIELDS = ("area_mm2", "power_w", "bw_gbps")

_REL_TOL = 1e-12
_MAX_APPS = 3                 # exhaustive guard, like packing.py
_MAX_CONFIGS = 200_000
_MAX_MOVES = 100_000          # greedy safety valve (never hit in practice)


class BudgetInfeasibleError(ValueError):
    """The mix cannot be served at all: even the minimal configuration
    (every app at its cheapest point, one replica) violates a budget.
    ``budget_field`` names the violated envelope."""

    def __init__(self, mix_name: str, budget: SoCBudget, budget_field: str,
                 need: float, limit: float):
        self.mix_name = mix_name
        self.budget_name = budget.name
        self.budget_field = budget_field
        self.need = need
        self.limit = limit
        super().__init__(
            f"traffic mix {mix_name!r} is infeasible under budget "
            f"{budget.name!r}: the minimal configuration (cheapest point, "
            f"one replica per app) needs {budget_field}={need:.6g} > "
            f"budget {limit:.6g}")


@dataclass(frozen=True)
class OperatingPoint:
    """One front point, priced against a budget's tech node.

    ``index`` is the point's position on the app's ascending-theta
    front; ``theta``/``cost`` are the front's native numbers; the three
    per-replica budget charges are derived through the demand's
    ``area_scale``/``bytes_per_request`` and the budget's tech tables.
    """

    index: int
    theta: float                  # requests/s one replica sustains
    cost: float                   # app-native front cost
    area_mm2: float               # at the budget's tech node
    power_w: float
    bw_gbps: float
    knobs: Tuple[Tuple[str, int], ...] = ()

    def to_json(self) -> Dict[str, Any]:
        return {"index": self.index, "theta": self.theta,
                "cost": self.cost, "area_mm2": self.area_mm2,
                "power_w": self.power_w, "bw_gbps": self.bw_gbps,
                "knobs": [list(k) for k in self.knobs]}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "OperatingPoint":
        return cls(index=doc["index"], theta=doc["theta"],
                   cost=doc["cost"], area_mm2=doc["area_mm2"],
                   power_w=doc["power_w"], bw_gbps=doc["bw_gbps"],
                   knobs=tuple((str(k), int(v))
                               for k, v in doc.get("knobs", [])))


def price_point(theta: float, cost: float, demand,
                budget: SoCBudget) -> Tuple[float, float, float]:
    """One replica's (area_mm2, power_w, bw_gbps) budget charge."""
    area_ref = cost * demand.area_scale
    return (budget.scale_area(area_ref), budget.power_of(area_ref),
            theta * demand.bytes_per_request / 1e9)


def operating_points(front: Sequence[DesignPoint], demand,
                     budget: SoCBudget) -> List[OperatingPoint]:
    """Price an app's front against a budget.  Points with non-positive
    throughput or area are unusable as replicas and are dropped."""
    out: List[OperatingPoint] = []
    for i, p in enumerate(front):
        area, power, bw = price_point(p.perf, p.cost, demand, budget)
        if p.perf <= 0 or area <= 0:
            continue
        out.append(OperatingPoint(index=i, theta=p.perf, cost=p.cost,
                                  area_mm2=area, power_w=power,
                                  bw_gbps=bw, knobs=tuple(p.knobs)))
    if not out:
        raise ValueError(f"app {demand.app!r}: no usable operating point "
                         f"on a front of {len(front)} point(s)")
    return out


@dataclass(frozen=True)
class Allocation:
    """One app's slice of the chip: ``replicas`` copies at ``point``."""

    app: str
    share: float                  # normalized share of the request mix
    replicas: int
    point: OperatingPoint

    @property
    def capacity(self) -> float:
        """Requests/s this allocation sustains (replicas x theta)."""
        return self.replicas * self.point.theta

    @property
    def area_mm2(self) -> float:
        return self.replicas * self.point.area_mm2

    @property
    def power_w(self) -> float:
        return self.replicas * self.point.power_w

    @property
    def bw_gbps(self) -> float:
        return self.replicas * self.point.bw_gbps

    def to_json(self) -> Dict[str, Any]:
        return {"app": self.app, "share": self.share,
                "replicas": self.replicas, "capacity": self.capacity,
                "area_mm2": self.area_mm2, "power_w": self.power_w,
                "bw_gbps": self.bw_gbps, "point": self.point.to_json()}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "Allocation":
        return cls(app=doc["app"], share=doc["share"],
                   replicas=doc["replicas"],
                   point=OperatingPoint.from_json(doc["point"]))


@dataclass(frozen=True)
class Composition:
    """One solved chip: allocations + totals + full provenance.

    ``to_json`` embeds the budget and the mix — the SOC001 lint rule
    and :mod:`repro.core.soc.verify` both insist a committed artifact
    carries enough provenance to be independently re-priced.
    """

    budget: SoCBudget
    mix: TrafficMix
    allocations: Tuple[Allocation, ...]
    method: str                   # "greedy" | "exhaustive"
    sustained_throughput: float   # T, requests/s on the mix

    @property
    def area_mm2(self) -> float:
        return sum(a.area_mm2 for a in self.allocations)

    @property
    def power_w(self) -> float:
        return sum(a.power_w for a in self.allocations)

    @property
    def bw_gbps(self) -> float:
        return sum(a.bw_gbps for a in self.allocations)

    @property
    def throughput_per_area(self) -> float:
        """Sustained requests/s per mm^2 — the trajectory headline
        ``artifacts/bench/BENCH_soc.json`` records."""
        return self.sustained_throughput / self.area_mm2

    def to_json(self) -> Dict[str, Any]:
        return {"version": 1,
                "budget": self.budget.to_json(),
                "mix": self.mix.to_json(),
                "method": self.method,
                "sustained_throughput": self.sustained_throughput,
                "throughput_per_area": self.throughput_per_area,
                "totals": {"area_mm2": self.area_mm2,
                           "power_w": self.power_w,
                           "bw_gbps": self.bw_gbps},
                "allocations": [a.to_json() for a in self.allocations]}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "Composition":
        return cls(budget=SoCBudget.from_json(doc["budget"]),
                   mix=TrafficMix.from_json(doc["mix"]),
                   allocations=tuple(Allocation.from_json(a)
                                     for a in doc["allocations"]),
                   method=doc["method"],
                   sustained_throughput=doc["sustained_throughput"])


# ----------------------------------------------------------------------
# shared machinery
# ----------------------------------------------------------------------
def _priced(budget: SoCBudget, mix: TrafficMix,
            fronts: Dict[str, Sequence[DesignPoint]]
            ) -> Dict[str, List[OperatingPoint]]:
    missing = sorted(d.app for d in mix.demands if d.app not in fronts)
    if missing:
        raise KeyError(f"mix {mix.name!r}: no front supplied for "
                       f"{missing}; fronts cover {sorted(fronts)}")
    return {d.app: operating_points(fronts[d.app], d, budget)
            for d in mix.demands}


def _totals(state: Dict[str, Tuple[int, int]],
            pts: Dict[str, List[OperatingPoint]]
            ) -> Tuple[float, float, float]:
    area = power = bw = 0.0
    for app, (idx, reps) in state.items():
        p = pts[app][idx]
        area += reps * p.area_mm2
        power += reps * p.power_w
        bw += reps * p.bw_gbps
    return area, power, bw


def _fits(budget: SoCBudget, totals: Tuple[float, float, float]) -> bool:
    limits = (budget.area_mm2, budget.power_w, budget.bw_gbps)
    return all(t <= lim * (1 + _REL_TOL)
               for t, lim in zip(totals, limits))


def _min_state(pts: Dict[str, List[OperatingPoint]]
               ) -> Dict[str, Tuple[int, int]]:
    """Every app at its cheapest-area point, one replica — the minimal
    configuration the infeasibility check (and greedy) starts from."""
    state: Dict[str, Tuple[int, int]] = {}
    for app in sorted(pts):
        best = min(range(len(pts[app])),
                   key=lambda i: (pts[app][i].area_mm2, i))
        state[app] = (best, 1)
    return state


def _check_feasible_start(budget: SoCBudget, mix: TrafficMix,
                          pts: Dict[str, List[OperatingPoint]]
                          ) -> Dict[str, Tuple[int, int]]:
    state = _min_state(pts)
    totals = _totals(state, pts)
    limits = (budget.area_mm2, budget.power_w, budget.bw_gbps)
    for field_, need, limit in zip(BUDGET_FIELDS, totals, limits):
        if need > limit * (1 + _REL_TOL):
            raise BudgetInfeasibleError(mix.name, budget, field_,
                                        need, limit)
    return state


def _sustained(state: Dict[str, Tuple[int, int]],
               pts: Dict[str, List[OperatingPoint]],
               shares: Dict[str, float]) -> float:
    return min(reps * pts[app][idx].theta / shares[app]
               for app, (idx, reps) in state.items())


def _finish(budget: SoCBudget, mix: TrafficMix,
            pts: Dict[str, List[OperatingPoint]],
            state: Dict[str, Tuple[int, int]], method: str
            ) -> Composition:
    shares = mix.shares()
    allocations = tuple(
        Allocation(app=app, share=shares[app], replicas=state[app][1],
                   point=pts[app][state[app][0]])
        for app in sorted(state))
    return Composition(budget=budget, mix=mix, allocations=allocations,
                       method=method,
                       sustained_throughput=_sustained(state, pts, shares))


# ----------------------------------------------------------------------
# the greedy / marginal-utility allocator
# ----------------------------------------------------------------------
def greedy_composition(budget: SoCBudget, mix: TrafficMix,
                       fronts: Dict[str, Sequence[DesignPoint]], *,
                       tracer=None, metrics: Optional[MetricsRegistry] = None
                       ) -> Composition:
    """Deterministic marginal-utility allocation.

    Start from the minimal configuration (raising
    :class:`BudgetInfeasibleError` if even that violates a budget),
    then loop: find the bottleneck app (lowest capacity/share, ties by
    name) and apply its best feasible capacity-increasing move — switch
    operating point and/or add a replica — ranked by marginal utility
    (delta-capacity / delta-area), ties by smaller delta-area, smaller
    delta-power, then (point index, replicas).  Between moves, any app
    that can *repack* (same-or-higher capacity, strictly less area, no
    more replicas) does, freeing budget for the bottleneck.  Both step
    kinds strictly increase (total capacity, -total area), so the walk
    terminates; the final state is the sustained-throughput local
    optimum the exhaustive packer gates in tests.
    """
    tracer = tracer if tracer is not None else NULL_TRACER
    metrics = metrics if metrics is not None else MetricsRegistry()
    moves_c = metrics.counter("soc.moves")
    pts = _priced(budget, mix, fronts)
    shares = mix.shares()
    with tracer.span("soc.allocate", mix=mix.name, budget=budget.name,
                     method="greedy") as sp:
        state = _check_feasible_start(budget, mix, pts)
        moves = 0
        while moves < _MAX_MOVES:
            if _repack(budget, pts, state, shares):
                moves += 1
                moves_c.inc()
                continue
            bottleneck = min(
                state, key=lambda a: (state[a][1] * pts[a][state[a][0]].theta
                                      / shares[a], a))
            move = _best_move(budget, pts, state, bottleneck)
            if move is None:
                break
            tracer.instant("soc.move", app=bottleneck,
                           point=move[0], replicas=move[1])
            state[bottleneck] = move
            moves += 1
            moves_c.inc()
        sp.set("moves", moves)
        sp.set("sustained_throughput", _sustained(state, pts, shares))
    return _finish(budget, mix, pts, state, "greedy")


def _candidates(reps: int, n_points: int):
    for idx2 in range(n_points):
        for reps2 in sorted({1, reps, reps + 1}):
            yield idx2, reps2


def _best_move(budget: SoCBudget, pts: Dict[str, List[OperatingPoint]],
               state: Dict[str, Tuple[int, int]], app: str
               ) -> Optional[Tuple[int, int]]:
    """The bottleneck's best feasible capacity-increasing move, or
    None.  Candidates: every point at 1, current, or current+1
    replicas (covering add-a-replica, switch-point, and
    collapse-to-one-bigger)."""
    idx, reps = state[app]
    cur = pts[app][idx]
    cap = reps * cur.theta
    area0, power0, bw0 = _totals(state, pts)
    best_key = None
    best = None
    for idx2, reps2 in _candidates(reps, len(pts[app])):
        if (idx2, reps2) == (idx, reps):
            continue
        p2 = pts[app][idx2]
        cap2 = reps2 * p2.theta
        if cap2 <= cap * (1 + _REL_TOL):
            continue
        d_area = reps2 * p2.area_mm2 - reps * cur.area_mm2
        d_power = reps2 * p2.power_w - reps * cur.power_w
        d_bw = reps2 * p2.bw_gbps - reps * cur.bw_gbps
        if not _fits(budget, (area0 + d_area, power0 + d_power,
                              bw0 + d_bw)):
            continue
        utility = (cap2 - cap) / max(d_area, 1e-9)
        key = (-utility, d_area, d_power, idx2, reps2)
        if best_key is None or key < best_key:
            best_key, best = key, (idx2, reps2)
    return best


def _repack(budget: SoCBudget, pts: Dict[str, List[OperatingPoint]],
            state: Dict[str, Tuple[int, int]],
            shares: Dict[str, float]) -> bool:
    """Apply the first available area-freeing repack: a config with
    same-or-higher capacity, strictly less area, and no more replicas.
    Returns True if a repack was applied."""
    for app in sorted(state):
        idx, reps = state[app]
        cur = pts[app][idx]
        cap = reps * cur.theta
        area = reps * cur.area_mm2
        best_key = None
        best = None
        for idx2, reps2 in _candidates(reps, len(pts[app])):
            if (idx2, reps2) == (idx, reps) or reps2 > reps:
                continue
            p2 = pts[app][idx2]
            if reps2 * p2.theta < cap * (1 - _REL_TOL):
                continue
            area2 = reps2 * p2.area_mm2
            if area2 >= area * (1 - _REL_TOL):
                continue
            key = (area2, reps2 * p2.power_w, idx2, reps2)
            if best_key is None or key < best_key:
                best_key, best = key, (idx2, reps2)
        if best is not None:
            state[app] = best
            return True
    return False


# ----------------------------------------------------------------------
# the exhaustive packer (small instances — the gate oracle)
# ----------------------------------------------------------------------
def optimal_composition(budget: SoCBudget, mix: TrafficMix,
                        fronts: Dict[str, Sequence[DesignPoint]], *,
                        max_apps: int = _MAX_APPS,
                        max_configs: int = _MAX_CONFIGS) -> Composition:
    """The certified optimum by full enumeration.

    Every per-app (point, replicas) config within the individual
    budget caps, crossed over apps; exponential, so guarded by
    ``max_apps`` and ``max_configs`` (:class:`ValueError` past either —
    mirroring :func:`repro.core.analysis.packing.optimal_plan`).
    Deterministic ties: max sustained throughput, then min area, then
    min power, then lexicographic (point index, replicas) per sorted
    app.  The oracle for the greedy gate in tests/test_soc.py and the
    ``soc_compose`` bench.
    """
    import itertools
    if len(mix.demands) > max_apps:
        raise ValueError(f"exhaustive composition is exponential: "
                         f"{len(mix.demands)} apps > max_apps={max_apps}")
    pts = _priced(budget, mix, fronts)
    shares = mix.shares()
    _check_feasible_start(budget, mix, pts)

    apps = sorted(pts)
    per_app: List[List[Tuple[int, int]]] = []
    total = 1
    for app in apps:
        configs: List[Tuple[int, int]] = []
        for i, p in enumerate(pts[app]):
            caps = [budget.area_mm2 / p.area_mm2,
                    budget.power_w / p.power_w if p.power_w > 0
                    else math.inf,
                    budget.bw_gbps / p.bw_gbps if p.bw_gbps > 0
                    else math.inf]
            rmax = int(min(caps) * (1 + _REL_TOL))
            configs.extend((i, r) for r in range(1, rmax + 1))
        per_app.append(configs)
        total *= max(1, len(configs))
    if total > max_configs:
        raise ValueError(f"exhaustive composition too large: {total} "
                         f"configs > max_configs={max_configs}")

    best_key = None
    best_state = None
    for combo in itertools.product(*per_app):
        state = dict(zip(apps, combo))
        if not _fits(budget, _totals(state, pts)):
            continue
        t = _sustained(state, pts, shares)
        area, power, _ = _totals(state, pts)
        key = (-t, area, power, combo)
        if best_key is None or key < best_key:
            best_key, best_state = key, state
    assert best_state is not None     # min config is feasible by check
    return _finish(budget, mix, pts, best_state, "exhaustive")


# ----------------------------------------------------------------------
# the composer: registry-resolved fronts + obs wiring
# ----------------------------------------------------------------------
class SoCComposer:
    """Front resolution + allocation, end to end.

    Resolves each demand's Pareto front through the registry
    (``build_session(app, backend, share_plm=..., delta=...)``) unless
    pre-computed ``fronts`` are injected; prices, allocates, and
    returns a :class:`Composition`.  All work is traced (``soc.compose``
    > ``soc.front`` / ``soc.allocate`` spans) and counted
    (``soc.compositions``, ``soc.moves``, the
    ``soc.sustained_throughput`` gauge) through :mod:`repro.core.obs`.
    """

    def __init__(self, budget: SoCBudget, mix: TrafficMix, *,
                 fronts: Optional[Dict[str, Sequence[DesignPoint]]] = None,
                 workers: int = 4, tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.budget = budget
        self.mix = mix
        self.workers = workers
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._fronts: Optional[Dict[str, List[DesignPoint]]] = (
            {k: list(v) for k, v in fronts.items()}
            if fronts is not None else None)

    def fronts(self) -> Dict[str, List[DesignPoint]]:
        """Each demand's system-level Pareto front, memoized.  One
        exploration session per app, in demand order."""
        if self._fronts is None:
            from ..registry import build_session
            out: Dict[str, List[DesignPoint]] = {}
            for d in self.mix.demands:
                with self.tracer.span("soc.front", app=d.app,
                                      backend=d.backend,
                                      share_plm=d.share_plm) as sp:
                    session = build_session(
                        d.app, d.backend, share_plm=d.share_plm,
                        delta=d.delta, workers=self.workers)
                    out[d.app] = session.run().pareto()
                    sp.set("points", len(out[d.app]))
            self._fronts = out
        return self._fronts

    def compose(self, method: str = "greedy") -> Composition:
        """Solve the chip.  ``method``: ``"greedy"`` (production) or
        ``"exhaustive"`` (the small-instance packer)."""
        if method not in ("greedy", "exhaustive"):
            raise ValueError(f"unknown method {method!r}; "
                             f"methods: ['exhaustive', 'greedy']")
        with self.tracer.span("soc.compose", mix=self.mix.name,
                              budget=self.budget.name,
                              tech_nm=self.budget.tech_nm,
                              method=method) as sp:
            fronts = self.fronts()
            fn = (greedy_composition if method == "greedy"
                  else optimal_composition)
            comp = fn(self.budget, self.mix, fronts,
                      **({"tracer": self.tracer, "metrics": self.metrics}
                         if method == "greedy" else {}))
            self.metrics.counter("soc.compositions").inc()
            self.metrics.gauge("soc.sustained_throughput").set(
                comp.sustained_throughput)
            sp.set("sustained_throughput", comp.sustained_throughput)
            sp.set("area_mm2", comp.area_mm2)
        return comp


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------
def _render(comp: Composition) -> str:
    b = comp.budget
    lines = [f"composition: mix={comp.mix.name} budget={b.name} "
             f"tech={b.tech_nm}nm method={comp.method}",
             "app,share,point,replicas,theta_per_replica,capacity,"
             "area_mm2,power_w,bw_gbps"]
    for a in comp.allocations:
        lines.append(f"{a.app},{a.share:.4f},{a.point.index},"
                     f"{a.replicas},{a.point.theta:.6g},"
                     f"{a.capacity:.6g},{a.area_mm2:.6g},"
                     f"{a.power_w:.6g},{a.bw_gbps:.6g}")
    lines.append(f"sustained_throughput={comp.sustained_throughput:.6g} "
                 f"req/s on the mix")
    lines.append(f"totals: area {comp.area_mm2:.6g}/{b.area_mm2:g} mm2, "
                 f"power {comp.power_w:.6g}/{b.power_w:g} W, "
                 f"bw {comp.bw_gbps:.6g}/{b.bw_gbps:g} GB/s")
    lines.append(f"throughput_per_area={comp.throughput_per_area:.6g} "
                 f"req/s/mm2")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.soc.compose",
        description="compose registered apps onto one SoC under global "
                    "area/power/bandwidth budgets")
    ap.add_argument("--mix", default="wami=0.6,fleet=0.4",
                    metavar="APP=SHARE,...",
                    help="the traffic mix (default wami=0.6,fleet=0.4)")
    ap.add_argument("--budget", default="sys_medium",
                    help="budget preset (sys_small/sys_medium/sys_large)")
    ap.add_argument("--area", type=float, default=None,
                    help="custom area envelope, mm^2 (overrides preset)")
    ap.add_argument("--power", type=float, default=None,
                    help="custom power envelope, W")
    ap.add_argument("--bw", type=float, default=None,
                    help="custom bandwidth envelope, GB/s")
    ap.add_argument("--tech", type=int, default=None, metavar="NM",
                    help="re-anchor the budget at this tech node "
                         "(45/32/22/16)")
    ap.add_argument("--method", choices=["greedy", "exhaustive"],
                    default="greedy")
    ap.add_argument("--workers", type=int, default=4,
                    help="session fan-out while resolving fronts")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the composition JSON artifact here")
    ap.add_argument("--verify", action="store_true",
                    help="independently re-prove the composition "
                         "(repro.core.soc.verify) before reporting")
    args = ap.parse_args(argv)

    try:
        budget = get_budget(args.budget)
        if args.area or args.power or args.bw:
            from dataclasses import replace
            budget = replace(
                budget, name=f"{args.budget}-custom",
                area_mm2=args.area or budget.area_mm2,
                power_w=args.power or budget.power_w,
                bw_gbps=args.bw or budget.bw_gbps)
        if args.tech is not None:
            budget = budget.at_tech(args.tech)
        mix = TrafficMix.parse(args.mix)
        mix.resolve()                 # registry listing errors on typos
        composer = SoCComposer(budget, mix, workers=args.workers)
        comp = composer.compose(args.method)
        if args.verify:
            from .verify import assert_composition_sound
            assert_composition_sound(comp, fronts=composer.fronts())
    except (BudgetInfeasibleError, KeyError, ValueError,
            AssertionError) as e:
        print(f"soc-compose: FAIL — {e}", file=sys.stderr)
        return 1
    print(_render(comp))
    if args.verify:
        print("verify: composition independently re-proved feasible")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(comp.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
