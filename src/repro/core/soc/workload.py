"""Traffic mixes: what the chip is asked to serve, per registered app.

A :class:`TrafficMix` is the demand side of SoC composition: a named
set of :class:`AppDemand` entries, one per registered app, each saying
*how much* of the request stream is that app (``share``) and how to
price one served request against the chip budgets:

* ``bytes_per_request`` — DRAM traffic per request, so a replica
  running at ``theta`` requests/s charges ``theta * bytes_per_request``
  against the bandwidth envelope;
* ``area_scale`` — the exchange rate from the app's *native* Pareto
  cost unit to reference-node mm^2.  COSMOS fronts are app-native on
  purpose (WAMI prices in mm^2, the fleet pipeline in HBM bytes — see
  docs/memory.md on unit systems); the mix is where a chip-level
  comparison fixes the rate, and provenance keeps it auditable;
* ``backend`` / ``share_plm`` / ``delta`` — which exploration produces
  the front the composer consumes (PLM-shared fronts included).

Apps resolve through :mod:`repro.core.registry` — any registered app
participates, and typos raise the registry's listing errors.
``TrafficMix.parse("wami=0.6,fleet=0.4")`` is the CLI/bench surface;
:data:`DEFAULT_DEMANDS` carries the per-app pricing defaults the parser
applies so one string names a fully priced mix.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["AppDemand", "TrafficMix", "DEFAULT_DEMANDS"]


@dataclass(frozen=True)
class AppDemand:
    """One app's slice of the mix, plus its budget pricing knobs."""

    app: str
    share: float
    bytes_per_request: float = 0.0
    area_scale: float = 1.0          # ref-node mm^2 per native cost unit
    backend: str = "analytical"
    share_plm: bool = False
    delta: Optional[float] = None

    def __post_init__(self):
        if not (isinstance(self.share, (int, float)) and self.share > 0):
            raise ValueError(f"demand {self.app!r}: share must be positive, "
                             f"got {self.share!r}")
        if self.area_scale <= 0:
            raise ValueError(f"demand {self.app!r}: area_scale must be "
                             f"positive, got {self.area_scale!r}")
        if self.bytes_per_request < 0:
            raise ValueError(f"demand {self.app!r}: bytes_per_request must "
                             f"be >= 0, got {self.bytes_per_request!r}")

    def to_json(self) -> Dict[str, Any]:
        return {"app": self.app, "share": self.share,
                "bytes_per_request": self.bytes_per_request,
                "area_scale": self.area_scale, "backend": self.backend,
                "share_plm": self.share_plm, "delta": self.delta}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "AppDemand":
        return cls(app=doc["app"], share=doc["share"],
                   bytes_per_request=doc.get("bytes_per_request", 0.0),
                   area_scale=doc.get("area_scale", 1.0),
                   backend=doc.get("backend", "analytical"),
                   share_plm=doc.get("share_plm", False),
                   delta=doc.get("delta"))


#: per-app pricing defaults :meth:`TrafficMix.parse` applies — the one
#: place the bench, the CLI, and the tests agree on what a request of
#: each built-in app costs the chip.  WAMI serves 2048x2048 u16 frames
#: (~8.4 MB DRAM traffic each) from its mm^2-priced, PLM-shared front;
#: the fleet pipeline's front prices in HBM bytes, exchanged at
#: 2 mm^2 per TB of pinned HBM footprint.
DEFAULT_DEMANDS: Dict[str, Dict[str, Any]] = {
    "wami": {"bytes_per_request": 2 * 2048 * 2048 * 2.0,
             "area_scale": 1.0, "share_plm": True},
    "fleet": {"bytes_per_request": 1.0e9, "area_scale": 2.0e-12},
}


@dataclass(frozen=True)
class TrafficMix:
    """A named, normalizable set of per-app demands (apps unique)."""

    name: str
    demands: Tuple[AppDemand, ...]

    def __post_init__(self):
        if not isinstance(self.demands, tuple):
            object.__setattr__(self, "demands", tuple(self.demands))
        if not self.demands:
            raise ValueError(f"mix {self.name!r}: no demands")
        apps = [d.app for d in self.demands]
        if len(set(apps)) != len(apps):
            raise ValueError(f"mix {self.name!r}: duplicate apps {apps}")

    # -- reading -------------------------------------------------------
    def demand(self, app: str) -> AppDemand:
        for d in self.demands:
            if d.app == app:
                return d
        raise KeyError(f"mix {self.name!r} has no demand for app {app!r}; "
                       f"apps in mix: {sorted(d.app for d in self.demands)}")

    def shares(self) -> Dict[str, float]:
        """Per-app share of the request stream, normalized to sum 1."""
        total = sum(d.share for d in self.demands)
        return {d.app: d.share / total for d in self.demands}

    def resolve(self) -> List[Any]:
        """The registered :class:`~repro.core.registry.App` records, in
        demand order — unknown apps raise the registry's listing
        KeyError (the same error a bad ``--mix`` gets on the CLI)."""
        from ..registry import get_app
        return [get_app(d.app) for d in self.demands]

    # -- construction --------------------------------------------------
    @classmethod
    def parse(cls, spec: str, name: Optional[str] = None,
              **overrides: Dict[str, Any]) -> "TrafficMix":
        """``"wami=0.6,fleet=0.4"`` -> a fully priced mix.

        Each app picks up its :data:`DEFAULT_DEMANDS` pricing;
        ``overrides`` maps app -> field dict for per-call tweaks
        (``TrafficMix.parse(spec, wami={"share_plm": False})``).
        """
        demands: List[AppDemand] = []
        for part in (p for p in spec.split(",") if p.strip()):
            if "=" not in part:
                raise ValueError(f"bad mix entry {part!r} in {spec!r} "
                                 f"(want app=share,app=share,...)")
            app, share_s = part.split("=", 1)
            app = app.strip()
            fields: Dict[str, Any] = dict(DEFAULT_DEMANDS.get(app, {}))
            fields.update(overrides.get(app, {}))
            demands.append(AppDemand(app=app, share=float(share_s),
                                     **fields))
        if not demands:
            raise ValueError(f"empty mix spec {spec!r}")
        if name is None:
            name = "_".join(f"{d.app}{round(d.share * 100):g}"
                            for d in demands)
        return cls(name=name, demands=tuple(demands))

    def normalized(self) -> "TrafficMix":
        shares = self.shares()
        return replace(self, demands=tuple(
            replace(d, share=shares[d.app]) for d in self.demands))

    # -- provenance ----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name,
                "demands": [d.to_json() for d in self.demands]}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "TrafficMix":
        return cls(name=doc["name"],
                   demands=tuple(AppDemand.from_json(d)
                                 for d in doc["demands"]))
