"""SoC budgets: the global area / power / bandwidth envelopes.

COSMOS sizes *one* accelerator; composing a chip's worth of them needs
the budgets the chip itself imposes.  :class:`SoCBudget` carries the
three envelopes every composition is priced against — logic area
(mm^2), power (W), and DRAM bandwidth (GB/s) — plus a technology-node
scaling hook in the Lumos MPSoC style (SNIPPETS.md: ``budget.area`` /
``budget.power`` / ``budget.bw[tech]``): accelerators are characterized
once at the 45 nm reference node, and :meth:`SoCBudget.scale_area` /
:meth:`SoCBudget.power_of` re-price a reference-node area at the
budget's node through per-node scaling tables.  Area shrinks faster
than per-op power falls, so power density rises with every shrink —
the dark-silicon pressure the composer trades replicas against.

Three Lumos-flavored presets (``sys_small`` / ``sys_medium`` /
``sys_large``) cover the bench and CLI defaults; custom envelopes are
one dataclass call.  Everything here is pure data + arithmetic —
deterministic, JSON-round-trippable, no registry access.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict

__all__ = ["TECH_NODES", "REF_TECH_NM", "SoCBudget", "BUDGET_PRESETS",
           "get_budget"]

#: the technology nodes the scaling tables know, newest last
TECH_NODES = (45, 32, 22, 16)

#: the node accelerator fronts are characterized at (all area scales in
#: :mod:`repro.core.soc.workload` are mm^2 at this node)
REF_TECH_NM = 45

# per-node scaling relative to the 45 nm reference: logic shrinks
# ~0.5x per node, per-op power falls slower (~0.66x), and the DRAM
# interface speeds up — so power density *rises* with every shrink
_AREA_SCALE = {45: 1.0, 32: 0.505, 22: 0.255, 16: 0.129}
_POWER_SCALE = {45: 1.0, 32: 0.66, 22: 0.44, 16: 0.29}
_BW_SCALE = {45: 1.0, 32: 1.33, 22: 1.78, 16: 2.37}


def _check_tech(tech_nm: int) -> int:
    if tech_nm not in _AREA_SCALE:
        raise KeyError(f"unknown tech node {tech_nm!r} nm; known nodes: "
                       f"{list(TECH_NODES)}")
    return tech_nm


@dataclass(frozen=True)
class SoCBudget:
    """One chip's global envelopes, at one technology node.

    ``power_density_w_per_mm2`` is the accelerator logic's power
    density at the *reference* node; :meth:`power_of` applies the
    per-node per-op scaling on top of it.
    """

    name: str
    area_mm2: float
    power_w: float
    bw_gbps: float
    tech_nm: int = REF_TECH_NM
    power_density_w_per_mm2: float = 0.5

    def __post_init__(self):
        _check_tech(self.tech_nm)
        for field_ in ("area_mm2", "power_w", "bw_gbps",
                       "power_density_w_per_mm2"):
            v = getattr(self, field_)
            if not (isinstance(v, (int, float)) and v > 0):
                raise ValueError(f"budget {self.name!r}: {field_} must be "
                                 f"a positive number, got {v!r}")

    # -- the tech-node scaling hook ------------------------------------
    def at_tech(self, tech_nm: int) -> "SoCBudget":
        """This budget re-anchored at another node: the logic envelopes
        (area, power) stay the chip's — they are package/cooling
        limits — while the bandwidth envelope follows the node's DRAM
        interface scaling (Lumos's ``budget.bw[tech]`` table)."""
        _check_tech(tech_nm)
        bw = self.bw_gbps * _BW_SCALE[tech_nm] / _BW_SCALE[self.tech_nm]
        return replace(self, tech_nm=tech_nm, bw_gbps=bw)

    def scale_area(self, area_mm2_ref: float) -> float:
        """Reference-node (45 nm) logic area -> area at this node."""
        return area_mm2_ref * _AREA_SCALE[self.tech_nm]

    def power_of(self, area_mm2_ref: float) -> float:
        """Reference-node logic area -> watts at this node (density x
        per-op scaling; divided by area scaling this is the rising
        power-density curve)."""
        return (area_mm2_ref * self.power_density_w_per_mm2
                * _POWER_SCALE[self.tech_nm])

    # -- provenance ----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "area_mm2": self.area_mm2,
                "power_w": self.power_w, "bw_gbps": self.bw_gbps,
                "tech_nm": self.tech_nm,
                "power_density_w_per_mm2": self.power_density_w_per_mm2}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "SoCBudget":
        return cls(name=doc["name"], area_mm2=doc["area_mm2"],
                   power_w=doc["power_w"], bw_gbps=doc["bw_gbps"],
                   tech_nm=doc.get("tech_nm", REF_TECH_NM),
                   power_density_w_per_mm2=doc.get(
                       "power_density_w_per_mm2", 0.5))


#: the Lumos-flavored platform presets (all at the 45 nm reference)
BUDGET_PRESETS: Dict[str, SoCBudget] = {
    "sys_small": SoCBudget("sys_small", area_mm2=100.0, power_w=40.0,
                           bw_gbps=128.0),
    "sys_medium": SoCBudget("sys_medium", area_mm2=200.0, power_w=80.0,
                            bw_gbps=256.0),
    "sys_large": SoCBudget("sys_large", area_mm2=400.0, power_w=150.0,
                           bw_gbps=512.0),
}


def get_budget(name: str) -> SoCBudget:
    """Resolve a preset by name; unknown names list what IS defined
    (the registry's error style)."""
    try:
        return BUDGET_PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown budget preset {name!r}; presets: "
                       f"{sorted(BUDGET_PRESETS)}") from None
