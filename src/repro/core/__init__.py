"""COSMOS core: compositional DSE coordinating synthesis + memory tools.

This package is the paper's primary contribution, implemented generically
over a batched synthesis oracle:

  * :mod:`repro.core.tmg` — timed-marked-graph system model (Section 2.2)
  * :mod:`repro.core.oracle` — the unified oracle protocol: batched
    ``evaluate``/``evaluate_batch``, the ``OracleLedger`` invocation
    accounting (Fig. 11), and the persistent result cache
  * :mod:`repro.core.session` — ``ExplorationSession``: the batched,
    resumable drive with explicit characterize/plan/map phases
  * :mod:`repro.core.characterize` — Algorithm 1 (Section 5)
  * :mod:`repro.core.planning` — Eq. (2) LP synthesis planning (Section 6.1)
  * :mod:`repro.core.mapping` — Eq. (4/5) synthesis mapping (Section 6.2)
  * :mod:`repro.core.dse` — thin drivers + exhaustive baseline (Section 7)
  * :mod:`repro.core.hlsim` / :mod:`repro.core.memgen` — the simulated
    HLS + Mnemosyne oracles (DESIGN.md Section 2)
  * :mod:`repro.core.autotune` / :mod:`repro.core.xlatool` — the TPU
    instantiation: XLA pricing/compiles as the synthesis oracle,
    sharding/remat as the memory knobs
  * :mod:`repro.core.pallas_oracle` / :mod:`repro.core.calibrate` — the
    measured backend: knob-parameterized Pallas kernels compiled + timed
    per point with record/replay, and the fit of the analytical tool's
    latency constants to those measurements (docs/backends.md)
  * :mod:`repro.core.plm` — the system-level PLM planner: the tile knob
    axis, the TMG non-concurrency certificate, shared-bank memory
    plans, and the one-cost-unit exchange rates (docs/memory.md)
  * :mod:`repro.core.registry` — the App/Backend registry: one entry
    point (``get_app``/``get_backend``/``build_session``) for every
    workload x oracle pair (docs/backends.md)
  * :mod:`repro.core.analysis` — schedule-aware static analysis: busy
    intervals + two-tier non-concurrency certificates, the independent
    PLM-plan race detector, and the repo lint driver (docs/analysis.md)
  * :mod:`repro.core.obs` — the unified observability layer: span-based
    tracing (deterministic under a logical clock, exportable as Chrome
    ``trace_event``) and the metrics registry behind every counter
    (docs/observability.md)
"""

from .characterize import CharacterizationResult, characterize_component, spans
from .dse import (CosmosResult, ExhaustiveResult, SystemPoint,
                  compose_exhaustive, cosmos_dse, exhaustive_dse)
from .hlsim import ComponentSpec, HLSTool, LoopNest
from .knobs import (CDFGFacts, KnobSpace, Region, Synthesis, SynthesisTool,
                    powers_of_two)
from .mapping import MapOutcome, map_target, phi
from .memgen import MemGen, PLM, PLMSpec
from .obs import (Counter, Gauge, Histogram, LogicalClock, MetricsRegistry,
                  NULL_TRACER, NullTracer, Span, Tracer, WallClock)
from .oracle import (CountingTool, InvocationRecord, InvocationRequest,
                     Oracle, OracleBatchMixin, OracleLedger,
                     PersistentOracleCache, SharedOracle)
from .calibrate import (CalibratedTool, CalibrationFit, calibrate_to_records,
                        fit_area_scale, fit_latency_scales)
from .plm import (MemoryCompatGraph, MemoryGroup, MemoryPlan, PLMPlanner,
                  PLMRequirement, UnitSystem, exclusive_pairs,
                  fit_unit_system)
from .pallas_oracle import (MeasurementSet, MeasurementStore,
                            MissingMeasurementError, PallasKernelSpec,
                            PallasOracle)
from .registry import (App, Backend, build_query_session, build_session,
                       build_tool, get_app, get_backend, list_apps,
                       list_backends, register_app, register_backend)
from .pricing import BatchPricer
from .surrogate import (GuidedCharacterization, RidgeSurrogate,
                        guided_characterize_component)
from .pareto import (DesignPoint, check_delta_curve, dominates_max_min,
                     dominates_min_min, pareto_front_max_min,
                     pareto_front_min_min, span)
from .planning import (ComponentModel, PiecewiseLinearCost, PlanPoint,
                       Schedule, plan, sweep, theta_bounds)
from .plm.compat import CompatSource
from .session import DSEQuery, ExplorationSession, ProgressEvent
from .tmg import TMG, Place, Transition, feedback_pipeline_tmg, pipeline_tmg

__all__ = [
    "TMG", "Place", "Transition", "pipeline_tmg", "feedback_pipeline_tmg",
    "DesignPoint", "pareto_front_min_min", "pareto_front_max_min", "span",
    "check_delta_curve", "dominates_min_min", "dominates_max_min",
    "KnobSpace", "Region", "Synthesis", "CDFGFacts", "SynthesisTool",
    "powers_of_two",
    "Oracle", "OracleBatchMixin", "OracleLedger", "CountingTool",
    "InvocationRequest", "InvocationRecord", "PersistentOracleCache",
    "SharedOracle",
    "PallasOracle", "PallasKernelSpec", "MeasurementStore",
    "MeasurementSet", "MissingMeasurementError",
    "App", "Backend", "register_app", "register_backend", "get_app",
    "get_backend", "list_apps", "list_backends", "build_tool",
    "build_session", "build_query_session",
    "CalibratedTool", "CalibrationFit", "fit_latency_scales",
    "fit_area_scale", "calibrate_to_records",
    "PLMRequirement", "MemoryGroup", "MemoryPlan", "MemoryCompatGraph",
    "exclusive_pairs", "PLMPlanner", "UnitSystem", "fit_unit_system",
    "ExplorationSession", "ProgressEvent", "DSEQuery",
    "ComponentSpec", "LoopNest", "HLSTool", "MemGen", "PLM", "PLMSpec",
    "CharacterizationResult", "characterize_component", "spans",
    "BatchPricer", "RidgeSurrogate", "GuidedCharacterization",
    "guided_characterize_component",
    "ComponentModel", "PiecewiseLinearCost", "PlanPoint", "Schedule",
    "plan", "sweep", "theta_bounds",
    "BusyInterval", "ScheduleCertificate", "schedule_exclusive_pairs",
    "compat_source_for", "CompatSource", "Violation",
    "PlanVerificationError", "verify_plan",
    "phi", "map_target", "MapOutcome",
    "cosmos_dse", "CosmosResult", "exhaustive_dse", "ExhaustiveResult",
    "compose_exhaustive", "SystemPoint",
    "Tracer", "Span", "NullTracer", "NULL_TRACER", "WallClock",
    "LogicalClock", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "SoCBudget", "TrafficMix", "AppDemand", "SoCComposer", "Composition",
    "BudgetInfeasibleError", "verify_composition",
]


# the static-analysis layer is exported lazily: its verify/lint modules
# are also `python -m` entry points, and importing them eagerly here
# would mean every `python -m repro.core.analysis.verify` run imports
# the module twice (runpy's double-import warning)
_ANALYSIS_LAZY = {
    "BusyInterval", "ScheduleCertificate", "schedule_exclusive_pairs",
    "compat_source_for", "Violation", "PlanVerificationError",
    "verify_plan",
}


# same rule for the SoC composition layer (compose/verify are
# `python -m` entry points too)
_SOC_LAZY = {
    "SoCBudget", "TrafficMix", "AppDemand", "SoCComposer", "Composition",
    "BudgetInfeasibleError", "verify_composition",
}


def __getattr__(name):
    if name in _ANALYSIS_LAZY:
        from . import analysis
        return getattr(analysis, name)
    if name in _SOC_LAZY:
        from . import soc
        return getattr(soc, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
