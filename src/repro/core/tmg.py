"""Timed marked graphs (TMGs) — the paper's computational model (Section 2.2).

A TMG is a Petri net in which every place has exactly one input and one
output transition.  Transitions model accelerator components; their firing
delay is the component's *effective latency* lambda.  Places model TLM
channels; their initial marking (tokens) models buffering (ping-pong
buffers contribute tokens, as in Fig. 3).

The minimum cycle time of a strongly-connected TMG is

    max_k ( D_k / N_k )            for every directed cycle k,

where D_k is the sum of transition delays on the cycle and N_k the number
of tokens on the cycle (Ramamoorthy & Ho, 1980).  The maximum sustainable
effective throughput theta is its reciprocal; for non-strongly-connected
TMGs theta is the minimum over the strongly-connected components
(Section 2.2 of the paper).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Place",
    "Transition",
    "TMG",
    "pipeline_tmg",
    "feedback_pipeline_tmg",
]


@dataclass(frozen=True)
class Transition:
    """A component of the accelerator (fires with delay = effective latency)."""

    name: str


@dataclass(frozen=True)
class Place:
    """A TLM channel between two components.

    ``tokens`` is the initial marking: 1 for a plain dependency edge, >1
    when the channel is double/circular-buffered (Fig. 3), and the
    feedback edge that closes a streaming pipeline carries the number of
    in-flight frames.
    """

    name: str
    src: str
    dst: str
    tokens: int = 0


class TMG:
    """A timed marked graph over named transitions.

    The class is deliberately small and dependency-free: the WAMI graph
    has 13 transitions and the LLM-block graphs have <10, so cycle
    enumeration is cheap.  All hot paths are plain python + numpy.
    """

    def __init__(self, transitions: Sequence[Transition], places: Sequence[Place]):
        self.transitions: List[Transition] = list(transitions)
        self.places: List[Place] = list(places)
        self._index: Dict[str, int] = {t.name: i for i, t in enumerate(self.transitions)}
        if len(self._index) != len(self.transitions):
            raise ValueError("duplicate transition names")
        for p in self.places:
            if p.src not in self._index or p.dst not in self._index:
                raise ValueError(f"place {p.name} references unknown transition")
        self._succ: Dict[str, List[Place]] = {t.name: [] for t in self.transitions}
        for p in self.places:
            self._succ[p.src].append(p)
        # lazily-filled cycle cache: the structure is immutable after
        # construction and every consumer (throughput, compat graphs,
        # certificates) re-enumerates the same cycles otherwise
        self._cycles: List[List[Place]] = None  # type: ignore[assignment]

    # ------------------------------------------------------------------
    # Structure
    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.transitions)

    @property
    def m(self) -> int:
        return len(self.places)

    def incidence_matrix(self) -> np.ndarray:
        """A[i, j] per Eq. (3): +1 if t_j is an output transition of p_i
        (consumes from it), -1 if t_j is an input transition of p_i
        (produces into it).  With this sign convention the Eq. (2) row
        A sigma + M0/theta >= tau^- reads
        sigma_dst - sigma_src + M0_i/theta >= tau_src: the consumer of a
        place fires no earlier than one producer latency after the
        producer, minus the slack of the initial tokens at period
        1/theta."""
        A = np.zeros((self.m, self.n), dtype=np.float64)
        for i, p in enumerate(self.places):
            A[i, self._index[p.dst]] += 1.0   # t_dst consumes from p
            A[i, self._index[p.src]] -= 1.0   # t_src produces into p
        return A

    def initial_marking(self) -> np.ndarray:
        return np.array([p.tokens for p in self.places], dtype=np.float64)

    def input_delay_selector(self) -> np.ndarray:
        """B[i, j] = 1 iff transition j feeds place i (tau^-_i = tau_j).

        Used to build the LP constraint A sigma + M0/theta >= B tau of
        Eq. (2):  tau^-_i is the firing delay of the transition entering
        place p_i.
        """
        B = np.zeros((self.m, self.n), dtype=np.float64)
        for i, p in enumerate(self.places):
            B[i, self._index[p.src]] = 1.0
        return B

    # ------------------------------------------------------------------
    # Cycles and throughput
    # ------------------------------------------------------------------
    def simple_cycles(self) -> List[List[Place]]:
        """Enumerate simple cycles (as place lists) via DFS (Johnson-lite).

        Graphs here are tiny; an exponential enumerator is fine and keeps
        the code auditable.  The result is computed once per TMG and
        cached — callers must not mutate the returned lists.
        """
        if self._cycles is not None:
            return self._cycles
        cycles: List[List[Place]] = []
        seen_keys = set()

        names = [t.name for t in self.transitions]
        for start in names:
            stack: List[Tuple[str, List[Place]]] = [(start, [])]
            while stack:
                node, path = stack.pop()
                for place in self._succ[node]:
                    nxt = place.dst
                    if nxt == start:
                        cyc = path + [place]
                        # canonicalize so each cycle is recorded once
                        key = frozenset(id_p.name for id_p in cyc)
                        if key not in seen_keys:
                            seen_keys.add(key)
                            cycles.append(cyc)
                    elif nxt not in {pl.src for pl in path} and nxt > start:
                        # ">" ordering prevents re-discovering cycles from
                        # a later start node
                        stack.append((nxt, path + [place]))
        self._cycles = cycles
        return cycles

    def strongly_connected(self) -> bool:
        """Kosaraju on the transition graph."""
        succ: Dict[str, List[str]] = {t.name: [] for t in self.transitions}
        pred: Dict[str, List[str]] = {t.name: [] for t in self.transitions}
        for p in self.places:
            succ[p.src].append(p.dst)
            pred[p.dst].append(p.src)

        def reach(adj: Dict[str, List[str]], root: str) -> set:
            out, stack = set(), [root]
            while stack:
                u = stack.pop()
                if u in out:
                    continue
                out.add(u)
                stack.extend(adj[u])
            return out

        root = self.transitions[0].name
        return len(reach(succ, root)) == self.n and len(reach(pred, root)) == self.n

    def min_cycle_time(self, delays: Dict[str, float]) -> float:
        """max over cycles of D_k / N_k.

        ``delays`` maps transition name -> firing delay (effective latency).
        A cycle with zero tokens is a deadlock -> +inf.
        """
        worst = 0.0
        for cyc in self.simple_cycles():
            d = sum(delays[p.src] for p in cyc)
            n_tok = sum(p.tokens for p in cyc)
            if n_tok == 0:
                return float("inf")
            worst = max(worst, d / n_tok)
        return worst

    def throughput(self, delays: Dict[str, float]) -> float:
        """Maximum sustainable effective throughput theta (Section 2.2)."""
        mct = self.min_cycle_time(delays)
        if mct == 0.0:
            return float("inf")
        return 1.0 / mct

    def critical_cycle(self, delays: Dict[str, float]) -> List[Place]:
        best, best_val = [], -1.0
        for cyc in self.simple_cycles():
            n_tok = sum(p.tokens for p in cyc)
            val = float("inf") if n_tok == 0 else sum(delays[p.src] for p in cyc) / n_tok
            if val > best_val:
                best, best_val = cyc, val
        return best

    def criticality(self, delays: Dict[str, float]) -> Dict[str, float]:
        """Per-component share of the critical cycle time — used by the DSE
        to prioritize synthesis of the components that bound throughput
        (Section 3.3: 'prioritizes the synthesis of the components
        depending on their level of contribution to the effective
        throughput')."""
        cyc = self.critical_cycle(delays)
        total = sum(delays[p.src] for p in cyc) or 1.0
        out = {t.name: 0.0 for t in self.transitions}
        for p in cyc:
            out[p.src] += delays[p.src] / total
        return out


# ----------------------------------------------------------------------
# Constructors for the common shapes
# ----------------------------------------------------------------------

def pipeline_tmg(names: Sequence[str], buffers: int = 1, frames_in_flight: int = 1) -> TMG:
    """A linear streaming pipeline closed by a feedback place.

    Forward places carry ``buffers`` tokens' worth of channel capacity
    modelled as: forward edge with 0 initial tokens is WRONG for a marked
    graph throughput model — the standard construction gives each forward
    edge 0 tokens and each *backward* (capacity) edge ``buffers`` tokens,
    plus a global feedback edge with ``frames_in_flight`` tokens.  The
    cycle (fwd_i, back_i) then has N = buffers and D = lam_i + lam_{i+1},
    which reproduces the ping-pong overlap of Fig. 3: with buffers=2 the
    pipeline sustains theta = 1/max(lam_i); with buffers=1 adjacent
    stages serialize (theta = 1/(lam_i + lam_{i+1}) pairwise).
    """
    transitions = [Transition(n) for n in names]
    places: List[Place] = []
    for a, b in zip(names, names[1:]):
        places.append(Place(f"fwd:{a}->{b}", a, b, tokens=0))
        places.append(Place(f"cap:{b}->{a}", b, a, tokens=buffers))
    # self-capacity on each stage: a component cannot re-fire before it
    # finished (initiation-interval 1 on itself)
    for nme in names:
        places.append(Place(f"self:{nme}", nme, nme, tokens=1))
    # close the stream: last -> first with the number of frames in flight
    places.append(Place(f"loop:{names[-1]}->{names[0]}", names[-1], names[0],
                        tokens=frames_in_flight + len(names) - 1))
    return TMG(transitions, places)


def feedback_pipeline_tmg(names: Sequence[str], loop_from: str, loop_to: str,
                          loop_tokens: int, buffers: int = 2) -> TMG:
    """Pipeline with an extra algorithmic feedback edge (e.g. Lucas-Kanade's
    iterative refinement loop in the WAMI TMG, Fig. 8)."""
    base = pipeline_tmg(names, buffers=buffers)
    places = list(base.places)
    places.append(Place(f"alg:{loop_from}->{loop_to}", loop_from, loop_to, tokens=loop_tokens))
    return TMG(base.transitions, places)
