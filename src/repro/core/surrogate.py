"""Surrogate-guided frugal characterization (Fig. 11, beyond 14.6x).

The paper's headline is invocation frugality: Algorithm 1 spends 14.6x
fewer HLS-tool invocations than exhaustive search on the WAMI zoo.
This module pushes further in the style of Ferretti et al.'s graph-DL
HLS-DSE proposal loop (PAPERS.md): a cheap model *proposes* likely-
Pareto knob points, and only the proposals are *confirmed* through the
real oracle.

Two cooperating pieces:

* :class:`RidgeSurrogate` — a lightweight TMG-feature ridge regression
  over per-component CDFG facts + knob coordinates, fitted online from
  the ledger's :class:`~repro.core.oracle.InvocationRecord` stream (no
  extra oracle traffic).  It ranks candidate Pareto corners; before any
  records exist it defers to the grid's own latency ordering.  The
  session fits it only at characterize-phase boundaries — every
  component ranks against the same phase-start state, so the guided
  ledger books are identical at any worker count; a surrogate reused
  across sessions (the service's pools, or ``build_session(surrogate=)``)
  carries the previous run's fit into the next ranking.
* :func:`guided_characterize_component` — runs the full Algorithm-1
  corner walk against a :class:`~repro.core.pricing.BatchPricer` grid
  (zero real invocations), then confirms the surrogate's top-ranked
  corner through the real ledger.  The confirmation is compared
  field-for-field against the grid's prediction; **any** mismatch
  discards the guided walk and re-runs the component through the real
  oracle unguided.

The fall-back guarantee this buys: the emitted regions/points — and
therefore the plan and the mapped Pareto front — are byte-identical to
the unguided walk, while the ledger's characterize-phase spend drops
from the full corner walk to one confirmation per component (the map
phase still pays real invocations for every mapped point, exactly as
before).  A poisoned surrogate can only change *which* corner is
confirmed, never the emitted front; a poisoned grid is caught by the
confirmation mismatch and costs one wasted invocation plus the normal
unguided walk.  The differential battery in ``tests/test_pricing.py``
pins the grid's bit-exactness; ``tests/test_surrogate.py`` pins the
byte-identity and the invocation-reduction ratio.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .characterize import CharacterizationResult, characterize_component
from .knobs import CDFGFacts, KnobSpace, Region, Synthesis
from .oracle import InvocationRecord, InvocationRequest, OracleLedger
from .pricing import BatchPricer

__all__ = ["RidgeSurrogate", "GuidedCharacterization",
           "guided_characterize_component"]


class _GridWalk:
    """Ledger-shaped facade over a :class:`BatchPricer`.

    ``characterize_component`` duck-types its ``tool`` — it only calls
    ``synthesize``/``cdfg_facts``/``total``/``failed.get`` — so the
    whole Algorithm-1 corner walk runs unchanged against the grid, with
    local counters standing in for the ledger's accounting.  Nothing
    here touches the real oracle.
    """

    def __init__(self, pricer: BatchPricer):
        self._pricer = pricer
        self._total: Dict[str, int] = {}
        self.failed: Dict[str, int] = {}

    def synthesize(self, component: str, **kw: Any) -> Synthesis:
        self._total[component] = self._total.get(component, 0) + 1
        if not kw.get("tile", 1):
            # mirror call_synthesize: a falsy tile is not forwarded, so
            # tools without a tile axis (XLATool) answer exactly as they
            # would under the real ledger
            kw.pop("tile")
        out = self._pricer.synthesize(component, **kw)
        if not out.feasible:
            self.failed[component] = self.failed.get(component, 0) + 1
        return out

    def cdfg_facts(self, component: str, synth: Synthesis) -> CDFGFacts:
        return self._pricer.cdfg_facts(component, synth)

    def total(self, component: str) -> int:
        return self._total.get(component, 0)


class RidgeSurrogate:
    """Ridge regression on ``log(lam)`` over CDFG facts + knob coords.

    Feature vector per priced point: ``[1, log2 u, log2 p, u/p,
    gamma_r, gamma_w, eta, log2(trip+1), tile]`` with the facts taken
    from the component's characterized lower-right corner (the paper's
    Eq. (1) inputs).  Fitting is a closed-form normal-equations solve —
    cheap enough to re-fit at every phase boundary.  Thread-safe: the
    session's characterize phase fans components out over a pool.
    """

    N_FEATURES = 9

    def __init__(self, l2: float = 1e-6):
        self.l2 = float(l2)
        self._w: Optional[np.ndarray] = None
        self._facts: Dict[str, CDFGFacts] = {}
        self._lock = threading.Lock()

    # -- facts registry ------------------------------------------------
    def observe_facts(self, component: str, facts: CDFGFacts) -> None:
        with self._lock:
            self._facts[component] = facts

    def features(self, component: str, unrolls: int, ports: int,
                 tile: int) -> List[float]:
        f = self._facts.get(component)
        gamma_r = float(f.gamma_r) if f else 0.0
        gamma_w = float(f.gamma_w) if f else 0.0
        eta = float(f.eta) if f else 0.0
        trip = float(f.trip) if f else 0.0
        return [1.0, math.log2(unrolls), math.log2(ports),
                unrolls / ports, gamma_r, gamma_w, eta,
                math.log2(trip + 1.0), float(tile)]

    # -- fit / predict ---------------------------------------------------
    @property
    def fitted(self) -> bool:
        return self._w is not None

    def fit(self, records: Iterable[InvocationRecord]) -> bool:
        """Fit from the ledger's record stream; returns True when there
        is enough signal (more usable rows than features).  Records are
        sorted into a canonical order first so the solved weights are
        independent of arrival order (a fanned-out characterize phase
        appends records in thread-completion order)."""
        usable = sorted(
            (r for r in records
             if r.feasible and math.isfinite(r.lam) and r.lam > 0),
            key=lambda r: (r.component, r.unrolls, r.ports, r.tile,
                           r.lam))
        rows: List[List[float]] = []
        targets: List[float] = []
        for r in usable:
            rows.append(self.features(r.component, r.unrolls, r.ports,
                                      r.tile))
            targets.append(math.log(r.lam))
        if len(rows) <= self.N_FEATURES:
            return False
        X = np.asarray(rows)
        y = np.asarray(targets)
        gram = X.T @ X + self.l2 * np.eye(X.shape[1])
        w = np.linalg.solve(gram, X.T @ y)
        with self._lock:
            self._w = w
        return True

    def predict(self, component: str, unrolls: int, ports: int,
                tile: int) -> float:
        """Predicted ``log(lam)``; raises before the first ``fit``."""
        with self._lock:
            w = self._w
        if w is None:
            raise RuntimeError("surrogate is not fitted")
        x = np.asarray(self.features(component, unrolls, ports, tile))
        return float(x @ w)


@dataclass(frozen=True)
class _Candidate:
    """One confirmable Pareto corner of a kept region."""

    region: Region
    request: InvocationRequest
    grid_lam: float


@dataclass
class GuidedCharacterization:
    """Outcome of one guided component run.

    ``result`` is what an unguided :func:`characterize_component` would
    have returned (same regions/points; ``invocations``/``failed`` are
    the *real-ledger* per-run deltas, so Fig. 11 accounting reads real
    money spent).  ``confirmed`` counts oracle confirmations paid;
    ``fell_back`` records that a grid/oracle mismatch forced the full
    unguided walk; ``grid_invocations`` is what the walk would have
    cost without the grid (the frugality numerator).
    """

    result: CharacterizationResult
    confirmed: int
    fell_back: bool
    grid_invocations: int


def _corner_request(component: str, region: Region) -> InvocationRequest:
    """The region's upper-left corner as the oracle request the walk
    made for it (Algorithm 1 lines 4-7: the Eq. (1) cap applies only to
    a real ladder step on a PLM-accessing loop)."""
    if region.mu_max > region.mu_min and region.facts.has_plm_access:
        cap = region.facts.h(region.mu_max, region.ports)
    else:
        cap = None
    return InvocationRequest(component=component, unrolls=region.mu_max,
                             ports=region.ports, max_states=cap,
                             tile=region.tile)


def _rank(component: str, candidates: List[_Candidate],
          surrogate: Optional[RidgeSurrogate]) -> List[_Candidate]:
    """Most-likely-Pareto first: surrogate order once fitted, the
    grid's own latency order before that (and for ties)."""
    if surrogate is not None and surrogate.fitted:
        return sorted(candidates, key=lambda c: (
            surrogate.predict(component, c.request.unrolls,
                              c.request.ports, c.request.tile),
            c.grid_lam))
    return sorted(candidates, key=lambda c: c.grid_lam)


def guided_characterize_component(
        ledger: OracleLedger, component: str, space: KnobSpace, *,
        pricer: BatchPricer,
        surrogate: Optional[RidgeSurrogate] = None,
        confirmations: int = 1,
        neighbourhood: int = 2,
        prune_dominated_regions: bool = True,
        refit: bool = True) -> GuidedCharacterization:
    """Algorithm 1 with grid pricing + oracle confirmation (module doc).

    ``confirmations`` bounds how many top-ranked corners are confirmed
    through the real oracle (at least one; a degenerate characterization
    with no kept regions confirms nothing and spends nothing).
    ``refit=False`` skips the end-of-run surrogate refit — the session's
    fanned-out characterize phase passes it so every component ranks
    against the same phase-start surrogate state (the guided ledger
    books stay worker-count invariant) and refits once at phase end.
    """
    total_before = ledger.total(component)
    failed_before = ledger.failed.get(component, 0)

    walk = _GridWalk(pricer)
    grid_res = characterize_component(
        walk, component, space, neighbourhood=neighbourhood,
        prune_dominated_regions=prune_dominated_regions)

    if surrogate is not None and grid_res.regions:
        # Eq. (1) inputs for the feature vector: the component's facts
        # as observed on its (grid-priced) lower-right corners
        surrogate.observe_facts(component, grid_res.regions[0].facts)

    candidates = [
        _Candidate(region=r, request=_corner_request(component, r),
                   grid_lam=r.lam_min)
        for r in grid_res.regions]
    ranked = _rank(component, candidates, surrogate)

    fell_back = False
    confirmed = 0
    for cand in ranked[:max(0, confirmations)]:
        req = cand.request
        expected = pricer.synthesize(
            component, unrolls=req.unrolls, ports=req.ports,
            max_states=req.max_states,
            **({"tile": req.tile} if req.tile else {}))
        actual = ledger.evaluate(req)
        confirmed += 1
        if actual != expected:
            fell_back = True
            break

    if fell_back:
        # trust nothing from the grid: re-run the whole component
        # through the real oracle; every invocation is counted, and the
        # emitted regions/points are the unguided walk's by definition
        real = characterize_component(
            ledger, component, space, neighbourhood=neighbourhood,
            prune_dominated_regions=prune_dominated_regions)
        regions, points = real.regions, real.points
    else:
        regions, points = grid_res.regions, grid_res.points

    if surrogate is not None and refit:
        # online refit from everything the ledger has actually paid for
        # (confirmations included) — the next run ranks better
        surrogate.fit(ledger.records)

    result = CharacterizationResult(
        component=component, regions=regions, points=points,
        invocations=ledger.total(component) - total_before,
        failed=ledger.failed.get(component, 0) - failed_before)
    return GuidedCharacterization(
        result=result, confirmed=confirmed, fell_back=fell_back,
        grid_invocations=walk.total(component))
