"""Small cross-cutting helpers (version compatibility shims)."""

from __future__ import annotations

from typing import Any

import jax

__all__ = ["keystr_path"]


def _keystr_fallback(kp: Any) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):          # DictKey
            parts.append(str(k.key))
        elif hasattr(k, "idx"):        # SequenceKey / FlattenedIndexKey
            parts.append(str(k.idx))
        elif hasattr(k, "name"):       # GetAttrKey
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


try:
    jax.tree_util.keystr((), simple=True, separator="/")
    _HAVE_SIMPLE = True
except TypeError:                      # jax < 0.4.38
    _HAVE_SIMPLE = False


def keystr_path(kp: Any) -> str:
    """'a/b/0'-style path string for a tree_flatten_with_path key path.

    Equivalent to ``jax.tree_util.keystr(kp, simple=True, separator="/")``
    on new jax; hand-rolled on versions whose keystr lacks the kwargs.
    """
    if _HAVE_SIMPLE:
        return jax.tree_util.keystr(kp, simple=True, separator="/")
    return _keystr_fallback(kp)
