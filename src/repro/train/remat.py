"""Activation-checkpoint (remat) policies, applied at the layer-scan body.

Models wrap their per-layer block with :func:`maybe_remat`; which policy
is active is a context installed by the train step — the models stay
policy-agnostic.  Policies:

  * ``none``  — save everything (prefill/decode, small models);
  * ``full``  — save only layer boundaries (max memory saving, recompute
    the whole block in backward);
  * ``dots``  — ``checkpoint_dots``: save matmul outputs, recompute the
    cheap elementwise chain (the usual best trade-off on TPU).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

import jax

__all__ = ["remat_context", "maybe_remat", "current_policy"]

_ctx = threading.local()

_POLICIES = {
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


@contextlib.contextmanager
def remat_context(policy: Optional[str]):
    prev = getattr(_ctx, "policy", None)
    _ctx.policy = policy
    try:
        yield
    finally:
        _ctx.policy = prev


def current_policy() -> Optional[str]:
    return getattr(_ctx, "policy", None)


def maybe_remat(fn: Callable) -> Callable:
    """Wrap a layer body according to the active policy (identity when
    no policy is installed)."""
    policy = current_policy()
    if policy in (None, "none"):
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=_POLICIES[policy])
