"""Training: step factory, remat policies, loop."""

from .remat import maybe_remat, remat_context
from .step import TrainStepConfig, make_loss_fn, make_train_step

__all__ = ["make_train_step", "make_loss_fn", "TrainStepConfig",
           "remat_context", "maybe_remat"]
