"""Train-step factory: microbatched, remat-policied, mixed-precision.

``make_train_step`` builds the function the launcher jits/lowers:

    step(params, opt_state, batch) -> (params, opt_state, metrics)

Features (all knobs the COSMOS-TPU planner can turn, DESIGN.md §2):
  * microbatch gradient accumulation (``microbatches`` — the "unrolls"
    analogue: time/space trade inside a fixed sharding);
  * remat policy for the layer scan (none/full/dots);
  * fp32 grad accumulation over bf16 compute, optional bf16 accumulation
    (halves the cross-pod gradient all-reduce bytes — §Perf lever);
  * optional error-feedback int8 gradient compression (``repro.dist``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.compression import ef_compress_tree
from ..optim import (AdamWConfig, OptState, QuantOptState, apply_updates,
                     apply_updates_q8, warmup_cosine)
from .remat import remat_context

__all__ = ["TrainStepConfig", "make_train_step", "make_loss_fn"]


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    remat: Optional[str] = "full"          # none | full | dots | dots_no_batch
    accum_dtype: str = "float32"           # float32 | bfloat16
    compress_grads_bits: int = 0           # 0 = off; 8 = int8 error feedback
    quantized_moments: bool = False        # 8-bit AdamW states (1T-scale)
    warmup_steps: int = 100
    total_steps: int = 10000


def make_loss_fn(model, remat: Optional[str]):
    def loss_fn(params, batch):
        with remat_context(remat):
            loss, metrics = model.loss(params, batch)
        return loss, metrics
    return loss_fn


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    def split(path_unused, x):
        return x  # placeholder, replaced below
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":          # (3, B, S): batch is dim 1
            B = v.shape[1]
            assert B % n == 0, f"batch {B} not divisible by {n} microbatches"
            out[k] = v.reshape(v.shape[0], n, B // n, *v.shape[2:]).swapaxes(0, 1)
        else:
            B = v.shape[0]
            assert B % n == 0, f"batch {B} not divisible by {n} microbatches"
            out[k] = v.reshape(n, B // n, *v.shape[1:])
    return out


def make_train_step(model, opt_cfg: AdamWConfig,
                    cfg: TrainStepConfig = TrainStepConfig()
                    ) -> Callable:
    """Build the jittable train step for ``model``."""
    loss_fn = make_loss_fn(model, cfg.remat)
    grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b), has_aux=True)
    acc_dt = jnp.dtype(cfg.accum_dtype)

    def step(params, opt_state: OptState, batch):
        if cfg.microbatches > 1:
            mbs = _split_microbatches(batch, cfg.microbatches)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def accum(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (grads, loss_sum), _ = lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
            loss = loss_sum / cfg.microbatches
            metrics: Dict[str, jnp.ndarray] = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if cfg.compress_grads_bits:
            grads, _ = ef_compress_tree(grads, bits=cfg.compress_grads_bits)

        lr_scale = warmup_cosine(opt_state.step, warmup=cfg.warmup_steps,
                                 total=cfg.total_steps)
        if cfg.quantized_moments:
            params, opt_state, opt_metrics = apply_updates_q8(
                opt_cfg, params, grads, opt_state, lr_scale=lr_scale)
        else:
            params, opt_state, opt_metrics = apply_updates(
                opt_cfg, params, grads, opt_state, lr_scale=lr_scale)
        out = {"loss": loss, **opt_metrics}
        if isinstance(metrics, dict):
            out.update({k: v for k, v in metrics.items()
                        if jnp.ndim(v) == 0})
        return params, opt_state, out

    return step
