"""Step-atomic sharded checkpointing (sync + async)."""

from .async_ckpt import AsyncCheckpointer
from .store import latest_step, list_steps, restore, save

__all__ = ["save", "restore", "latest_step", "list_steps", "AsyncCheckpointer"]
