"""Asynchronous checkpointing: snapshot to host, write in background.

``save_async`` copies device arrays to host numpy synchronously (cheap —
bounded by PCIe/ICI, not disk) and hands the serialized write to a single
worker thread, so training resumes while the previous step is still
hitting disk.  At most one write is in flight; a second request waits for
the first (bounded memory).  ``wait()`` drains the queue — call before
exiting or measuring.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from . import store

__all__ = ["AsyncCheckpointer"]


class AsyncCheckpointer:
    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()                              # one write in flight
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                store.save(self.root, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        t = threading.Thread(target=work, daemon=True)
        with self._lock:
            self._pending = t
        t.start()

    def wait(self):
        with self._lock:
            t, self._pending = self._pending, None
        if t is not None:
            t.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = store.list_steps(self.root)
        for s in steps[:-self.keep_last]:
            import shutil, os
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.wait()
