"""Sharded, step-atomic checkpointing (numpy container, no orbax).

Layout:

    <root>/step_000123/
        manifest.json           # pytree structure, leaf paths/shapes/dtypes
        <leafpath>.npy          # one file per leaf (host-local shard)
    <root>/LATEST                # atomic pointer, written last

Write protocol: serialize into ``step_xxxxx.tmp``, fsync files, rename
the directory, then rewrite LATEST — a crash leaves either the previous
complete checkpoint or a garbage .tmp that restore ignores, never a torn
state (the fault-tolerance contract ``repro.ft`` relies on).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..utils import keystr_path

__all__ = ["save", "restore", "latest_step", "list_steps"]


def _leaves_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    out = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((keystr_path(kp), leaf))
    return out


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(root: str, step: int, tree: Any, *, extra: Optional[Dict] = None):
    """Write one checkpoint atomically."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for path, leaf in _leaves_with_paths(tree):
        arr = np.asarray(leaf)
        fn = path.replace("/", "__") + ".npy"
        with open(os.path.join(tmp, fn), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({"path": path, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(root)
    latest = os.path.join(root, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest + ".tmp", latest)


def list_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, "manifest.json")):
                out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    """The step LATEST points to, falling back to a directory scan (a
    crash between dir-rename and LATEST update is recoverable)."""
    steps = list_steps(root)
    ptr = os.path.join(root, "LATEST")
    if os.path.exists(ptr):
        with open(ptr) as f:
            s = int(f.read().strip())
        if s in steps:
            return s
    return steps[-1] if steps else None


def restore(root: str, step: int, like: Any) -> Tuple[Any, Dict]:
    """Restore a checkpoint into the structure of ``like``."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {m["path"]: m for m in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat:
        path = keystr_path(kp)
        m = by_path.get(path)
        if m is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(os.path.join(d, m["file"]))
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(f"{path}: checkpoint shape {arr.shape} != {want}")
        leaves.append(arr.astype(np.asarray(leaf).dtype)
                      if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest["extra"]
