"""CDFG extraction from jaxprs.

The paper infers the lambda-constraint inputs (gamma_r, gamma_w, eta) "by
traversing the control data flow graph (CDFG) created by the HLS tool for
scheduling the lower-right point" (Section 5).  Our components are JAX
functions, so the CDFG *is* the jaxpr: each WAMI component exposes its
per-iteration scalar body (``kernel``), and this module traverses
``jax.make_jaxpr(kernel)`` to count

  * gamma_r — the maximum number of reads of the same PLM array per loop
    iteration = the largest per-iteration window among the kernel inputs;
  * gamma_w — writes per iteration = total output elements;
  * arith_ops / dep_depth — arithmetic operation count and critical
    dependence-chain depth of the dataflow graph (the scheduler inputs).

This keeps the characterization honest: the same dataflow graph that
executes (and is golden-tested) drives the synthesis model.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence, Tuple

import jax
import numpy as np

from ...core.hlsim import LoopNest

__all__ = ["KernelFacts", "analyze_kernel", "loop_nest_from_kernel"]

# Primitives that occupy a functional unit for one state.  Everything
# else (reshapes, converts, broadcasts) is wiring.
_ARITH = {
    "add", "sub", "mul", "div", "rem", "neg", "abs", "sign",
    "max", "min", "pow", "integer_pow", "exp", "log", "sqrt", "rsqrt",
    "tanh", "logistic", "floor", "ceil", "round", "erf",
    "lt", "le", "gt", "ge", "eq", "ne", "select_n", "clamp",
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "nextafter", "atan2", "square",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin"}
_FREE = {"reshape", "broadcast_in_dim", "convert_element_type", "squeeze",
         "transpose", "slice", "concatenate", "rev", "copy", "stop_gradient",
         "split", "pjit", "custom_jvp_call", "custom_vjp_call"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


@dataclass(frozen=True)
class KernelFacts:
    reads_per_input: Tuple[int, ...]   # window elements read per iteration
    writes: int                        # output elements per iteration
    arith_ops: int
    dep_depth: int
    live_values: int


def _walk(jaxpr, depth_in) -> Tuple[int, int, int]:
    """Return (arith_ops, dep_depth, n_intermediate) of a (possibly
    nested) jaxpr whose invars start at the given depths."""
    depth = dict(depth_in)
    arith = 0
    max_depth = max(depth.values(), default=0)
    n_vars = 0

    def var_depth(v) -> int:
        if hasattr(v, "val"):      # Literal
            return 0
        return depth.get(v, 0)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        d_in = max((var_depth(v) for v in eqn.invars), default=0)
        width = max((_size(ov.aval) for ov in eqn.outvars), default=1)

        if name in _FREE:
            cost, d = 0, d_in
        elif name in _ARITH:
            cost, d = width, d_in + 1
        elif name in _REDUCE:
            n = max((_size(v.aval) for v in eqn.invars if not hasattr(v, "val")),
                    default=1)
            cost = max(1, n - 1)
            d = d_in + max(1, math.ceil(math.log2(max(2, n))))  # tree reduce
        elif name == "dot_general":
            shapes = [v.aval.shape for v in eqn.invars if not hasattr(v, "val")]
            k = shapes[0][-1] if shapes and shapes[0] else 1
            cost = 2 * width * max(1, k)
            d = d_in + 1 + math.ceil(math.log2(max(2, k)))
        elif name in ("scan", "while", "cond", "closed_call", "core_call"):
            # nested control flow: recurse into the first branch/body
            sub = eqn.params.get("jaxpr", None) or eqn.params.get("branches", [None])[0]
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                sub_depth = {v: d_in for v in inner.invars}
                a2, d2, n2 = _walk(inner, sub_depth)
                trips = int(eqn.params.get("length", 1) or 1)
                cost, d = a2 * trips, d_in + d2 * trips
                n_vars += n2
            else:
                cost, d = width, d_in + 1
        else:
            cost, d = width, d_in + 1

        arith += cost
        for ov in eqn.outvars:
            depth[ov] = d
            n_vars += 1
        max_depth = max(max_depth, d)
    return arith, max_depth, n_vars


def analyze_kernel(kernel: Callable, example_args: Sequence) -> KernelFacts:
    """Traverse the kernel's jaxpr and extract scheduling facts."""
    closed = jax.make_jaxpr(kernel)(*example_args)
    jaxpr = closed.jaxpr
    reads = tuple(_size(v.aval) for v in jaxpr.invars)
    writes = sum(_size(v.aval) for v in jaxpr.outvars)
    depth0 = {v: 0 for v in jaxpr.invars}
    arith, dep_depth, n_vars = _walk(jaxpr, depth0)
    live = max(4, min(n_vars, sum(reads) + writes + 4))
    return KernelFacts(reads_per_input=reads, writes=writes,
                       arith_ops=max(1, arith), dep_depth=max(1, dep_depth),
                       live_values=live)


def loop_nest_from_kernel(kernel: Callable, example_args: Sequence, *,
                          trip: int, has_plm_access: bool = True) -> LoopNest:
    """Build the hlsim LoopNest for a component from its scalar body."""
    f = analyze_kernel(kernel, example_args)
    return LoopNest(trip=trip,
                    gamma_r=max(f.reads_per_input) if f.reads_per_input else 0,
                    gamma_w=max(1, f.writes),
                    arith_ops=f.arith_ops,
                    dep_depth=f.dep_depth,
                    live_values=f.live_values,
                    has_plm_access=has_plm_access)
