"""The canonical WAMI knob-space table (Section 7.2).

One source of truth for the per-component exploration bounds —
``(max_ports, max_unrolls)`` per Table 1 component, following the paper:
'a number of ports in the interval [1, 16] and a maximum number of
unrolls in the interval [8, 32], depending on the components'.
``components.build_components``, the benchmarks, and the examples all
import from here instead of repeating the table inline.
"""

from __future__ import annotations

from typing import Dict, Tuple

from ...core.knobs import KnobSpace

__all__ = ["WAMI_KNOB_TABLE", "wami_knob_space"]

# component -> (max_ports, max_unrolls)
WAMI_KNOB_TABLE: Dict[str, Tuple[int, int]] = {
    "debayer":       (16, 32),
    "grayscale":     (16, 32),
    "gradient":      (16, 32),
    "steep_descent": (8, 16),
    "hessian":       (16, 32),
    "sd_update":     (16, 32),
    "matrix_sub":    (8, 16),
    "matrix_add":    (4, 8),
    "matrix_mul":    (4, 12),
    "matrix_resh":   (2, 8),
    "warp":          (8, 16),
    "change_det":    (8, 16),
}


def wami_knob_space(component: str, *, clock_ns: float = 1.0) -> KnobSpace:
    max_ports, max_unrolls = WAMI_KNOB_TABLE[component]
    return KnobSpace(clock_ns=clock_ns, max_ports=max_ports,
                     max_unrolls=max_unrolls)
