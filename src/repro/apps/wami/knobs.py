"""The canonical WAMI knob-space table (Section 7.2).

One source of truth for the per-component exploration bounds —
``(max_ports, max_unrolls)`` per Table 1 component, following the paper:
'a number of ports in the interval [1, 16] and a maximum number of
unrolls in the interval [8, 32], depending on the components'.
``components.build_components``, the benchmarks, and the examples all
import from here instead of repeating the table inline.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

from ...core.knobs import KnobSpace

__all__ = ["WAMI_KNOB_TABLE", "WAMI_TILE_SCALED", "WAMI_TILE_SIZES",
           "wami_knob_space"]

# component -> (max_ports, max_unrolls)
WAMI_KNOB_TABLE: Dict[str, Tuple[int, int]] = {
    "debayer":       (16, 32),
    "grayscale":     (16, 32),
    "gradient":      (16, 32),
    "steep_descent": (8, 16),
    "hessian":       (16, 32),
    "sd_update":     (16, 32),
    "matrix_sub":    (8, 16),
    "matrix_add":    (4, 8),
    "matrix_mul":    (4, 12),
    "matrix_resh":   (2, 8),
    "warp":          (8, 16),
    "change_det":    (8, 16),
}


# components whose PLM footprint scales with the tile edge — only these
# get the tile knob axis; the 6x6 matrix stages are tile-invariant
WAMI_TILE_SCALED = frozenset({
    "debayer", "grayscale", "gradient", "steep_descent", "hessian",
    "sd_update", "matrix_sub", "warp", "change_det",
})

# canonical tile axis for the 512x512 PERFECT frame: the native 128 plus
# one step down/up in PLM capacity (frame % tile == 0 for all three)
WAMI_TILE_SIZES: Tuple[int, ...] = (64, 128, 256)


def wami_knob_space(component: str, *, clock_ns: float = 1.0,
                    tile_sizes: Sequence[int] = ()) -> KnobSpace:
    """The Table-1 bounds, optionally with a tile axis.  ``tile_sizes``
    only applies to tile-scaled components (WAMI_TILE_SCALED) — the
    matrix stages would just re-synthesize identical points."""
    max_ports, max_unrolls = WAMI_KNOB_TABLE[component]
    tiles = tuple(tile_sizes) if component in WAMI_TILE_SCALED else ()
    return KnobSpace(clock_ns=clock_ns, max_ports=max_ports,
                     max_unrolls=max_unrolls, tile_sizes=tiles)
