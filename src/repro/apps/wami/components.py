"""WAMI accelerator components (PERFECT benchmark suite, paper Section 7).

Each component is specified twice, from one dataflow:

  * ``apply``  — the full-frame vectorized JAX implementation used by the
    runnable pipeline (``pipeline.py``) and the golden tests;
  * ``kernel`` — the per-iteration scalar body (what one loop iteration
    of the SystemC module computes).  Its jaxpr is the CDFG from which
    ``cdfg.py`` extracts gamma_r / gamma_w / arith / depth for Eq. (1)
    and the hlsim scheduler.

Frame geometry follows PERFECT WAMI: 512x512 16-bit Bayer input frames,
processed by the accelerator in 128x128 PLM-resident tiles (16 tiles per
frame = ``outer_repeats``).  The Lucas-Kanade components run once per LK
refinement iteration (N_LK per frame).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.hlsim import ComponentSpec, LoopNest
from ...core.knobs import KnobSpace
from .knobs import wami_knob_space
from .cdfg import analyze_kernel

__all__ = [
    "FRAME", "TILE", "N_LK",
    "WamiComponent", "build_components",
    "debayer", "grayscale", "gradient", "steepest_descent", "hessian",
    "sd_update", "matrix_add", "matrix_sub", "matrix_mul", "matrix_reshape",
    "matrix_invert", "warp_affine", "change_detection",
]

FRAME = 512          # full frame edge (pixels)
TILE = 128           # PLM-resident tile edge
N_LK = 6             # Lucas-Kanade refinement iterations per frame
_GMM_K = 3           # change-detection mixture size


# ======================================================================
# Full-frame vectorized implementations
# ======================================================================

def debayer(bayer: jnp.ndarray) -> jnp.ndarray:
    """Bilinear demosaic of an RGGB Bayer mosaic -> (H, W, 3) float32.

    R G      (0,0)=R (0,1)=G
    G B      (1,0)=G (1,1)=B
    """
    img = bayer.astype(jnp.float32)
    H, W = img.shape
    p = jnp.pad(img, 1, mode="reflect")
    c = p[1:-1, 1:-1]
    n, s = p[:-2, 1:-1], p[2:, 1:-1]
    w, e = p[1:-1, :-2], p[1:-1, 2:]
    nw, ne = p[:-2, :-2], p[:-2, 2:]
    sw, se = p[2:, :-2], p[2:, 2:]
    cross = (n + s + w + e) * 0.25
    diag = (nw + ne + sw + se) * 0.25
    horiz = (w + e) * 0.5
    vert = (n + s) * 0.5

    yy, xx = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
    r_loc = (yy % 2 == 0) & (xx % 2 == 0)
    g1_loc = (yy % 2 == 0) & (xx % 2 == 1)
    g2_loc = (yy % 2 == 1) & (xx % 2 == 0)
    b_loc = (yy % 2 == 1) & (xx % 2 == 1)

    r = jnp.where(r_loc, c, jnp.where(g1_loc, horiz, jnp.where(g2_loc, vert, diag)))
    g = jnp.where(r_loc | b_loc, cross, c)
    b = jnp.where(b_loc, c, jnp.where(g2_loc, horiz, jnp.where(g1_loc, vert, diag)))
    return jnp.stack([r, g, b], axis=-1)


def grayscale(rgb: jnp.ndarray) -> jnp.ndarray:
    """ITU-R BT.601 luma."""
    return (0.299 * rgb[..., 0] + 0.587 * rgb[..., 1] + 0.114 * rgb[..., 2])


def gradient(gray: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Central-difference image gradient (gx, gy)."""
    p = jnp.pad(gray, 1, mode="edge")
    gx = (p[1:-1, 2:] - p[1:-1, :-2]) * 0.5
    gy = (p[2:, 1:-1] - p[:-2, 1:-1]) * 0.5
    return gx, gy


def steepest_descent(gx: jnp.ndarray, gy: jnp.ndarray) -> jnp.ndarray:
    """Inverse-compositional LK steepest-descent images for an affine
    warp with parameters p = (p1..p6): returns (H, W, 6)."""
    H, W = gx.shape
    yy, xx = jnp.meshgrid(jnp.arange(H, dtype=gx.dtype),
                          jnp.arange(W, dtype=gx.dtype), indexing="ij")
    return jnp.stack([gx * xx, gx * yy, gx, gy * xx, gy * yy, gy], axis=-1)


def hessian(sd: jnp.ndarray) -> jnp.ndarray:
    """Gauss-Newton Hessian H = sum_x sd(x)^T sd(x): (6, 6)."""
    flat = sd.reshape(-1, 6)
    return flat.T @ flat


def sd_update(sd: jnp.ndarray, err: jnp.ndarray) -> jnp.ndarray:
    """b = sum_x sd(x)^T err(x): (6,)."""
    return jnp.einsum("hwk,hw->k", sd, err)


def matrix_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


def matrix_sub(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a - b


def matrix_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a @ b


def matrix_reshape(a: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    return a.reshape(shape)


def matrix_invert(a: jnp.ndarray) -> jnp.ndarray:
    """6x6 inverse via Gauss-Jordan (runs in SOFTWARE in the paper's
    system to preserve floating-point precision — modeled with a fixed
    effective latency in the TMG, Section 7.1)."""
    n = a.shape[0]
    aug = jnp.concatenate([a.astype(jnp.float64) if a.dtype == jnp.float64
                           else a.astype(jnp.float32),
                           jnp.eye(n, dtype=a.dtype)], axis=1)

    def step(i, aug):
        pivot = aug[i, i]
        row = aug[i] / pivot
        aug = aug.at[i].set(row)
        factors = aug[:, i].at[i].set(0.0)
        return aug - factors[:, None] * row[None, :]

    aug = jax.lax.fori_loop(0, n, step, aug)
    return aug[:, n:]


def warp_affine(img: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Bilinear warp of ``img`` by affine params p=(p1..p6):
    x' = (1+p1) x + p2 y + p3 ;  y' = p4 x + (1+p5) y + p6."""
    H, W = img.shape
    yy, xx = jnp.meshgrid(jnp.arange(H, dtype=img.dtype),
                          jnp.arange(W, dtype=img.dtype), indexing="ij")
    sx = (1.0 + p[0]) * xx + p[1] * yy + p[2]
    sy = p[3] * xx + (1.0 + p[4]) * yy + p[5]
    x0 = jnp.clip(jnp.floor(sx), 0, W - 2)
    y0 = jnp.clip(jnp.floor(sy), 0, H - 2)
    fx = jnp.clip(sx - x0, 0.0, 1.0)
    fy = jnp.clip(sy - y0, 0.0, 1.0)
    x0i, y0i = x0.astype(jnp.int32), y0.astype(jnp.int32)
    i00 = img[y0i, x0i]
    i01 = img[y0i, x0i + 1]
    i10 = img[y0i + 1, x0i]
    i11 = img[y0i + 1, x0i + 1]
    top = i00 * (1 - fx) + i01 * fx
    bot = i10 * (1 - fx) + i11 * fx
    return top * (1 - fy) + bot * fy


def change_detection(gray: jnp.ndarray, mu: jnp.ndarray, var: jnp.ndarray,
                     w: jnp.ndarray, *, lr: float = 0.05,
                     mahal_thresh: float = 6.25, fg_thresh: float = 0.7
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-pixel Gaussian-mixture background subtraction (K=3).

    Returns (mask, mu', var', w').  State arrays have shape (H, W, K).
    """
    x = gray[..., None]
    d2 = (x - mu) ** 2 / jnp.maximum(var, 1e-4)
    match = d2 < mahal_thresh                       # (H, W, K)
    any_match = jnp.any(match, axis=-1)
    # best (lowest-d2) matching mixture component
    d2_masked = jnp.where(match, d2, jnp.inf)
    best = jnp.argmin(d2_masked, axis=-1)
    onehot = jax.nn.one_hot(best, _GMM_K, dtype=gray.dtype) * any_match[..., None]

    mu_n = mu + onehot * lr * (x - mu)
    var_n = var + onehot * lr * ((x - mu) ** 2 - var)
    w_n = (1 - lr) * w + lr * onehot
    # no match: replace weakest component with a fresh one centred at x
    weakest = jnp.argmin(w, axis=-1)
    wh = jax.nn.one_hot(weakest, _GMM_K, dtype=gray.dtype) * (~any_match)[..., None]
    mu_n = mu_n * (1 - wh) + wh * x
    var_n = var_n * (1 - wh) + wh * 25.0
    w_n = w_n * (1 - wh) + wh * lr
    w_n = w_n / jnp.sum(w_n, axis=-1, keepdims=True)
    # foreground: matched component is low-weight, or no match at all
    matched_w = jnp.sum(onehot * w, axis=-1)
    mask = (~any_match) | (matched_w < (1.0 - fg_thresh))
    return mask, mu_n, var_n, w_n


# ======================================================================
# Per-iteration scalar kernels (the CDFGs)
# ======================================================================

def _k_debayer(quad_win: jnp.ndarray) -> jnp.ndarray:
    """One 2x2 Bayer quad (with 1-px border: 4x4 window) -> 2x2x3 RGB."""
    w = quad_win
    out = []
    for (dy, dx), kind in (((1, 1), "R"), ((1, 2), "G1"),
                           ((2, 1), "G2"), ((2, 2), "B")):
        c = w[dy, dx]
        cross = (w[dy - 1, dx] + w[dy + 1, dx] + w[dy, dx - 1] + w[dy, dx + 1]) * 0.25
        diag = (w[dy - 1, dx - 1] + w[dy - 1, dx + 1]
                + w[dy + 1, dx - 1] + w[dy + 1, dx + 1]) * 0.25
        horiz = (w[dy, dx - 1] + w[dy, dx + 1]) * 0.5
        vert = (w[dy - 1, dx] + w[dy + 1, dx]) * 0.5
        if kind == "R":
            out += [c, cross, diag]
        elif kind == "G1":
            out += [horiz, c, vert]
        elif kind == "G2":
            out += [vert, c, horiz]
        else:
            out += [diag, cross, c]
    return jnp.stack(out)


def _k_grayscale(rgb: jnp.ndarray) -> jnp.ndarray:
    return 0.299 * rgb[0] + 0.587 * rgb[1] + 0.114 * rgb[2]


def _k_gradient(cross: jnp.ndarray) -> jnp.ndarray:
    # cross = [center, west, east, north, south]
    return jnp.stack([(cross[2] - cross[1]) * 0.5, (cross[4] - cross[3]) * 0.5])


def _k_steep(grad2: jnp.ndarray, xy: jnp.ndarray) -> jnp.ndarray:
    gx, gy = grad2[0], grad2[1]
    x, y = xy[0], xy[1]
    return jnp.stack([gx * x, gx * y, gx, gy * x, gy * y, gy])


def _k_hessian(sd6: jnp.ndarray, acc: jnp.ndarray) -> jnp.ndarray:
    outer = sd6[:, None] * sd6[None, :]
    iu = jnp.triu_indices(6)
    return acc + outer[iu]


def _k_sd_update(sd6: jnp.ndarray, err: jnp.ndarray, acc: jnp.ndarray) -> jnp.ndarray:
    return acc + sd6 * err


def _k_mat_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


def _k_mat_sub(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a - b


def _k_mat_mul(row: jnp.ndarray, col: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(row, col)


def _k_mat_resh(a: jnp.ndarray) -> jnp.ndarray:
    return a * 1.0   # copy through the datapath


def _k_warp(neigh: jnp.ndarray, frac: jnp.ndarray) -> jnp.ndarray:
    fx, fy = frac[0], frac[1]
    top = neigh[0] * (1 - fx) + neigh[1] * fx
    bot = neigh[2] * (1 - fx) + neigh[3] * fx
    return top * (1 - fy) + bot * fy


def _k_change_det(px: jnp.ndarray, state9: jnp.ndarray) -> jnp.ndarray:
    mu, var, w = state9[0:3], state9[3:6], state9[6:9]
    d2 = (px - mu) ** 2 / jnp.maximum(var, 1e-4)
    match = d2 < 6.25
    any_match = jnp.any(match)
    best = jnp.argmin(jnp.where(match, d2, jnp.inf))
    onehot = jax.nn.one_hot(best, 3) * any_match
    lr = 0.05
    mu_n = mu + onehot * lr * (px - mu)
    var_n = var + onehot * lr * ((px - mu) ** 2 - var)
    w_n = (1 - lr) * w + lr * onehot
    matched_w = jnp.sum(onehot * w)
    mask = (~any_match) | (matched_w < 0.3)
    return jnp.concatenate([mu_n, var_n, w_n, mask[None].astype(mu.dtype)])


# ======================================================================
# Component table
# ======================================================================

@dataclass
class WamiComponent:
    """Binds the functional implementation to its synthesis model."""

    name: str
    apply: Callable
    kernel: Callable
    kernel_args: Tuple
    trip: int                      # dominant-loop iterations per execution
    words_in: int
    words_out: int
    outer_repeats: int
    knobs: KnobSpace
    plm_words: int = 0
    gamma_r_override: Optional[int] = None   # e.g. register-cached state
    gamma_w_override: Optional[int] = None   # e.g. register accumulators
    has_plm_access: bool = True
    base_tile: int = 0             # PLM tile the sizes above are for;
                                   # 0 = sizes do not depend on the tile

    def loop_nest(self) -> LoopNest:
        f = analyze_kernel(self.kernel, self.kernel_args)
        g_r = self.gamma_r_override
        if g_r is None:
            g_r = max(f.reads_per_input) if f.reads_per_input else 0
        g_w = self.gamma_w_override
        if g_w is None:
            g_w = max(1, f.writes)
        return LoopNest(trip=self.trip, gamma_r=g_r, gamma_w=g_w,
                        arith_ops=f.arith_ops, dep_depth=f.dep_depth,
                        live_values=f.live_values,
                        has_plm_access=self.has_plm_access)

    def spec(self) -> ComponentSpec:
        return ComponentSpec(name=self.name, loop=self.loop_nest(),
                             words_in=self.words_in, words_out=self.words_out,
                             word_bits=32, plm_words=self.plm_words,
                             outer_repeats=self.outer_repeats,
                             base_tile=self.base_tile)


def build_components(tile: int = TILE, frame: int = FRAME,
                     n_lk: int = N_LK) -> Dict[str, WamiComponent]:
    """The 12 synthesizable WAMI components (Table 1) + their knob spaces.

    Knob bounds follow Section 7.2: 'a number of ports in the interval
    [1, 16] and a maximum number of unrolls in the interval [8, 32],
    depending on the components'.
    """
    t2 = tile * tile
    tiles = (frame // tile) ** 2
    f32 = jnp.float32
    v = lambda *shape: jnp.zeros(shape, f32)
    s = jnp.zeros((), f32)

    ks = wami_knob_space            # canonical Table-1 bounds

    comps = {
        "debayer": WamiComponent(
            name="debayer", apply=debayer,
            kernel=_k_debayer, kernel_args=(v(4, 4),),
            trip=t2 // 4, words_in=t2, words_out=3 * t2,
            outer_repeats=tiles, knobs=ks("debayer"), base_tile=tile),
        "grayscale": WamiComponent(
            name="grayscale", apply=grayscale,
            kernel=_k_grayscale, kernel_args=(v(3),),
            trip=t2, words_in=3 * t2, words_out=t2,
            outer_repeats=tiles, knobs=ks("grayscale"), base_tile=tile),
        "gradient": WamiComponent(
            name="gradient", apply=gradient,
            kernel=_k_gradient, kernel_args=(v(5),),
            trip=t2, words_in=t2, words_out=2 * t2,
            outer_repeats=tiles, knobs=ks("gradient"), base_tile=tile),
        "steep_descent": WamiComponent(
            name="steep_descent", apply=steepest_descent,
            kernel=_k_steep, kernel_args=(v(2), v(2)),
            trip=t2, words_in=2 * t2, words_out=6 * t2,
            outer_repeats=tiles, knobs=ks("steep_descent"), base_tile=tile),
        "hessian": WamiComponent(
            name="hessian", apply=hessian,
            kernel=_k_hessian, kernel_args=(v(6), v(21)),
            trip=t2, words_in=6 * t2, words_out=21,
            outer_repeats=tiles, knobs=ks("hessian"), base_tile=tile,
            gamma_w_override=1),          # accumulator lives in registers
        "sd_update": WamiComponent(
            name="sd_update", apply=sd_update,
            kernel=_k_sd_update, kernel_args=(v(6), s, v(6)),
            trip=t2, words_in=7 * t2, words_out=6,
            outer_repeats=tiles * n_lk, knobs=ks("sd_update"), base_tile=tile,
            gamma_w_override=1),
        "matrix_sub": WamiComponent(
            name="matrix_sub", apply=matrix_sub,
            kernel=_k_mat_sub, kernel_args=(s, s),
            trip=t2, words_in=2 * t2, words_out=t2,
            outer_repeats=tiles * n_lk, knobs=ks("matrix_sub"), base_tile=tile),
        "matrix_add": WamiComponent(
            name="matrix_add", apply=matrix_add,
            kernel=_k_mat_add, kernel_args=(s, s),
            trip=36, words_in=72, words_out=36,
            outer_repeats=n_lk, knobs=ks("matrix_add")),
        "matrix_mul": WamiComponent(
            name="matrix_mul", apply=matrix_mul,
            kernel=_k_mat_mul, kernel_args=(v(6), v(6)),
            trip=36, words_in=72, words_out=36,
            outer_repeats=n_lk, knobs=ks("matrix_mul")),
        "matrix_resh": WamiComponent(
            name="matrix_resh", apply=lambda a: matrix_reshape(a, (-1,)),
            kernel=_k_mat_resh, kernel_args=(s,),
            trip=36, words_in=36, words_out=36,
            outer_repeats=n_lk, knobs=ks("matrix_resh")),
        "warp": WamiComponent(
            name="warp", apply=warp_affine,
            kernel=_k_warp, kernel_args=(v(4), v(2)),
            trip=t2, words_in=t2, words_out=t2,
            outer_repeats=tiles * n_lk, knobs=ks("warp"), base_tile=tile),
        "change_det": WamiComponent(
            name="change_det", apply=change_detection,
            kernel=_k_change_det, kernel_args=(s, v(9)),
            trip=t2, words_in=10 * t2, words_out=10 * t2,
            outer_repeats=tiles, knobs=ks("change_det"), base_tile=tile,
            gamma_r_override=1),          # GMM state cached in registers
    }
    return comps
