"""The WAMI application: Lucas-Kanade alignment + change detection.

This is the paper's case study (Section 7) as a runnable JAX program,
plus its TMG system model (Fig. 8) and the COSMOS entry points used by
the benchmarks:

  * :func:`lucas_kanade` — inverse-compositional LK affine registration
    built from the WAMI components;
  * :func:`wami_app` — frame-stream driver: debayer -> grayscale -> LK
    align -> warp -> GMM change detection;
  * :func:`wami_tmg` — the Fig. 8 timed marked graph (Matrix-Inv is a
    software transition with fixed latency);
  * :func:`wami_cosmos` / :func:`wami_exhaustive` — DSE drivers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ...core import (CosmosResult, ExhaustiveResult, ExplorationSession,
                     HLSTool, KnobSpace, OracleLedger, Place, PLMPlanner,
                     TMG, Transition, cosmos_dse, exhaustive_dse)
from . import components as C
from .knobs import (WAMI_KNOB_TABLE, WAMI_TILE_SIZES, wami_knob_space)

__all__ = ["lucas_kanade", "wami_app", "wami_tmg", "wami_hls_tool",
           "wami_knob_spaces", "wami_session", "wami_cosmos",
           "wami_exhaustive", "wami_plm_planner", "WAMI_KNOB_TABLE",
           "WAMI_TILE_SIZES", "MATRIX_INV_LATENCY_S"]

# Matrix-Inv runs in software (Section 7.1): fixed effective latency.
# 6x6 Gauss-Jordan on an embedded core, amortized per frame.
MATRIX_INV_LATENCY_S = 40e-6


# ----------------------------------------------------------------------
# Functional pipeline
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_iters",))
def lucas_kanade(template: jnp.ndarray, image: jnp.ndarray,
                 n_iters: int = C.N_LK) -> jnp.ndarray:
    """Inverse-compositional LK: find affine p aligning ``image`` to
    ``template``.  Returns p=(p1..p6)."""
    gx, gy = C.gradient(template)
    sd = C.steepest_descent(gx, gy)                      # (H, W, 6)
    H = C.hessian(sd)                                    # (6, 6)
    Hinv = C.matrix_invert(H + 1e-3 * jnp.eye(6, dtype=H.dtype))

    def step(p, _):
        warped = C.warp_affine(image, p)
        err = C.matrix_sub(warped, template)             # error image
        b = C.sd_update(sd, err)                         # (6,)
        dp = C.matrix_reshape(C.matrix_mul(Hinv, b), (6,))
        # inverse-compositional update: p <- p ∘ dp^-1 (first-order)
        p_new = C.matrix_sub(p, dp)
        return C.matrix_add(p_new, jnp.zeros_like(p_new)), None

    p0 = jnp.zeros(6, dtype=template.dtype)
    p, _ = jax.lax.scan(step, p0, None, length=n_iters)
    return p


def wami_app(bayer_frames: jnp.ndarray, n_iters: int = C.N_LK
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """End-to-end WAMI over a stream of Bayer frames (T, H, W).

    Returns (masks (T-1, H, W) bool, warp params (T-1, 6)).
    """
    grays = jax.vmap(lambda f: C.grayscale(C.debayer(f)))(bayer_frames)
    template = grays[0]
    Himg, Wimg = template.shape
    mu0 = jnp.repeat(template[..., None], 3, axis=-1)
    var0 = jnp.full((Himg, Wimg, 3), 36.0, template.dtype)
    w0 = jnp.full((Himg, Wimg, 3), 1.0 / 3.0, template.dtype)

    def step(carry, gray):
        mu, var, w = carry
        p = lucas_kanade(template, gray, n_iters=n_iters)
        aligned = C.warp_affine(gray, p)
        mask, mu, var, w = C.change_detection(aligned, mu, var, w)
        return (mu, var, w), (mask, p)

    (_, _, _), (masks, ps) = jax.lax.scan(step, (mu0, var0, w0), grays[1:])
    return masks, ps


# ----------------------------------------------------------------------
# System model (Fig. 8)
# ----------------------------------------------------------------------

def wami_tmg(buffers: int = 2, frames_in_flight: int = 4) -> TMG:
    """The WAMI TMG.  Forward edges carry no initial tokens; each has a
    backward capacity edge with ``buffers`` tokens (ping-pong channels,
    Fig. 3).  The LK refinement loop is an algorithmic feedback cycle
    with a single token (iterations serialize), and the frame stream is
    closed by a feedback place carrying the frames in flight."""
    names = ["debayer", "grayscale", "gradient", "steep_descent", "hessian",
             "matrix_inv", "warp", "matrix_sub", "sd_update", "matrix_mul",
             "matrix_add", "matrix_resh", "change_det"]
    ts = [Transition(n) for n in names]
    places: List[Place] = []

    def chain(a: str, b: str, tokens_fwd: int = 0):
        places.append(Place(f"fwd:{a}->{b}", a, b, tokens=tokens_fwd))
        places.append(Place(f"cap:{b}->{a}", b, a, tokens=buffers))

    # main stream
    chain("debayer", "grayscale")
    chain("grayscale", "gradient")
    # template side of LK
    chain("gradient", "steep_descent")
    chain("steep_descent", "hessian")
    chain("hessian", "matrix_inv")
    chain("matrix_inv", "matrix_mul")
    # image side of LK (iterated)
    chain("grayscale", "warp")
    chain("warp", "matrix_sub")
    chain("matrix_sub", "sd_update")
    chain("sd_update", "matrix_mul")
    chain("matrix_mul", "matrix_add")
    chain("matrix_add", "matrix_resh")
    # LK refinement loop: new params feed the next warp; one token, so
    # the refinement chain serializes per iteration.
    places.append(Place("alg:matrix_resh->warp", "matrix_resh", "warp", tokens=1))
    chain("matrix_resh", "change_det")
    # self-capacity (a module cannot re-fire while busy)
    for n in names:
        places.append(Place(f"self:{n}", n, n, tokens=1))
    # close the frame stream
    places.append(Place("loop:change_det->debayer", "change_det", "debayer",
                        tokens=frames_in_flight + len(names)))
    return TMG(ts, places)


# ----------------------------------------------------------------------
# DSE drivers
# ----------------------------------------------------------------------

def wami_hls_tool(noise: float = 1.0, tile: int = C.TILE,
                  frame: int = C.FRAME) -> HLSTool:
    """The analytical WAMI oracle.  The retile factory rebuilds the
    component table exactly at a requested tile (trip counts, PLM sizes
    and outer repeats all recomputed from the frame geometry), which is
    what makes the tile knob honest for this backend."""
    comps = C.build_components(tile=tile, frame=frame)
    return HLSTool({n: c.spec() for n, c in comps.items()}, noise=noise,
                   retile=lambda t: {
                       n: c.spec()
                       for n, c in C.build_components(tile=t,
                                                      frame=frame).items()})


def wami_knob_spaces(tile: int = C.TILE, frame: int = C.FRAME,
                     tile_sizes: Tuple[int, ...] = ()
                     ) -> Dict[str, KnobSpace]:
    """Per-component knob bounds; pass ``tile_sizes`` (e.g.
    ``WAMI_TILE_SIZES``) to open the tile axis on the tile-scaled
    components."""
    comps = C.build_components(tile=tile, frame=frame)
    if not tile_sizes:
        return {n: c.knobs for n, c in comps.items()}
    return {n: wami_knob_space(n, tile_sizes=tile_sizes) for n in comps}


def wami_plm_planner() -> PLMPlanner:
    """The WAMI memory planner: compatibility from the Fig. 8 TMG
    (certifying the LK refinement loop mutually exclusive), Matrix-Inv
    excluded (software, no PLM)."""
    return PLMPlanner(wami_tmg(), exclude=("matrix_inv",))


def wami_session(delta: float = 0.25, noise: float = 1.0, *,
                 workers: int = 1, share_plm: bool = False,
                 tile_sizes: Tuple[int, ...] = (),
                 **kwargs) -> ExplorationSession:
    """An :class:`ExplorationSession` over the WAMI system — the object
    API behind :func:`wami_cosmos`, now resolving through the registry
    (``build_session("wami", "analytical")`` with the classic
    signature).  ``share_plm`` attaches the system-level PLM planner
    (docs/memory.md); ``tile_sizes`` opens the tile knob axis."""
    from ...core.registry import build_session     # lazy: apps register late
    return build_session("wami", "analytical",
                         tool=wami_hls_tool(noise=noise), delta=delta,
                         share_plm=share_plm,
                         tile_sizes=tuple(tile_sizes),
                         workers=workers, **kwargs)


def wami_cosmos(delta: float = 0.25, noise: float = 1.0,
                counting: Optional[OracleLedger] = None, *,
                workers: int = 1) -> CosmosResult:
    """Run the full COSMOS methodology on WAMI (the paper's experiment)."""
    tool = wami_hls_tool(noise=noise)
    return cosmos_dse(wami_tmg(), tool, wami_knob_spaces(), delta=delta,
                      fixed={"matrix_inv": MATRIX_INV_LATENCY_S},
                      counting=counting, workers=workers)


def wami_exhaustive(noise: float = 1.0,
                    counting: Optional[OracleLedger] = None, *,
                    workers: int = 1) -> ExhaustiveResult:
    """The exhaustive baseline: synthesize every knob combination."""
    tool = wami_hls_tool(noise=noise)
    spaces = wami_knob_spaces()
    comps = [n for n in spaces]     # matrix_inv excluded (software)
    return exhaustive_dse(comps, tool, spaces, counting=counting,
                          workers=workers)


def wami_cosmos_no_memory(delta: float = 0.25, noise: float = 1.0
                          ) -> CosmosResult:
    """Table 1's 'No Memory' reference: the PLM is not part of the DSE —
    only standard dual-port memories are used (ports fixed at 2), and the
    exploration reduces to the unroll knob."""
    tool = wami_hls_tool(noise=noise)
    spaces = {n: KnobSpace(clock_ns=s.clock_ns, min_ports=2, max_ports=2,
                           max_unrolls=s.max_unrolls)
              for n, s in wami_knob_spaces().items()}
    return cosmos_dse(wami_tmg(), tool, spaces, delta=delta,
                      fixed={"matrix_inv": MATRIX_INV_LATENCY_S})
