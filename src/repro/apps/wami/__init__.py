"""WAMI (wide-area motion imagery) accelerator — the paper's case study."""

from .components import (FRAME, N_LK, TILE, WamiComponent, build_components,
                         change_detection, debayer, gradient, grayscale,
                         hessian, matrix_add, matrix_invert, matrix_mul,
                         matrix_reshape, matrix_sub, sd_update,
                         steepest_descent, warp_affine)
from .knobs import WAMI_KNOB_TABLE, wami_knob_space
from .pallas import (WAMI_RECORDED_TILES, default_measurement_path,
                     wami_measurement_set, wami_pallas_components,
                     wami_pallas_oracle, wami_pallas_session,
                     wami_parity_cases, wami_plm_session, wami_unit_system)
from .pipeline import (MATRIX_INV_LATENCY_S, lucas_kanade, wami_app,
                       wami_cosmos, wami_exhaustive, wami_hls_tool,
                       wami_knob_spaces, wami_session, wami_tmg)

__all__ = [
    "FRAME", "TILE", "N_LK", "WamiComponent", "build_components",
    "debayer", "grayscale", "gradient", "steepest_descent", "hessian",
    "sd_update", "matrix_add", "matrix_sub", "matrix_mul", "matrix_reshape",
    "matrix_invert", "warp_affine", "change_detection",
    "lucas_kanade", "wami_app", "wami_tmg", "wami_hls_tool",
    "wami_knob_spaces", "wami_session", "wami_cosmos", "wami_exhaustive",
    "WAMI_KNOB_TABLE", "wami_knob_space", "MATRIX_INV_LATENCY_S",
    "wami_pallas_components", "wami_pallas_oracle", "wami_pallas_session",
    "wami_plm_session", "wami_unit_system", "wami_measurement_set",
    "wami_parity_cases", "WAMI_RECORDED_TILES", "default_measurement_path",
]
